"""Distributed k-fused halo exchange sweep on the 8-device CPU mesh.

Sweeps fusion depth k x n_devices x workload for the multi-device engine
(core/distributed.py, 'dist-block' XLA compute — the kernel computes run
the Pallas interpreter off-TPU, so their CPU timings say nothing about
the MXU path and are not swept here). k=1 reproduces the pre-fusion
engine's every-step-exchange pattern (one strip all-gather per step) and
is the baseline; fused k>=2 exchanges depth-k strips once per k steps.

    PYTHONPATH=src python benchmarks/distributed_bench.py [--r 6] [--m 2]
                                                          [--smoke]

Per configuration the bench asserts parity against the single-device
block engine (bit-exact for Life, 1e-5 for the PDE workloads) and records
the engine's ``exchange_stats()`` (collectives per step, strip bytes
gathered per step) and ``memory_bytes()`` next to the timing. Writes
BENCH_distributed.json; after the JSON is written, the gate *fails the
process* unless the geometric mean over the 8-device configurations of
the best fused (k>=2) per-step speedup vs the k=1 baseline reaches 1.5x
— the CI distributed perf-gate step.

Methodology notes (the host-platform "mesh" is threads on a few cores,
so wall-clock is noisy): every k of a (workload, n_devices) cell is
timed in INTERLEAVED rounds and scored by its minimum per-step time
(noise on a shared runner only ever adds time), and each timed call runs
``--steps 32`` steps inside the engine's compiled fori_loop so the
per-call Python/dispatch overhead — identical for every k — does not
dilute the per-step exchange signal being measured.

The script forces 8 single-threaded host-platform CPU devices; it must
own the process (the flag precedes the jax import), which is also why CI
runs it as its own step rather than inside pytest.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

# hard assignment, not setdefault: the CI gate depends on the 8-device
# mesh existing — a stray inherited XLA_FLAGS must not silently shrink it
# (same pattern as tests/_distributed_check.py)
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8"
                           " --xla_cpu_multi_thread_eigen=false")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.distributed import make_distributed_engine  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402
from repro.workloads import GRAY_SCOTT, HEAT, LIFE  # noqa: E402
from benchmarks.common import emit  # noqa: E402

WORKLOADS = (LIFE, HEAT, GRAY_SCOTT)


def _tol(wl):
    return dict(rtol=0, atol=0) if wl is LIFE \
        else dict(rtol=1e-5, atol=1e-5)


def _reference(layout, wl, steps):
    eng = SqueezeBlockEngine(layout, wl, fusion_k=1)
    s = eng.init_random(0)
    for _ in range(steps):
        s = eng.step(s)
    return np.asarray(s)


def _one_time(eng, state, steps) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(eng.run(state, steps))
    return (time.perf_counter() - t0) / steps * 1e6


def bench_cell(layout, mesh, wl, ks, steps, rounds, want) -> list:
    """All fusion depths of one (workload, mesh) cell, interleaved."""
    engines, states = {}, {}
    for k in ks:
        # pinned to the all-gather exchange: this sweep's gate measures
        # the k-fusion win against its own k=1 baseline, and pinning
        # keeps the series comparable across PRs. The exchange-mode
        # comparison (p2p vs gather across device counts) is the
        # --scaling sweep below.
        eng = make_distributed_engine(layout, mesh=mesh, workload=wl,
                                      compute="jnp", fusion_k=k,
                                      exchange="gather")
        state = eng.init_random(0)
        got = eng.run(state, steps)  # warm + parity in one
        np.testing.assert_allclose(
            np.asarray(eng.to_dense(got)), want, **_tol(wl),
            err_msg=f"distributed parity broke: {wl.name}"
                    f"/nd={eng.n_shards}/k={k}")
        engines[k], states[k] = eng, state
    acc = {k: [] for k in ks}
    for k in ks:  # second warmup round, uninterleaved
        _one_time(engines[k], states[k], steps)
    for _ in range(rounds):
        for k in ks:
            acc[k].append(_one_time(engines[k], states[k], steps))
    records = []
    for k in ks:
        eng = engines[k]
        eng.reset_exchange_stats()
        eng.run(states[k], steps)
        st = eng.exchange_stats()
        us = min(acc[k])
        cells = layout.frac.volume(layout.r)
        records.append({
            "workload": wl.name, "engine": "dist-block",
            "fractal": layout.frac.name, "r": layout.r, "m": layout.m,
            "n_devices": eng.n_shards, "k": k, "us_per_step": us,
            "cells": cells, "mcells_per_s": cells / us,
            "memory_bytes": eng.memory_bytes(),
            "collectives_per_step": st.collectives_per_step,
            "bytes_gathered_per_step": st.bytes_per_step,
        })
        emit(f"dist/{wl.name}/nd{eng.n_shards}/k{k}", us,
             f"r={layout.r};coll/step={st.collectives_per_step:.2f};"
             f"KiB/step={st.bytes_per_step / 1024:.1f}")
    return records


# ------------------------------------------------ device-count scaling
def _scaling_cell(layout, nd, wl, k, steps, rounds, want):
    """One device count, both exchange modes, interleaved timing.
    Returns {exchange: record}."""
    mesh = Mesh(np.array(jax.devices()[:nd]), ("data",))
    engines, states = {}, {}
    for ex in ("gather", "p2p"):
        eng = make_distributed_engine(layout, mesh=mesh, workload=wl,
                                      compute="jnp", fusion_k=k,
                                      exchange=ex)
        state = eng.init_random(0)
        got = eng.run(state, steps)  # warm + parity in one
        np.testing.assert_allclose(
            np.asarray(eng.to_dense(got)), want, **_tol(wl),
            err_msg=f"scaling parity broke: {wl.name}/nd={nd}/{ex}")
        engines[ex], states[ex] = eng, state
    acc = {ex: [] for ex in engines}
    for ex in engines:  # second warmup round, uninterleaved
        _one_time(engines[ex], states[ex], steps)
    for _ in range(rounds):
        for ex in engines:
            acc[ex].append(_one_time(engines[ex], states[ex], steps))
    out = {}
    for ex, eng in engines.items():
        eng.reset_exchange_stats()
        eng.run(states[ex], steps)
        st = eng.exchange_stats()
        us = min(acc[ex])
        # per-device wire bytes per STEP: the scaling gate's curve. The
        # accounting is static (routing tables), so this is exact, not
        # a measurement.
        pd_step = eng.wire_bytes_per_device(k) / k
        out[ex] = {
            "workload": wl.name, "engine": "dist-block",
            "fractal": layout.frac.name, "r": layout.r, "m": layout.m,
            "exchange": ex, "n_devices": nd, "k": k,
            "us_per_step": us,
            "wire_bytes_per_device_per_step": pd_step,
            "exchanged_bytes_per_step": st.bytes_per_step,
            "neighbor_sends": st.neighbor_sends,
            "collectives_per_step": st.collectives_per_step,
        }
        emit(f"dist-scaling/{ex}/nd{nd}", us,
             f"r={layout.r};wireB/dev/step={pd_step:.0f}")
    return out


def main_scaling(args):
    """p2p-vs-gather device-count scaling sweep + gate: p2p per-device
    exchanged bytes/step must be flat in the device count (the gather
    curve grows ~linearly), and p2p must not lose to gather on the full
    mesh. Writes BENCH_dist_scaling.json."""
    n_avail = jax.device_count()
    devices = tuple(args.devices)
    if max(devices) > n_avail:
        raise SystemExit(
            f"--devices {max(devices)} exceeds the {n_avail} "
            "available devices (the gated mesh would silently shrink)")
    frac = fractals.SIERPINSKI
    # the default r=11/m=1 keeps the 8-shard strip decomposition valid
    # AND exactly flat: every shard boundary lands inside the widest
    # row band, so ms_prev/ms_next (and with them the per-device wire
    # bytes) are identical at nd = 2, 4 and 8
    layout = BlockLayout(frac, args.r, args.m)
    if not layout.strip_decomposition(max(devices)).valid:
        raise SystemExit(
            f"r={args.r}, m={args.m} has too few occupied rows for a "
            f"{max(devices)}-shard p2p decomposition — raise --r")
    wl, k = LIFE, min(2, layout.rho)
    want = _reference(layout, wl, args.steps)

    def sweep():
        cells = {}
        for nd in devices:
            cells[nd] = _scaling_cell(layout, nd, wl, k, args.steps,
                                      args.rounds, want)
        return cells

    def curve(cells, ex, field):
        return {nd: cells[nd][ex][field] for nd in devices}

    # byte curves are static routing-table arithmetic: one sweep decides
    # them. Wall-clock on the oversubscribed shared CPU runner is noisy:
    # the time condition gets up to 3 measurement attempts (best kept).
    attempts, best_cells, best_ratio = 0, None, float("inf")
    while attempts < (1 if args.smoke else 3):
        attempts += 1
        cells = sweep()
        nd_max = max(devices)
        ratio = (cells[nd_max]["p2p"]["us_per_step"]
                 / cells[nd_max]["gather"]["us_per_step"])
        if ratio < best_ratio:
            best_cells, best_ratio = cells, ratio
        if best_ratio <= args.max_slowdown:
            break
        if attempts < 3 and not args.smoke:
            print(f"scaling gate attempt {attempts}: p2p/gather time "
                  f"ratio {ratio:.2f} > {args.max_slowdown} — "
                  "re-measuring")
    cells = best_cells
    nd_lo = min(nd for nd in devices if nd >= 2)
    nd_max = max(devices)
    p2p_pd = curve(cells, "p2p", "wire_bytes_per_device_per_step")
    gat_pd = curve(cells, "gather", "wire_bytes_per_device_per_step")
    p2p_flat = p2p_pd[nd_max] <= p2p_pd[nd_lo] * args.flat_tol
    gather_grows = gat_pd[nd_max] >= gat_pd[nd_lo] * 1.5
    p2p_fast = best_ratio <= args.max_slowdown
    gate = {
        "n_devices": nd_max, "attempts": attempts,
        "p2p_bytes_per_device": p2p_pd,
        "gather_bytes_per_device": gat_pd,
        "flat_tol": args.flat_tol,
        "p2p_bytes_flat": bool(p2p_flat),
        "gather_bytes_grow": bool(gather_grows),
        "p2p_vs_gather_time_ratio": best_ratio,
        "max_slowdown": args.max_slowdown,
        "p2p_no_time_regression": bool(p2p_fast),
        "pass": bool(p2p_flat and gather_grows and p2p_fast),
    }
    records = [rec for nd in devices for rec in cells[nd].values()]
    out = pathlib.Path(args.out)
    out.write_text(json.dumps({
        "mode": "scaling", "fractal": frac.name, "r": args.r,
        "m": args.m, "k": k, "steps": args.steps,
        "rounds": args.rounds, "backend": jax.default_backend(),
        "n_devices_available": n_avail,
        "records": records, "gate": gate,
    }, indent=2))
    print(f"wrote {out} ({len(records)} records)")
    for nd in devices:
        p_us = cells[nd]["p2p"]["us_per_step"]
        g_us = cells[nd]["gather"]["us_per_step"]
        print(f"scaling nd={nd}: p2p {p_us:.1f}us/step "
              f"({p2p_pd[nd]:.0f} B/dev/step), gather {g_us:.1f}"
              f"us/step ({gat_pd[nd]:.0f} B/dev/step)")
    # JSON first, so a regression still leaves the curves behind
    if args.smoke:
        print(f"smoke: p2p/gather time ratio {best_ratio:.2f} "
              "(gate not enforced)")
        return
    if not gate["pass"]:
        msgs = []
        if not p2p_flat:
            msgs.append(
                f"p2p per-device bytes grew with the mesh: "
                f"{p2p_pd[nd_lo]:.0f} B @ nd={nd_lo} -> "
                f"{p2p_pd[nd_max]:.0f} B @ nd={nd_max} "
                f"(tol {args.flat_tol}x)")
        if not gather_grows:
            msgs.append("gather per-device bytes did not grow — the "
                        "baseline curve is wrong")
        if not p2p_fast:
            msgs.append(
                f"p2p lost to gather on nd={nd_max}: time ratio "
                f"{best_ratio:.2f} > {args.max_slowdown}")
        raise SystemExit("dist-scaling gate failed: " + "; ".join(msgs))
    print(f"dist-scaling gate: p2p bytes flat "
          f"({p2p_pd[nd_lo]:.0f} -> {p2p_pd[nd_max]:.0f} B/dev/step), "
          f"gather grows ({gat_pd[nd_lo]:.0f} -> {gat_pd[nd_max]:.0f}), "
          f"p2p/gather time ratio {best_ratio:.2f} on nd={nd_max}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=6)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=15,
                    help="interleaved timing rounds per cell")
    ap.add_argument("--steps", type=int, default=32,
                    help="steps per timed run() call")
    ap.add_argument("--devices", type=int, nargs="+", default=(2, 4, 8))
    ap.add_argument("--ks", type=int, nargs="+", default=(1, 2, 4))
    ap.add_argument("--gate", type=float, default=1.5)
    ap.add_argument("--scaling", action="store_true",
                    help="p2p-vs-gather device-count scaling sweep + "
                         "gate instead of the k-fusion sweep (r/m "
                         "default to 11/1 — the exchange-bound fine-"
                         "block regime; devices default to 1 2 4 8)")
    ap.add_argument("--max-slowdown", type=float, default=1.05,
                    help="scaling gate: max allowed p2p/gather "
                         "per-step time ratio on the full mesh")
    ap.add_argument("--flat-tol", type=float, default=1.25,
                    help="scaling gate: max allowed growth of p2p "
                         "per-device bytes from the smallest multi-"
                         "device mesh to the full mesh")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep: {1,8} devices, 4 rounds (dev loop; "
                         "gate not enforced)")
    ap.add_argument("--out", default="BENCH_distributed.json")
    args = ap.parse_args()
    if args.scaling:
        # scaling defaults differ: full device curve, and the fine-block
        # exchange-bound regime (m=1 -> rho=2: ~4*ns/rho wire bytes per
        # compute cell under gather) where the neighbor-only exchange
        # is the difference that shows — at coarse blocks the all-gather
        # is a negligible in-process memcpy and the sweep measures
        # nothing but compute
        if ap.get_default("r") == args.r:
            args.r = 11
        if ap.get_default("m") == args.m:
            args.m = 1
        if tuple(args.devices) == tuple(ap.get_default("devices")):
            args.devices = (1, 2, 4, 8)
        if ap.get_default("out") == args.out:
            args.out = "BENCH_dist_scaling.json"
        if args.smoke:
            args.rounds, args.devices = 4, (1, 2, 8)
        return main_scaling(args)
    if args.smoke:
        args.rounds, args.devices = 4, (1, 8)

    n_avail = jax.device_count()
    if max(args.devices) > n_avail:
        raise SystemExit(
            f"--devices {max(args.devices)} exceeds the {n_avail} "
            "available devices (the gated mesh would silently shrink)")
    frac = fractals.SIERPINSKI
    layout = BlockLayout(frac, args.r, args.m)
    ks = tuple(k for k in args.ks if k <= layout.rho)

    refs = {wl.name: _reference(layout, wl, args.steps)
            for wl in WORKLOADS}

    def sweep(nd):
        mesh = Mesh(np.array(jax.devices()[:nd]), ("data",))
        return [rec for wl in WORKLOADS
                for rec in bench_cell(layout, mesh, wl, ks, args.steps,
                                      args.rounds, refs[wl.name])]

    def cell_speedups(recs):
        """Best fused (k>=2) speedup vs the k=1 every-step-exchange
        baseline, per (workload, n_devices) cell."""
        out = []
        for rec in recs:
            if rec["k"] != 1:
                continue
            fused = [f for f in recs
                     if f["workload"] == rec["workload"]
                     and f["n_devices"] == rec["n_devices"]
                     and f["k"] > 1]
            if not fused:
                continue
            best = min(fused, key=lambda f: f["us_per_step"])
            out.append({
                "workload": rec["workload"],
                "n_devices": rec["n_devices"], "best_k": best["k"],
                "speedup": rec["us_per_step"] / best["us_per_step"],
            })
        return out

    def geo(sps):
        vals = [s["speedup"] for s in sps]
        return float(np.exp(np.mean(np.log(vals)))) if vals \
            else float("nan")

    records = []
    for nd in args.devices:
        if nd <= n_avail and nd != max(args.devices):
            records.extend(sweep(nd))
    # the gated mesh: wall-clock on an oversubscribed shared CPU runner
    # is noisy, so a below-threshold geomean is re-measured (up to 3
    # attempts, best kept) — a structural regression fails every attempt
    attempts = 0
    gated_records, geomean = [], float("-inf")
    while attempts < (1 if args.smoke else 3):
        attempts += 1
        recs = sweep(max(args.devices))
        g = geo(cell_speedups(recs))
        if g > geomean:
            gated_records, geomean = recs, g
        if geomean >= args.gate:
            break
        if attempts < 3 and not args.smoke:
            print(f"gate attempt {attempts}: geomean {g:.2f}x < "
                  f"{args.gate}x — re-measuring")
    records.extend(gated_records)
    speedups = cell_speedups(records)
    gated = [s["speedup"] for s in speedups
             if s["n_devices"] == max(args.devices)]

    out = pathlib.Path(args.out)
    out.write_text(json.dumps({
        "fractal": frac.name, "r": args.r, "m": args.m,
        "steps": args.steps, "rounds": args.rounds,
        "backend": jax.default_backend(),
        "n_devices_available": n_avail,
        "records": records, "speedups": speedups,
        "gate": {"n_devices": max(args.devices), "threshold": args.gate,
                 "geomean_fused_speedup": geomean,
                 "attempts": attempts},
    }, indent=2))
    print(f"wrote {out} ({len(records)} records)")
    for s in speedups:
        print(f"dist speedup {s['workload']}/nd{s['n_devices']}: "
              f"{s['speedup']:.2f}x (best k={s['best_k']})")
    # JSON first, so a regression still leaves the timings behind
    if args.smoke:
        print(f"smoke: geomean fused speedup on nd={max(args.devices)} = "
              f"{geomean:.2f}x (gate not enforced)")
        return
    if not gated or not math.isfinite(geomean):
        raise SystemExit("no gated configurations ran")
    print(f"dist gate: geomean fused speedup on nd={max(args.devices)} = "
          f"{geomean:.2f}x over {len(gated)} workloads")
    if geomean < args.gate:
        raise SystemExit(
            f"k-fused distributed stepping geomean speedup {geomean:.2f}x "
            f"< {args.gate}x vs the every-step-exchange baseline on the "
            f"{max(args.devices)}-device mesh")


if __name__ == "__main__":
    main()
