"""Distributed chaos matrix on the 8-device CPU mesh: every shard-level
fault class must recover bit-exact, inside the recovery-time bound.

    PYTHONPATH=src python benchmarks/chaos_dist_bench.py \
        [--smoke] [--max-recovery-s 20] [--out BENCH_chaos_dist.json]

Seven scenarios, one per fault class of the shard-aware chaos matrix
(DESIGN.md Sections 9-10), each driving an
:class:`~repro.core.elastic.ElasticDistributedRunner` over the full
8-device mesh with sharded checkpointing enabled:

  * ``shard_exception`` — a shard raises mid-run: backoff + restore;
  * ``shard_stall``     — a fused launch stalls past the launch
    timeout: the launch is abandoned, the engine rebuilt, the run
    restored (the hang class);
  * ``halo_corrupt``    — a shard's tiles come back poisoned: the
    post-launch dead-cell integrity check detects it, restore;
  * ``damaged_ckpt``    — the newest checkpoint is corrupted on disk,
    then a shard raises: the restore falls back to the previous intact
    step (crc32 walk);
  * ``device_loss``     — a shard's device is lost: elastic reshard
    8 -> 4 devices, the newest intact sharded checkpoint restores onto
    the smaller mesh (repadded, operands rebuilt), degraded-mode
    finish;
  * ``strip_drop``      — a neighbor strip send is lost in flight on
    the p2p (``ppermute``) exchange path: the launch aborts, backoff +
    restore relaunches and re-issues the permutes;
  * ``strip_corrupt``   — a received neighbor strip was damaged on the
    wire (p2p path): the dead-cell integrity check catches the
    poisoned band rows, restore.

Every scenario asserts the final state is BIT-EXACT against an
uninterrupted single-device run of the same seed (Life CA), and
records the runner's recovery stats (failures / retries / reshards /
recovery seconds). After the JSON is written the gate fails the
process if any scenario's parity broke or the maximum recovery time
exceeded ``--max-recovery-s`` — the CI chaos-dist gate. Prints
``CHAOS_DIST_OK`` on success (the pytest wrapper greps for it).

The script forces 8 single-threaded host-platform CPU devices; the
flag must precede the jax import, which is why CI runs it in its own
interpreter (same pattern as distributed_bench.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

# hard assignment, not setdefault: the suite depends on the 8-device
# mesh existing — a stray inherited XLA_FLAGS must not silently shrink
# it (same pattern as tests/_distributed_check.py)
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8"
                           " --xla_cpu_multi_thread_eigen=false")

import numpy as np  # noqa: E402

from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.elastic import ElasticDistributedRunner  # noqa: E402
from repro.core.fractals import SIERPINSKI  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402
from repro.runtime.fault import Fault, FaultInjector  # noqa: E402
from repro.workloads import LIFE  # noqa: E402

SEED = 11


K = 2  # fused launch depth of every scenario


def scenarios(steps, ckpt_every):
    """name -> (faults, runner kwargs, ckpt_every). ``at_segment``
    indexes the runner's launch-attempt counter (k=2 -> launch n
    starts at step 2n); checkpoints land every ``ckpt_every`` steps,
    so the checkpoint at step ``c`` is written when the counter reads
    ``c / k``."""
    # damaged_ckpt needs TWO checkpoints before the crash so the
    # fallback walk has an intact earlier step to land on
    ce = max(K, (steps // 4) // K * K)
    second = 2 * ce // K            # counter at the 2nd checkpoint
    return {
        "shard_exception": (
            [Fault("shard_exception", at_segment=2, shard=1)],
            {}, ckpt_every),
        "shard_stall": (
            [Fault("shard_stall", at_segment=2, stall_s=3.0)],
            dict(launch_timeout_s=1.0, compile_grace_s=120.0),
            ckpt_every),
        "halo_corrupt": (
            [Fault("halo_corrupt", at_segment=3, shard=2)],
            {}, ckpt_every),
        # damage the 2nd checkpoint the moment it lands, then crash a
        # shard: the restore must fall back to the 1st (intact) step
        "damaged_ckpt": (
            [Fault("corrupt", at_segment=second),
             Fault("shard_exception", at_segment=second + 1)],
            {}, ce),
        "device_loss": (
            [Fault("device_loss", at_segment=5, shard=3)],
            {}, ckpt_every),
        # the neighbor-only exchange: pin exchange='p2p' so recovery is
        # proven on the ppermute path specifically (the other scenarios
        # ride the 'auto' default, which also resolves to p2p here)
        "strip_drop": (
            [Fault("strip_drop", at_segment=2, shard=1)],
            dict(exchange="p2p"), ckpt_every),
        "strip_corrupt": (
            [Fault("strip_corrupt", at_segment=3, shard=2,
                   band_rows=K)],
            dict(exchange="p2p"), ckpt_every),
    }


def run_scenario(name, faults, kwargs, layout, ref, steps, ckpt_every):
    inj = FaultInjector(faults)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = ElasticDistributedRunner(
            layout, workload=LIFE, fusion_k=K, ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every, max_retries=4,
            backoff_base_s=0.02, backoff_cap_s=0.25,
            injector=inj, **kwargs)
        n0 = runner.n_shards
        t0 = time.perf_counter()
        out = runner.run(steps, seed=SEED)
        wall = time.perf_counter() - t0
        final = np.asarray(runner.engine.to_dense(out))
        runner.close()
    exact = bool(np.array_equal(final, ref))
    st = runner.stats
    rec = {
        "scenario": name, "bit_exact": exact, "wall_s": wall,
        "shards_before": n0, "shards_after": runner.n_shards,
        "fired": [list(e) for e in inj.log],
        "pending": len(inj.pending()),
        **{f.name: getattr(st, f.name)
           for f in dataclasses.fields(st)},
        "max_recovery_s": st.max_recovery_s,
    }
    print(f"[chaos-dist] {name}: bit_exact={exact} "
          f"failures={st.failures} retries={st.retries} "
          f"reshards={st.reshards} shards={n0}->{runner.n_shards} "
          f"max_recovery={st.max_recovery_s:.3f}s", flush=True)
    assert inj.all_fired(), f"{name}: unfired faults {inj.pending()}"
    assert st.failures >= 1, f"{name}: no fault was detected"
    if name == "device_loss":
        assert runner.n_shards < n0, "device loss did not reshard"
        assert st.degraded and st.reshards == 1
    if name == "damaged_ckpt":
        assert st.restores >= 1, "no fallback restore happened"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=5)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--ckpt-every", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="shorter run (same scenario coverage)")
    ap.add_argument("--max-recovery-s", type=float, default=None,
                    help="gate: fail if any recovery exceeds this")
    ap.add_argument("--out", default="BENCH_chaos_dist.json")
    args = ap.parse_args()
    steps = 16 if args.smoke else args.steps
    ckpt_every = min(args.ckpt_every, steps // 2)

    import jax
    assert len(jax.devices()) == 8, jax.devices()
    layout = BlockLayout(SIERPINSKI, r=args.r, m=args.m)
    eng = SqueezeBlockEngine(layout, LIFE, fusion_k=K)
    ref = np.asarray(eng.run(eng.init_random(SEED), steps))

    records = []
    for name, (faults, kwargs, ce) in scenarios(steps,
                                                ckpt_every).items():
        records.append(run_scenario(name, faults, kwargs, layout, ref,
                                    steps, ce))

    max_rec = max(r["max_recovery_s"] for r in records)
    all_exact = all(r["bit_exact"] for r in records)
    gate = {"scenarios": len(records), "bit_exact": all_exact,
            "max_recovery_s": max_rec,
            "bound_s": args.max_recovery_s,
            "pass": all_exact and (args.max_recovery_s is None
                                   or max_rec <= args.max_recovery_s)}
    out = pathlib.Path(args.out)
    out.write_text(json.dumps({"records": records, "gate": gate},
                              indent=2))
    print(f"[chaos-dist] wrote {out}; gate={gate}", flush=True)
    if not gate["pass"]:
        print("[chaos-dist] GATE FAILED", flush=True)
        raise SystemExit(1)
    print("CHAOS_DIST_OK", flush=True)


if __name__ == "__main__":
    main()
