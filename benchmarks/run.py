"""Benchmark orchestrator — one module per paper table/figure plus the
roofline analysis. Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (fig10_mrf, fig13_speedup, fig14_tensorcore,
                            roofline, stencil_traffic, table2_memory)
    modules = [
        ("fig10_mrf", fig10_mrf.run),
        # fig13 runs fig12 internally (shares timings)
        ("fig12+fig13", fig13_speedup.run),
        ("fig14_tensorcore", fig14_tensorcore.run),
        ("table2_memory", table2_memory.run),
        ("stencil_traffic", stencil_traffic.run),
        ("roofline-single-pod", lambda: roofline.run("16x16")),
        ("roofline-multi-pod", lambda: roofline.run("2x16x16")),
        ("roofline-validate", roofline.validate_analytic_vs_compiled),
    ]
    failed = []
    for name, fn in modules:
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
