"""3D fused-block sweep: block3d (k-fused) vs the cell3d per-cell engine.

Sweeps r x rho x k on the 3D NBB fractals (Sierpinski tetrahedron,
Menger sponge): per configuration the cell-level engine (one lambda3
per cell + one nu3 per neighbor, re-evaluated every step) is the
baseline and the block engine steps through its depth-k fused path
(``step_k``; k = 1 is the unfused block step). Step-for-step parity
against the cell engine is asserted before timing (bit-exact for
LIFE3D, 1e-5 for HEAT3D), so the bench doubles as the CI 3D
correctness smoke.

    PYTHONPATH=src python benchmarks/stencil3d_bench.py [--smoke]
                                                        [--min-speedup 1.5]

Writes BENCH_3d.json (one record per (fractal, workload, engine, r, m,
k): us_per_step amortized over the fused launch, mcells_per_s,
state_bytes). After the JSON is written the gate *fails the process*
unless the geometric mean over configurations of the best fused-block
(k >= 2) per-step speedup over the cell engine reaches ``--min-speedup``
— the CI 3d perf-gate step (benchmarks/ci_gates.py --gate 3d).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fractals3d as f3  # noqa: E402
from repro.core.stencil import make_engine  # noqa: E402
from repro.workloads import HEAT3D, LIFE3D  # noqa: E402
from benchmarks.common import emit, time_fn  # noqa: E402

WORKLOADS = (LIFE3D, HEAT3D)


def _tol(wl):
    return dict(rtol=0, atol=0) if wl is LIFE3D \
        else dict(rtol=1e-5, atol=1e-5)


def _single_steps(eng, state, n):
    for _ in range(n):
        state = eng.step(state)
    return state


def bench_cell(frac, r, wl, iters) -> dict:
    eng = make_engine("cell3d", frac, r, workload=wl)
    state = eng.init_random(seed=0)
    us = time_fn(eng.step, state, iters=iters)
    cells = frac.volume(r)
    rec = {
        "workload": wl.name, "engine": "cell3d", "fractal": frac.name,
        "r": r, "m": 0, "k": 1, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "state_bytes": eng.memory_bytes(),
    }
    emit(f"stencil3d/{wl.name}/cell3d/r{r}", us,
         f"mcups={rec['mcells_per_s']:.1f}")
    return rec


def bench_block(frac, r, m, wl, k, iters, want) -> dict:
    """Amortized per-step cost of one fused block3d launch; parity vs
    the cell engine's expanded trajectory (``want``) is asserted before
    timing. Both engines seed their start state from the same BB3D
    ``init_random(seed=0)`` path, so the trajectories are comparable."""
    eng = make_engine("block3d", frac, r, m, workload=wl, fusion_k=k)
    state = eng.init_random(seed=0)
    got = eng.to_expanded(eng.step_k(state, k) if k > 1
                          else eng.step(state))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **_tol(wl),
        err_msg=f"3d parity broke: block3d/{wl.name}/r={r}/m={m}/k={k}")
    if k > 1:
        us = time_fn(lambda s: eng.step_k(s, k), state, iters=iters) / k
    else:
        us = time_fn(eng.step, state, iters=iters)
    cells = frac.volume(r)
    rho = frac.s ** m
    rec = {
        "workload": wl.name, "engine": "block3d", "fractal": frac.name,
        "r": r, "m": m, "rho": rho, "k": k, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "state_bytes": eng.memory_bytes(),
    }
    emit(f"stencil3d/{wl.name}/block3d/r{r}/rho{rho}/k{k}", us,
         f"mcups={rec['mcells_per_s']:.1f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller levels (CI end-to-end check)")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="geomean best fused-block speedup over the cell "
                         "engine required to pass (the CI 3d gate)")
    ap.add_argument("--out", default="BENCH_3d.json")
    args = ap.parse_args()
    iters = max(args.iters, 10)

    # (fractal, r, block levels m) — rho = s**m per level
    configs = ([(f3.SIERPINSKI3D, 6, (1, 2)), (f3.MENGER, 3, (1,))]
               if args.smoke else
               [(f3.SIERPINSKI3D, 8, (1, 2)), (f3.MENGER, 3, (1,))])

    records, speedups = [], []
    for frac, r, ms in configs:
        for wl in WORKLOADS:
            cell_eng = make_engine("cell3d", frac, r, workload=wl)
            base = bench_cell(frac, r, wl, iters)
            records.append(base)
            for m in ms:
                rho = frac.s ** m
                ks = sorted({1, 2, rho})
                # one shared oracle trajectory per (config, k)
                s0 = cell_eng.init_random(seed=0)
                best = 0.0
                for k in ks:
                    want = cell_eng.to_expanded(
                        _single_steps(cell_eng, s0, k))
                    rec = bench_block(frac, r, m, wl, k, iters, want)
                    records.append(rec)
                    if k >= 2:
                        best = max(best,
                                   base["us_per_step"] / rec["us_per_step"])
                speedups.append((f"{frac.name}/{wl.name}/r{r}/rho{rho}",
                                 best))

    out = pathlib.Path(args.out)
    out.write_text(json.dumps({
        "backend": jax.default_backend(),
        "min_speedup": args.min_speedup,
        "records": records}, indent=2))
    print(f"wrote {out} ({len(records)} records)")
    # JSON first, so a regression still leaves the timings behind
    for name, x in speedups:
        print(f"3d fused-block speedup {name}: {x:.2f}x")
    geomean = float(np.exp(np.mean(np.log([x for _, x in speedups]))))
    print(f"3d gate: geomean best fused (k>=2) block3d speedup over "
          f"cell3d = {geomean:.2f}x ({len(speedups)} configs)")
    if geomean < args.min_speedup:
        raise SystemExit(
            f"3d fused-block geomean speedup {geomean:.2f}x < "
            f"{args.min_speedup}x over the cell3d engine")


if __name__ == "__main__":
    main()
