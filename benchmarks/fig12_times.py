"""Paper Fig. 12: execution time per game-of-life step for the three
approaches (BB / lambda / Squeeze) on the Sierpinski triangle, sweeping
the level r and the Squeeze block size rho.

IMPORTANT CAVEAT (recorded in EXPERIMENTS.md): this container is CPU-only,
so absolute times are NOT comparable to the paper's GPU walls; the
structural signal (compact engines touch k^r cells vs the BB's s^2r, and
the crossover as r grows) is what we validate. The TPU deployment path is
the Pallas kernel pair (kernels/squeeze_stencil.py).
"""
from repro.core import fractals
from repro.core.baselines import BBEngine, LambdaEngine
from repro.core.compact import BlockLayout
from repro.core.stencil import SqueezeBlockEngine, SqueezeCellEngine
from benchmarks.common import emit, time_fn

LEVELS = (5, 7, 9)
RHO_M = (1, 2, 4)   # rho = 2^m


def run(levels=LEVELS):
    frac = fractals.SIERPINSKI
    results = {}
    for r in levels:
        engines = {"bb": BBEngine(frac, r), "lambda": LambdaEngine(frac, r),
                   "cell": SqueezeCellEngine(frac, r)}
        for m in RHO_M:
            if m < r:
                engines[f"block_rho{2**m}"] = SqueezeBlockEngine(
                    BlockLayout(frac, r, m))
        for name, eng in engines.items():
            state = eng.init_random(seed=1)
            us = time_fn(eng.step, state, warmup=2, iters=8)
            results[(r, name)] = us
            cells = (frac.side(r) ** 2 if name in ("bb", "lambda")
                     else frac.volume(r))
            emit(f"fig12/time/sierpinski/r={r}/{name}", us,
                 f"cells={cells};ns_per_cell={1e3 * us / cells:.2f}")
    return results


if __name__ == "__main__":
    run()
