"""Serving-layer gate: continuous-batching throughput + bounded
recovery.

Phase 1 (throughput): N same-bucket requests served with continuous
batching (``max_batch=N`` — one vmapped launch per segment for the
whole batch) vs the per-request baseline (``max_batch=1`` — the same
service machinery, one row per launch, which is what serving without
batching costs). Continuous batching must reach ``--min-speedup`` x
the per-request rate. The raw sequential engine loop (no service at
all) is also timed and recorded — informational: on single-device CPU
its compute equals the vmapped batch's, so it bounds what any serving
layer can reach rather than gating this one. Results are asserted
bit-exact against the raw engine runs first — a fast wrong answer
never passes.

Phase 2 (recovery): the same workload with an in-step crash and a
corrupted checkpoint injected mid-run. Every request must still finish
``ok`` and bit-exact, and the measured recovery time (failure ->
batch healthy again, from the ``serve.recovery_seconds`` histogram)
must stay under ``--max-recovery-s``.

Writes ``BENCH_serve.json`` (records + a ``gate`` verdict) before the
gate check, so a failing run still leaves its numbers behind for the
CI artifact upload.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py \
        --min-speedup 1.0 --max-recovery-s 10.0 --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from repro import obs
from repro.core import fractals
from repro.core.stencil import make_engine
from repro.runtime.fault import Fault, FaultInjector
from repro.serving import FractalService, ServiceConfig, SimRequest
from repro.workloads import LIFE

FRAC = fractals.SIERPINSKI
R = 5
M = 2


def _reqs(n, steps, prefix, snapshot_every=0):
    return [SimRequest(frac=FRAC, r=R, steps=steps, m=M, workload=LIFE,
                       seed=s, snapshot_every=snapshot_every,
                       rid=f"{prefix}-{s}")
            for s in range(n)]


def _sequential(n, steps, eng):
    """The no-service baseline: one engine, one request at a time."""
    outs = []
    t0 = time.perf_counter()
    for s in range(n):
        state = eng.run(eng.init_random(s), steps)
        outs.append(np.asarray(state))  # host read, like SimResult.state
    dt = time.perf_counter() - t0
    return outs, dt


def _serve_timed(cfg, runner, reqs):
    svc = FractalService(cfg, runner=runner)
    t0 = time.perf_counter()
    res = svc.serve(reqs)
    return res, time.perf_counter() - t0


def bench_throughput(n, steps, cfg, base_cfg, runner):
    # warm every path OUTSIDE the timed region: the raw loop pays its
    # single-sim trace, each service config its vmapped trace at its
    # real batch size (the shared runner keeps the compiled entries
    # across service instances)
    eng = make_engine("block", FRAC, R, M, workload=LIFE)
    _sequential(n, 2, eng)
    FractalService(base_cfg, runner=runner).serve(_reqs(2, 2, "w1"))
    FractalService(cfg, runner=runner).serve(_reqs(n, 2, "wn"))
    refs, raw_s = _sequential(n, steps, eng)

    base_res, base_s = _serve_timed(base_cfg, runner,
                                    _reqs(n, steps, "base"))
    res, svc_s = _serve_timed(cfg, runner, _reqs(n, steps, "tput"))
    for i, r in enumerate(res):
        assert r.ok, (r.rid, r.status, r.error)
        np.testing.assert_array_equal(refs[i], r.state)
    for i, r in enumerate(base_res):
        assert r.ok, (r.rid, r.status, r.error)
        np.testing.assert_array_equal(refs[i], r.state)
    return {"phase": "throughput", "n": n, "steps": steps,
            "raw_seq_s": raw_s, "raw_seq_rps": n / raw_s,
            "per_request_s": base_s, "per_request_rps": n / base_s,
            "svc_s": svc_s, "svc_rps": n / svc_s,
            "speedup": base_s / svc_s}


def bench_recovery(n, steps, cfg, reg, runner):
    eng = make_engine("block", FRAC, R, M, workload=LIFE)
    refs, _ = _sequential(n, steps, eng)
    inj = FaultInjector([Fault(kind="exception", at_segment=1),
                         Fault(kind="corrupt", at_segment=1),
                         Fault(kind="exception", at_segment=3)])
    svc = FractalService(cfg, runner=runner, injector=inj)
    t0 = time.perf_counter()
    res = svc.serve(_reqs(n, steps, "chaos", snapshot_every=8))
    wall = time.perf_counter() - t0
    for i, r in enumerate(res):
        assert r.ok, (r.rid, r.status, r.error)
        np.testing.assert_array_equal(refs[i], r.state)
    assert inj.all_fired(), inj.pending()
    rec = reg.histogram("serve.recovery_seconds", kind="block")
    assert rec.count >= 2, "recoveries not recorded"
    return {"phase": "recovery", "n": n, "steps": steps, "wall_s": wall,
            "faults": [f.kind for f in inj.faults],
            "recoveries": rec.count, "mean_recovery_s": rec.mean,
            "max_recovery_s": rec.max}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8,
                    help="requests per phase (one bucket)")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--min-speedup", type=float, default=1.0)
    ap.add_argument("--max-recovery-s", type=float, default=10.0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    from repro.workloads import BatchedRunner
    runner = BatchedRunner()
    with obs.enabled_scope(True) as reg:
        obs.reset()
        # throughput phase: no snapshots requested, so let one segment
        # cover the whole run — the batch advantage is one vmapped call
        # for all n requests vs n sequential dispatches
        cfg = ServiceConfig(max_batch=args.n,
                            max_segment_steps=args.steps,
                            backoff_base_s=0.01, backoff_cap_s=0.1,
                            hang_threshold_s=30.0)
        base_cfg = ServiceConfig(max_batch=1,
                                 max_segment_steps=args.steps,
                                 backoff_base_s=0.01,
                                 backoff_cap_s=0.1,
                                 hang_threshold_s=30.0)
        records = [bench_throughput(args.n, args.steps, cfg, base_cfg,
                                    runner)]
        with tempfile.TemporaryDirectory() as d:
            ccfg = ServiceConfig(max_batch=args.n, max_segment_steps=8,
                                 backoff_base_s=0.01, backoff_cap_s=0.1,
                                 hang_threshold_s=30.0, ckpt_dir=d)
            records.append(bench_recovery(args.n, args.steps, ccfg,
                                          reg, runner))

    tput, rec = records
    gate = {
        "min_speedup": args.min_speedup,
        "speedup": tput["speedup"],
        "per_request_rps": tput["per_request_rps"],
        "raw_seq_rps": tput["raw_seq_rps"],
        "svc_rps": tput["svc_rps"],
        "max_recovery_s": args.max_recovery_s,
        "recovery_s": rec["max_recovery_s"],
        "recoveries": rec["recoveries"],
        "passed": (tput["speedup"] >= args.min_speedup
                   and rec["max_recovery_s"] <= args.max_recovery_s),
    }
    with open(args.out, "w") as f:
        json.dump({"records": records, "gate": gate}, f, indent=2)
    print(f"[serve_bench] per-request {tput['per_request_rps']:.2f} "
          f"req/s -> batched {tput['svc_rps']:.2f} req/s "
          f"({tput['speedup']:.2f}x; raw loop "
          f"{tput['raw_seq_rps']:.2f} req/s); recovery "
          f"{rec['max_recovery_s']:.3f}s over {rec['recoveries']} "
          f"recoveries")
    if not gate["passed"]:
        raise SystemExit(
            f"serve gate FAILED: speedup {tput['speedup']:.2f} < "
            f"{args.min_speedup} or recovery "
            f"{rec['max_recovery_s']:.3f}s > {args.max_recovery_s}s")
    print("[serve_bench] gate passed")


if __name__ == "__main__":
    main()
