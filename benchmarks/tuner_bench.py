"""CI gate for the autotuner: tuned knobs must not lose to the static
heuristics.

Runs the bounded ``ci`` preset sweep (``repro.tuning.preset_specs``)
with the same interleaved min-of-rounds timing the real tuner uses,
then gates on

* parity: every candidate in every sweep must match the heuristic
  engine bit-exactly (integer CA) / within tolerance (float PDE) — a
  parity failure anywhere fails the gate regardless of speed;
* geomean speedup of tuned-best vs the static heuristic across the
  preset, measured on the SAME timing matrix: must be >= the
  ``--min-speedup`` threshold (1.0 in CI — the heuristic baseline is
  itself in the candidate space, so a healthy tuner can never lose;
  < 1.0 means the sweep or the timer is broken).

Writes ``BENCH_tuner.json``:

    {"records": [{key, best, baseline, speedup, times,
                  parity_failures, roofline_s, suspect} ...],
     "gate": {geomean_speedup, parity_ok, suspects, min_speedup,
              passed}}

Run via ``python benchmarks/ci_gates.py --gate tuner`` (CI) or
directly: ``PYTHONPATH=src python benchmarks/tuner_bench.py``.
"""
from __future__ import annotations

import argparse
import json

from repro.tuning import geomean, preset_specs, tune_spec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=["ci", "default"])
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-candidates", type=int, default=8)
    ap.add_argument("--min-speedup", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_tuner.json")
    args = ap.parse_args()

    records = []
    speedups = []
    parity_ok = True
    for spec in preset_specs(args.preset):
        res = tune_spec(spec, steps=args.steps, rounds=args.rounds,
                        seed=args.seed,
                        max_candidates=args.max_candidates)
        parity_ok &= not res.parity_failures
        speedups.append(res.speedup)
        records.append({
            "key": res.spec.tuning_key(),
            "best": res.best.label,
            "baseline": res.baseline.label,
            "speedup": res.speedup,
            "times": res.times,
            "parity_failures": res.parity_failures,
            "roofline_s": res.roofline_s,
            "suspect": res.suspect,
        })
        print(f"tuner,{res.spec.tuning_key()},best={res.best.label},"
              f"baseline={res.baseline.label},"
              f"speedup={res.speedup:.3f}", flush=True)

    gm = geomean(speedups)
    gate = {
        "geomean_speedup": gm,
        "parity_ok": parity_ok,
        "suspects": sum(1 for r in records if r["suspect"]),
        "min_speedup": args.min_speedup,
        "passed": parity_ok and gm >= args.min_speedup,
    }
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump({"records": records, "gate": gate}, fh, indent=2)
    print(f"tuner gate: geomean={gm:.3f}x (min {args.min_speedup}), "
          f"parity_ok={parity_ok} -> "
          f"{'PASS' if gate['passed'] else 'FAIL'}", flush=True)
    return 0 if gate["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
