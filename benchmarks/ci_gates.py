"""One entry point for the CI perf-gate matrix.

The workflow used to carry four copy-pasted inline bench invocations
(workloads smoke, fusion, mxu, distributed) whose thresholds, output
paths and env quirks lived in YAML. This script owns all of that:

    PYTHONPATH=src python benchmarks/ci_gates.py --gate <name>
    PYTHONPATH=src python benchmarks/ci_gates.py --list

one gate per CI matrix entry ({workloads, fusion, mxu, distributed,
3d, telemetry}). Each gate shells out to its bench script in a fresh
interpreter — deliberately: the distributed gate must set XLA_FLAGS
before jax is imported (it forces the 8-device host-platform mesh),
and a subprocess keeps every gate's device/backend state isolated from
this process and from the other gates. The bench scripts keep their
own parity assertions; the *thresholds* and JSON artifact paths are
pinned here so the workflow matrix calls this with one flag and
nothing else.

Every gate run also writes ``gate_report_<name>.json`` next to the
bench JSON — a structured verdict for the artifact upload: the
threshold, the measured numbers re-derived from the bench JSON (so the
report is self-contained even if the raw JSON rots), parity status,
exit status, and a telemetry snapshot of the gate subprocess (the
subprocess runs with ``SQUEEZE_TELEMETRY=1`` and dumps its registry
via ``SQUEEZE_TELEMETRY_DUMP`` at exit — kernel entry counts, fused
launches, cache hits, collective counts land in the CI artifact for
free). The report is written even when the bench fails, before the
exit status propagates.

Exit status is the bench's: nonzero on parity breakage or a speedup
below the gate threshold. The JSON is written before the gate check,
so a failing run still leaves its timings behind for the artifact
upload (`if: always()`).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import subprocess
import sys

BENCH_DIR = pathlib.Path(__file__).resolve().parent


# --------------------------------------------------- per-gate summarizers
# Each takes the gate's parsed bench JSON and returns the measured
# numbers the gate decided on — mirroring (not re-running) the bench's
# own gate arithmetic so the report is honest about what was compared.
def _summ_workloads(data):
    mc = [r["mcells_per_s"] for r in data["records"]]
    return {"records": len(mc),
            "max_mcells_per_s": max(mc), "min_mcells_per_s": min(mc)}


def _summ_fusion(data):
    records = data["records"]
    best = 0.0
    for rec in records:
        if rec["k"] == 1:
            continue
        base = next(b for b in records
                    if b["k"] == 1 and b["engine"] == rec["engine"]
                    and b["workload"] == rec["workload"])
        best = max(best, base["us_per_step"] / rec["us_per_step"])
    return {"records": len(records), "best_fused_speedup": best}


def _summ_mxu(data):
    records = data["records"]
    gated = []
    for rec in records:
        if rec["engine"] != "pallas-mxu":
            continue
        base = next(b for b in records
                    if b["engine"] == "pallas-strips"
                    and b["workload"] == rec["workload"]
                    and b["m"] == rec["m"] and b["batch"] == rec["batch"])
        if rec["rho"] <= 9 and rec["batch"] >= 8:
            gated.append(rec["mcells_per_s"] / base["mcells_per_s"])
    geomean = (math.exp(sum(map(math.log, gated)) / len(gated))
               if gated else None)
    return {"records": len(records), "gated_configs": len(gated),
            "geomean_batched_speedup": geomean}


def _summ_distributed(data):
    return dict(data["gate"])


def _summ_3d(data):
    records = data["records"]
    best = {}  # (fractal, workload, r, m) -> best fused speedup
    for rec in records:
        if rec["engine"] != "block3d" or rec["k"] < 2:
            continue
        base = next(b for b in records
                    if b["engine"] == "cell3d"
                    and b["fractal"] == rec["fractal"]
                    and b["workload"] == rec["workload"]
                    and b["r"] == rec["r"] and b["m"] == rec["m"])
        key = (rec["fractal"], rec["workload"], rec["r"], rec["m"])
        x = base["us_per_step"] / rec["us_per_step"]
        best[key] = max(best.get(key, 0.0), x)
    xs = list(best.values())
    geomean = (math.exp(sum(map(math.log, xs)) / len(xs))
               if xs else None)
    return {"records": len(records), "configs": len(xs),
            "geomean_best_fused_speedup": geomean}


def _summ_telemetry(data):
    return dict(data["gate"])


def _summ_serve(data):
    return dict(data["gate"])


def _summ_chaos_dist(data):
    return dict(data["gate"])


def _summ_dist_scaling(data):
    return dict(data["gate"])


def _summ_tuner(data):
    return dict(data["gate"])


#: gate name -> spec. Thresholds and output paths live HERE, not in the
#: workflow and not in bench defaults. ``threshold`` is the number the
#: bench gate compares against (None: correctness/parity-only gate);
#: ``summarize`` re-derives the measured side from the bench JSON for
#: the gate report.
GATES = {
    # every (workload, engine, batch) combination runs end to end
    "workloads": dict(
        script="workloads_bench.py",
        args=["--smoke", "--no-fusion", "--out", "BENCH_workloads.json"],
        env={}, out="BENCH_workloads.json", threshold=None,
        summarize=_summ_workloads),
    # fused k>=2 stepping must beat single stepping somewhere (parity
    # asserted per configuration first)
    "fusion": dict(
        script="workloads_bench.py",
        args=["--smoke", "--fusion-only", "--min-speedup", "1.0",
              "--fusion-out", "BENCH_fusion.json"],
        env={}, out="BENCH_fusion.json", threshold=1.0,
        summarize=_summ_fusion),
    # v5 stencil-as-matmul vs pallas-strips at a block count large
    # enough to exercise the macro-tile grid: geomean batched speedup
    # at rho <= 9 must reach 1.5x (bit-exact CA / 1e-5 PDE parity)
    "mxu": dict(
        script="workloads_bench.py",
        args=["--mxu-only", "--r", "7", "--mxu-ms", "2",
              "--mxu-batches", "8", "--min-speedup", "1.5",
              "--mxu-out", "BENCH_mxu.json"],
        env={}, out="BENCH_mxu.json", threshold=1.5,
        summarize=_summ_mxu),
    # k-fused strip halo exchange vs every-step exchange on the 8-device
    # host-platform CPU mesh; geomean best fused per-step speedup on the
    # largest mesh must reach 1.5x. XLA_FLAGS is set by the bench itself
    # before importing jax — which is exactly why it needs its own
    # interpreter.
    "distributed": dict(
        script="distributed_bench.py",
        args=["--gate", "1.5", "--out", "BENCH_distributed.json"],
        env={}, out="BENCH_distributed.json", threshold=1.5,
        summarize=_summ_distributed),
    # 3D stack: block3d fused k-stepping vs the cell3d per-cell engine
    # across r x rho x k (parity per configuration); geomean best fused
    # speedup must reach 1.5x
    "3d": dict(
        script="stencil3d_bench.py",
        args=["--smoke", "--min-speedup", "1.5", "--out",
              "BENCH_3d.json"],
        env={}, out="BENCH_3d.json", threshold=1.5,
        summarize=_summ_3d),
    # the instrumented-but-disabled BatchedRunner hot path must stay
    # within 2% of the pre-instrumentation fast path (threshold is a
    # max overhead %, not a min speedup). The bench toggles telemetry
    # itself, so no SQUEEZE_TELEMETRY in env (it would be ignored —
    # but don't imply otherwise).
    "telemetry": dict(
        script="workloads_bench.py",
        args=["--telemetry", "--max-overhead-pct", "2.0",
              "--telemetry-out", "BENCH_telemetry.json"],
        env={}, out="BENCH_telemetry.json", threshold=2.0,
        summarize=_summ_telemetry, no_telemetry_env=True),
    # continuous batching vs per-request serving (same service
    # machinery, max_batch=1) — batched must not lose; plus the chaos
    # phase: injected crash + corrupt checkpoint must recover bit-exact
    # within the wall-time bound. The bench drives its own
    # enabled_scope registry, so no SQUEEZE_TELEMETRY needed (the dump
    # env is still honored for the artifact snapshot).
    "serve": dict(
        script="serve_bench.py",
        args=["--min-speedup", "1.0", "--max-recovery-s", "10.0",
              "--out", "BENCH_serve.json"],
        env={}, out="BENCH_serve.json", threshold=1.0,
        summarize=_summ_serve),
    # the distributed chaos matrix on the 8-device CPU mesh: every
    # shard-level fault class (exception, stalled launch, device loss
    # + elastic 8->4 reshard, corrupted halo band, damaged sharded
    # checkpoint) must recover BIT-EXACT vs an uninterrupted run, and
    # no recovery may take longer than the bound (threshold is a max
    # recovery time in seconds, not a min speedup). XLA_FLAGS is set
    # by the bench itself before importing jax — its own interpreter,
    # like the distributed gate.
    "chaos-dist": dict(
        script="chaos_dist_bench.py",
        args=["--max-recovery-s", "20.0",
              "--out", "BENCH_chaos_dist.json"],
        env={}, out="BENCH_chaos_dist.json", threshold=20.0,
        summarize=_summ_chaos_dist),
    # neighbor-only ppermute exchange vs the all-gather baseline across
    # the device-count curve (nd = 1, 2, 4, 8) in the exchange-bound
    # fine-block regime: p2p per-device exchanged bytes/step must stay
    # flat in the device count while the gather curve grows, and p2p
    # must not lose to gather on the full mesh (threshold is the max
    # allowed p2p/gather per-step time ratio). Parity against the
    # single-device engine is asserted per cell before any timing.
    # XLA_FLAGS is set by the bench itself — own interpreter, like the
    # distributed gate.
    "dist-scaling": dict(
        script="distributed_bench.py",
        args=["--scaling", "--max-slowdown", "1.05",
              "--out", "BENCH_dist_scaling.json"],
        env={}, out="BENCH_dist_scaling.json", threshold=1.05,
        summarize=_summ_dist_scaling),
    # the autotuner's bounded ci-preset sweep: every candidate parity-
    # gated against the heuristic engine, tuned-best geomean speedup
    # vs the static heuristic (same interleaved timing matrix) must
    # reach 1.0x — the baseline is in the candidate space, so below
    # 1.0 means the sweep or the timer is broken, not "slow hardware".
    # SQUEEZE_TUNING=off pins the baseline to the true heuristic (the
    # shipped table must not leak into the thing it is compared to).
    "tuner": dict(
        script="tuner_bench.py",
        args=["--preset", "ci", "--min-speedup", "1.0",
              "--out", "BENCH_tuner.json"],
        env={"SQUEEZE_TUNING": "off"}, out="BENCH_tuner.json",
        threshold=1.0, summarize=_summ_tuner),
}


def run_gate(name: str) -> int:
    gate = GATES[name]
    env = dict(os.environ, **gate["env"])
    # the benches import repro; make a bare `python benchmarks/ci_gates
    # .py` work outside CI too
    root = str(BENCH_DIR.parent / "src")
    env["PYTHONPATH"] = (root + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else root)
    # capture the gate subprocess's registry in the artifact: enable
    # collection (except for the overhead gate, which drives the toggle
    # itself) and dump the snapshot at interpreter exit
    dump = f"telemetry_{name}.jsonl"
    env["SQUEEZE_TELEMETRY_DUMP"] = dump
    if not gate.get("no_telemetry_env"):
        env["SQUEEZE_TELEMETRY"] = "1"
    cmd = [sys.executable, str(BENCH_DIR / gate["script"]), *gate["args"]]
    print(f"[ci_gates] {name}: {' '.join(cmd)}", flush=True)
    rc = subprocess.call(cmd, env=env)
    write_report(name, gate, cmd, rc, dump)
    return rc


def write_report(name: str, gate: dict, cmd, rc: int, dump: str) -> None:
    """gate_report_<name>.json — always written, even on a failed bench
    (the artifact upload runs `if: always()`)."""
    report = {
        "gate": name,
        "command": cmd,
        "exit_status": rc,
        "passed": rc == 0,
        "threshold": gate["threshold"],
        "bench_json": gate["out"],
        # the benches assert parity BEFORE writing their JSON, so a
        # parseable bench JSON means every parity check passed; no JSON
        # means the bench died before or during the sweep
        "parity": "unknown",
        "measured": None,
        "telemetry": None,
    }
    try:
        data = json.loads(pathlib.Path(gate["out"]).read_text())
        report["parity"] = "ok"
        report["measured"] = gate["summarize"](data)
    except FileNotFoundError:
        report["parity"] = "no-bench-json"
    except Exception as e:  # summarizer bug must not mask the bench rc
        report["parity"] = f"report-error: {e}"
    try:
        lines = pathlib.Path(dump).read_text().splitlines()
        # metrics only: span lines can number one per runner.run call
        # and belong in the raw dump, not a readable report
        report["telemetry"] = [
            m for m in (json.loads(x) for x in lines if x.strip())
            if m.get("type") in ("counter", "gauge", "histogram")]
    except FileNotFoundError:
        pass
    path = pathlib.Path(f"gate_report_{name}.json")
    path.write_text(json.dumps(report, indent=2))
    print(f"[ci_gates] wrote {path} (passed={report['passed']})",
          flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", choices=sorted(GATES),
                    help="which perf gate to run")
    ap.add_argument("--list", action="store_true",
                    help="print the gate names (the CI matrix) and exit")
    args = ap.parse_args()
    if args.list:
        print("\n".join(sorted(GATES)))
        return
    if not args.gate:
        ap.error("--gate is required (or --list)")
    raise SystemExit(run_gate(args.gate))


if __name__ == "__main__":
    main()
