"""One entry point for the CI perf-gate matrix.

The workflow used to carry four copy-pasted inline bench invocations
(workloads smoke, fusion, mxu, distributed) whose thresholds, output
paths and env quirks lived in YAML. This script owns all of that:

    PYTHONPATH=src python benchmarks/ci_gates.py --gate <name>
    PYTHONPATH=src python benchmarks/ci_gates.py --list

one gate per CI matrix entry ({workloads, fusion, mxu, distributed,
3d}). Each gate shells out to its bench script in a fresh interpreter —
deliberately: the distributed gate must set XLA_FLAGS before jax is
imported (it forces the 8-device host-platform mesh), and a subprocess
keeps every gate's device/backend state isolated from this process and
from the other gates. The bench scripts keep their own parity
assertions; the *thresholds* and JSON artifact paths are pinned here so
the workflow matrix calls this with one flag and nothing else.

Exit status is the bench's: nonzero on parity breakage or a speedup
below the gate threshold. The JSON is written before the gate check,
so a failing run still leaves its timings behind for the artifact
upload (`if: always()`).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys

BENCH_DIR = pathlib.Path(__file__).resolve().parent

#: gate name -> (bench script, args, extra env). Thresholds and output
#: paths live HERE, not in the workflow and not in bench defaults.
GATES = {
    # every (workload, engine, batch) combination runs end to end
    "workloads": ("workloads_bench.py",
                  ["--smoke", "--no-fusion", "--out",
                   "BENCH_workloads.json"], {}),
    # fused k>=2 stepping must beat single stepping somewhere (parity
    # asserted per configuration first)
    "fusion": ("workloads_bench.py",
               ["--smoke", "--fusion-only", "--min-speedup", "1.0",
                "--fusion-out", "BENCH_fusion.json"], {}),
    # v5 stencil-as-matmul vs pallas-strips at a block count large
    # enough to exercise the macro-tile grid: geomean batched speedup
    # at rho <= 9 must reach 1.5x (bit-exact CA / 1e-5 PDE parity)
    "mxu": ("workloads_bench.py",
            ["--mxu-only", "--r", "7", "--mxu-ms", "2", "--mxu-batches",
             "8", "--min-speedup", "1.5", "--mxu-out",
             "BENCH_mxu.json"], {}),
    # k-fused strip halo exchange vs every-step exchange on the 8-device
    # host-platform CPU mesh; geomean best fused per-step speedup on the
    # largest mesh must reach 1.5x. XLA_FLAGS is set by the bench itself
    # before importing jax — which is exactly why it needs its own
    # interpreter.
    "distributed": ("distributed_bench.py",
                    ["--gate", "1.5", "--out",
                     "BENCH_distributed.json"], {}),
    # 3D stack: block3d fused k-stepping vs the cell3d per-cell engine
    # across r x rho x k (parity per configuration); geomean best fused
    # speedup must reach 1.5x
    "3d": ("stencil3d_bench.py",
           ["--smoke", "--min-speedup", "1.5", "--out",
            "BENCH_3d.json"], {}),
}


def run_gate(name: str) -> int:
    script, args, extra_env = GATES[name]
    env = dict(os.environ, **extra_env)
    # the benches import repro; make a bare `python benchmarks/ci_gates
    # .py` work outside CI too
    root = str(BENCH_DIR.parent / "src")
    env["PYTHONPATH"] = (root + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else root)
    cmd = [sys.executable, str(BENCH_DIR / script), *args]
    print(f"[ci_gates] {name}: {' '.join(cmd)}", flush=True)
    return subprocess.call(cmd, env=env)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", choices=sorted(GATES),
                    help="which perf gate to run")
    ap.add_argument("--list", action="store_true",
                    help="print the gate names (the CI matrix) and exit")
    args = ap.parse_args()
    if args.list:
        print("\n".join(sorted(GATES)))
        return
    if not args.gate:
        ap.error("--gate is required (or --list)")
    raise SystemExit(run_gate(args.gate))


if __name__ == "__main__":
    main()
