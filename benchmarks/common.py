"""Shared benchmark utilities: wall-clock timing of jitted callables and
CSV emission (one row: name,us_per_call,derived).

The autotuner's interleaved min-of-rounds timer and the gates' geomean
live in ``repro.tuning.measure`` (the tuner must not depend on the
benchmarks directory); they are re-exported here so every bench scores
candidates with the same clock the tuner used.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.tuning.measure import geomean, time_interleaved  # noqa: F401


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            min_time_s: float = 0.2) -> float:
    """Median-of-reps wall time per call, in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    reps = []
    total = 0.0
    n = iters
    while total < min_time_s and n > 0:
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        reps.append(dt)
        total += dt
        n -= 1
    reps.sort()
    return reps[len(reps) // 2] * 1e6


def emit(name: str, us_per_call: Optional[float], derived: str = ""):
    us = f"{us_per_call:.2f}" if us_per_call is not None else ""
    print(f"{name},{us},{derived}", flush=True)
