"""Roofline analysis (EXPERIMENTS.md §Roofline): merge the compiled
dry-run results (memory fit + collective inventory; dryrun_results.jsonl)
with the analytic three-term model (utils/analytic.py), which is
authoritative for FLOPs/bytes because XLA's cost_analysis counts loop
bodies once (verified; see utils/analytic.py docstring).

Prints one CSV row per (arch x shape x mesh) with the three terms, the
dominant bottleneck, MODEL_FLOPS, the useful-flops ratio, and the
MFU bound implied by the dominant term.
"""
from __future__ import annotations

import json
import os

from repro import configs
from repro.utils import analytic
from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.jsonl")


def load_dryrun(path=RESULTS):
    rows = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                rows[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return rows


def run(mesh_name: str = "16x16"):
    compiled = load_dryrun()
    mesh = (analytic.MeshModel(pod=2) if mesh_name == "2x16x16"
            else analytic.MeshModel())
    for arch, shape, _ in configs.cells():
        cfg = configs.get_config(arch)
        roof = analytic.analytic_roofline(cfg, shape, mesh)
        c = compiled.get((arch, shape, mesh_name), {})
        fit = c.get("per_device_bytes")
        fit_s = f"{fit / 2 ** 30:.1f}GiB" if fit else "n/a"
        ok = c.get("ok", False)
        emit(
            f"roofline/{mesh_name}/{arch}/{shape}", None,
            f"t_comp={roof.t_compute:.4f}s;t_mem={roof.t_memory:.4f}s;"
            f"t_coll={roof.t_collective:.4f}s;bound={roof.bottleneck};"
            f"model_flops={roof.model_flops:.3e};"
            f"useful_ratio={roof.useful_flops_ratio:.2f};"
            f"mfu_bound={roof.mfu_bound:.3f};compiled_ok={ok};"
            f"per_dev={fit_s}")


def validate_analytic_vs_compiled():
    """Spot-check: for a no-layer-scan model variant the compiled flops
    should track the analytic forward flops (run by tests)."""
    import dataclasses
    import jax
    from repro.models import model as model_lib

    cfg = configs.get_smoke_config("tinyllama-1.1b")
    cfg = dataclasses.replace(cfg, n_units=1, remat="none")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    import jax.numpy as jnp
    batch = {"tokens": jnp.zeros((2, 128), jnp.int32),
             "labels": jnp.zeros((2, 128), jnp.int32)}

    def fwd(p, b):
        return model_lib.forward(p, b, cfg)[0]

    comp = jax.jit(fwd).lower(params, batch).compile()
    flops = (comp.cost_analysis() or {}).get("flops", 0.0)
    # analytic fwd matmul flops for this tiny config
    n = cfg.param_count()
    tokens = 2 * 128
    approx = 2.0 * n * tokens
    ratio = flops / approx
    emit("roofline/validate/no-scan-fwd", None,
         f"hlo={flops:.3e};analytic2ND={approx:.3e};ratio={ratio:.2f}")
    return ratio


if __name__ == "__main__":
    run("16x16")
    run("2x16x16")
    validate_analytic_vs_compiled()
