"""Paper Table 2: memory needed + memory-reduction-factor per approach on
the Sierpinski triangle at r=16, across block sizes rho. Analytic bytes
(1 byte/cell), cross-checked against allocated array sizes at a small r
(both formulas are exact, so the small-r measurement certifies the
r=16 analytic row). Paper: 99.8x / 74.8 / 56.1 / 42.1 / 31.6 / 23.7."""
import numpy as np

from repro.core import fractals
from repro.core.baselines import BBEngine
from repro.core.compact import BlockLayout
from repro.core.stencil import SqueezeBlockEngine, SqueezeCellEngine
from benchmarks.common import emit

PAPER_R16 = {1: 99.8, 2: 74.8, 4: 56.1, 8: 42.1, 16: 31.6, 32: 23.7}


def run():
    frac = fractals.SIERPINSKI
    r = 16
    bb = BBEngine(frac, r).memory_bytes()
    emit("table2/bb/r=16", None, f"bytes={bb};gb={bb / 2 ** 30:.2f}")
    for m, rho in ((0, 1), (1, 2), (2, 4), (3, 8), (4, 16), (5, 32)):
        # analytic bytes (BlockLayout.memory_bytes is O(1); engines would
        # materialize 3^16-block neighbor tables)
        mem = (BlockLayout(frac, r, m).memory_bytes() if m
               else frac.volume(r))
        mrf = bb / mem
        paper = PAPER_R16[rho]
        emit(f"table2/squeeze/rho={rho}", None,
             f"bytes={mem};mrf={mrf:.1f};paper={paper};"
             f"match={abs(mrf - paper) / paper < 0.02}")

    # measured cross-check at r=8: allocated nbytes equals the formula
    r_small = 8
    for m in (0, 2):
        eng = SqueezeBlockEngine(BlockLayout(frac, r_small, m)) if m else \
            SqueezeCellEngine(frac, r_small)
        state = eng.init_random(seed=0)
        assert int(np.asarray(state).nbytes) == eng.memory_bytes()
    emit("table2/crosscheck/r=8", None, "allocated==formula")

    # the r=20 capability claim: Squeeze fits where BB needs 4 TB
    r20 = 20
    bb20 = BBEngine(frac, r20).memory_bytes()
    sq20 = BlockLayout(frac, r20, 4).memory_bytes()
    emit("table2/r=20", None,
         f"bb_tb={bb20 / 2 ** 40:.2f};squeeze_gb={sq20 / 2 ** 30:.2f};"
         f"mrf={bb20 / sq20:.0f}")


if __name__ == "__main__":
    run()
