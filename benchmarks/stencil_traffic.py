"""§Perf (paper cell): per-step HBM traffic of the three block-level
Squeeze stencil kernels, analytic (bytes/block, uint8 cells) plus a
CPU-XLA proxy measurement of the two halo-assembly strategies (full
neighbor-block gather vs strip gather) via cost_analysis bytes.

v1 (blocks): center + 8 full neighbor blocks into VMEM     ~ 10 rho^2
v2 (strips): XLA strip gather to a (nb,4,rho+2) halo tensor,
             kernel reads center+halo                      ~ 2 rho^2 + 12 rho
v3 (fused):  strip arrays read in-kernel via scalar-prefetch
             index maps; halo tensor never materialised    ~ 2 rho^2 + 8 rho
"""
import jax
import jax.numpy as jnp

from repro.core import fractals
from repro.core.compact import BlockLayout
from repro.core.stencil import SqueezeBlockEngine
from repro.kernels import squeeze_stencil as sk
from benchmarks.common import emit, time_fn


def analytic_bytes_per_block(rho: int) -> dict:
    return {
        "v1_blocks": 9 * rho * rho + rho * rho,
        "v2_strips": (rho * rho + 4 * (rho + 2)      # kernel reads
                      + rho * rho                     # kernel write
                      + 2 * 4 * (rho + 2)),           # halo build r/w
        "v3_fused": (rho * rho + 4 * rho + 4          # kernel reads
                     + rho * rho                      # kernel write
                     + 2 * 4 * rho),                  # strip array build
    }


def run():
    for rho in (4, 8, 16, 32):
        a = analytic_bytes_per_block(rho)
        base = a["v1_blocks"]
        emit(f"stencil_traffic/analytic/rho={rho}", None,
             ";".join(f"{k}={v}B({base / v:.2f}x)" for k, v in a.items()))

    # CPU-XLA proxy: halo assembly traffic, full-block vs strip gather
    frac = fractals.SIERPINSKI
    layout = BlockLayout(frac, 9, 2).materialize()   # 2187 blocks, rho=4
    eng = SqueezeBlockEngine(layout)
    state = eng.init_random(seed=0)
    table = jnp.asarray(layout.neighbor_table)

    @jax.jit
    def gather_full_blocks(s):
        padded = jnp.concatenate(
            [s, jnp.zeros((1,) + s.shape[1:], s.dtype)], 0)
        return jnp.stack([jnp.take(padded, table[:, d], axis=0)
                          for d in range(8)], 1)

    @jax.jit
    def gather_strips(s):
        return sk.gather_halo_strips(layout, s)

    t_full = time_fn(gather_full_blocks, state)
    t_strip = time_fn(gather_strips, state)
    from repro.utils.jax_compat import cost_analysis_dict
    ca_full = cost_analysis_dict(
        jax.jit(gather_full_blocks).lower(state).compile())
    ca_strip = cost_analysis_dict(
        jax.jit(gather_strips).lower(state).compile())
    b_full = ca_full.get("bytes accessed", 0.0)
    b_strip = ca_strip.get("bytes accessed", 0.0)
    emit("stencil_traffic/halo_assembly/full_blocks", t_full,
         f"bytes={b_full:.3e}")
    emit("stencil_traffic/halo_assembly/strips", t_strip,
         f"bytes={b_strip:.3e};traffic_win={b_full / max(b_strip, 1):.2f}x;"
         f"wall_win={t_full / t_strip:.2f}x")


if __name__ == "__main__":
    run()
