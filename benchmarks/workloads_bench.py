"""Per-workload step timing on the Sierpinski triangle: one step of each
workload (life, totalistic highlife, heat, Gray-Scott) on the cell, block,
and Pallas-strips engines, the batched-runner throughput at batch 8, and
the temporal-fusion k sweep (fused k-step launches vs single stepping on
the block engines, with a parity assertion).

    PYTHONPATH=src python benchmarks/workloads_bench.py [--r 9] [--m 2]
                                                        [--smoke]
                                                        [--fusion-only]

Writes BENCH_workloads.json (one record per (workload, engine)) and
BENCH_fusion.json (one record per (engine, workload, k): us_per_step and
mcells_per_s, amortized over the fused launch), and prints the
common.emit CSV rows. ``--smoke`` shrinks the level so the script doubles
as a CI check that every (workload, engine, k) combination runs end to
end; the fusion sweep *fails* (nonzero exit) if fused-k stepping diverges
from k single steps. ``--fusion-only`` skips the workload section (the CI
perf-smoke step).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.stencil import make_engine  # noqa: E402
from repro.workloads import (GRAY_SCOTT, HEAT, HIGHLIFE, LIFE,  # noqa: E402
                             BatchedRunner)
from benchmarks.common import emit, time_fn  # noqa: E402

ENGINES = ("cell", "block", "pallas-strips")
WORKLOADS = (LIFE, HIGHLIFE, HEAT, GRAY_SCOTT)

FUSION_ENGINES = ("block", "pallas-strips")
FUSION_WORKLOADS = (LIFE, HEAT, GRAY_SCOTT)
FUSION_KS = (1, 2, 3)


def bench_one(kind: str, frac, r: int, m: int, wl, iters: int) -> dict:
    eng = make_engine(kind, frac, r, m, workload=wl)
    state = eng.init_random(seed=0)
    us = time_fn(eng.step, state, iters=iters)
    cells = frac.volume(r)
    rec = {
        "workload": wl.name, "engine": kind, "fractal": frac.name,
        "r": r, "m": m, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "state_bytes": eng.memory_bytes(
            dtype_size=jax.numpy.dtype(wl.dtype).itemsize),
    }
    emit(f"workloads/{wl.name}/{kind}", us,
         f"r={r};m={m};mcups={rec['mcells_per_s']:.1f}")
    return rec


def bench_batched(frac, r: int, m: int, wl, iters: int, batch: int) -> dict:
    runner = BatchedRunner()
    states = runner.init_batch("cell", frac, r, seeds=range(batch),
                               workload=wl)
    us = time_fn(lambda s: runner.step("cell", frac, r, s, workload=wl),
                 states, iters=iters)
    cells = frac.volume(r) * batch
    rec = {
        "workload": wl.name, "engine": f"runner-cell-b{batch}",
        "fractal": frac.name, "r": r, "m": m, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "builds": runner.stats.builds, "traces": runner.stats.traces,
    }
    emit(f"workloads/{wl.name}/runner-b{batch}", us,
         f"r={r};mcups={rec['mcells_per_s']:.1f}")
    return rec


def _tol(wl):
    return dict(rtol=0, atol=0) if wl is LIFE or wl is HIGHLIFE \
        else dict(rtol=1e-5, atol=1e-5)


def bench_fusion_one(kind: str, frac, r: int, m: int, wl, k: int,
                     iters: int) -> dict:
    """Amortized per-step cost of k-fused stepping: one timed call is one
    ``step_k`` launch (k=1: one ``step``), us_per_step = launch / k.
    Fused-vs-single parity is asserted before timing — the bench doubles
    as the CI fused-k correctness smoke."""
    eng = make_engine(kind, frac, r, m, workload=wl, fusion_k=k)
    state = eng.init_random(seed=0)
    if k > 1:
        want = state
        for _ in range(k):
            want = eng.step(want)
        got = eng.step_k(state, k)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), **_tol(wl),
            err_msg=f"fused-k parity broke: {kind}/{wl.name}/k={k}")
        us = time_fn(lambda s: eng.step_k(s, k), state, iters=iters) / k
    else:
        us = time_fn(eng.step, state, iters=iters)
    cells = frac.volume(r)
    rec = {
        "workload": wl.name, "engine": kind, "fractal": frac.name,
        "r": r, "m": m, "k": k, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
    }
    emit(f"fusion/{wl.name}/{kind}/k{k}", us,
         f"r={r};m={m};mcups={rec['mcells_per_s']:.1f}")
    return rec


def bench_fusion(frac, r: int, m: int, iters: int, out_path: str,
                 min_speedup: float = 1.0) -> None:
    # the speedup gate below compares wall-clock medians, so never drop
    # below 10 reps even in --smoke mode (2 reps flake on loaded runners)
    iters = max(iters, 10)
    rho = frac.s ** m
    records = []
    for kind in FUSION_ENGINES:
        for wl in FUSION_WORKLOADS:
            for k in FUSION_KS:
                if k > rho and kind.startswith("pallas"):
                    emit(f"fusion/{wl.name}/{kind}/k{k}", None,
                         f"skipped:k>rho={rho}")
                    continue  # v4 kernel is one-block-ring only
                records.append(
                    bench_fusion_one(kind, frac, r, m, wl, k, iters))
    # the point of temporal fusion: at least one fused configuration must
    # beat single stepping per step (fail loudly if the hot path regressed)
    speedups = []
    for rec in records:
        if rec["k"] == 1:
            continue
        base = next(b for b in records
                    if b["k"] == 1 and b["engine"] == rec["engine"]
                    and b["workload"] == rec["workload"])
        speedups.append((rec["us_per_step"] < base["us_per_step"],
                         rec["engine"], rec["workload"], rec["k"],
                         base["us_per_step"] / rec["us_per_step"]))
    out = pathlib.Path(out_path)
    out.write_text(json.dumps({
        "fractal": frac.name, "r": r, "m": m,
        "backend": jax.default_backend(), "records": records}, indent=2))
    print(f"wrote {out} ({len(records)} records)")
    # JSON is written first so a regression still leaves the timings behind
    best = max((x for *_, x in speedups), default=0.0)
    if not any(s[0] for s in speedups) or best < min_speedup:
        raise SystemExit(
            f"fused k>=2 stepping beats k=1 nowhere by >= "
            f"{min_speedup:.2f}x (best {best:.2f}x): "
            + "; ".join(f"{e}/{w}/k={k}: {x:.2f}x"
                        for _, e, w, k, x in speedups))


# ---------------------------------------------------------------- v5 MXU
MXU_WORKLOADS = (LIFE, HEAT, GRAY_SCOTT)


def bench_mxu_one(runner, kind, frac, r, m, wl, k, batch, steps, iters):
    states = runner.init_batch(kind, frac, r, seeds=range(batch), m=m,
                               workload=wl)
    us = time_fn(
        lambda s: runner.run(kind, frac, r, s, steps=steps, m=m,
                             workload=wl, k=k),
        states, iters=iters) / steps
    cells = frac.volume(r) * batch
    rho = frac.s ** m
    rec = {
        "workload": wl.name, "engine": kind, "fractal": frac.name,
        "r": r, "m": m, "rho": rho, "k": k if k is not None else "auto",
        "batch": batch, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
    }
    emit(f"mxu/{wl.name}/{kind}/rho{rho}/b{batch}/k{rec['k']}", us,
         f"r={r};mcups={rec['mcells_per_s']:.1f}")
    return rec


def bench_mxu(frac, r, ms, iters, batches, out_path,
              min_speedup: float = 1.5) -> None:
    """v5 (pallas-mxu, stencil-as-matmul macro-tiles + native batch grid)
    vs v2/v4 (pallas-strips single-step / fused-k) across rho and batch
    size. Per configuration, step-for-step parity between the two kinds
    is asserted first (bit-exact for CA, 1e-5 for the PDE workloads);
    after writing the JSON the speedup gate *fails the process* unless
    the geometric-mean pallas-mxu speedup over pallas-strips across the
    batched (B >= 8) configurations at rho <= 9 reaches 1.5x mcells/s —
    the acceptance bar for the MXU path on the serving-shaped workloads
    (see DESIGN.md Section 2.2; individual configurations are printed so
    a single-cell regression is still visible in the CI log).
    """
    iters = max(iters, 10)
    steps = 6
    records = []
    for m in ms:
        if m > r:
            continue
        for wl in MXU_WORKLOADS:
            for batch in batches:
                runner = BatchedRunner()  # fresh cache per config: honest
                states = runner.init_batch("pallas-strips", frac, r,
                                           seeds=range(batch), m=m,
                                           workload=wl)
                want = runner.run("pallas-strips", frac, r, states,
                                  steps=steps, m=m, workload=wl)
                got = runner.run("pallas-mxu", frac, r, states,
                                 steps=steps, m=m, workload=wl)
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), **_tol(wl),
                    err_msg=f"mxu parity broke: {wl.name}/m={m}/b={batch}")
                for kind in ("pallas-strips", "pallas-mxu"):
                    records.append(bench_mxu_one(
                        runner, kind, frac, r, m, wl, None, batch, steps,
                        iters))
    out = pathlib.Path(out_path)
    out.write_text(json.dumps({
        "fractal": frac.name, "r": r, "ms": list(ms),
        "batches": list(batches), "backend": jax.default_backend(),
        "records": records}, indent=2))
    print(f"wrote {out} ({len(records)} records)")
    # JSON first, so a regression still leaves the timings behind
    speedups, gated = [], []
    for rec in records:
        if rec["engine"] != "pallas-mxu":
            continue
        base = next(b for b in records
                    if b["engine"] == "pallas-strips"
                    and b["workload"] == rec["workload"]
                    and b["m"] == rec["m"] and b["batch"] == rec["batch"])
        x = rec["mcells_per_s"] / base["mcells_per_s"]
        speedups.append((rec, x))
        if rec["rho"] <= 9 and rec["batch"] >= 8:
            gated.append(x)
    for rec, x in speedups:
        print(f"mxu speedup {rec['workload']}/rho{rec['rho']}"
              f"/b{rec['batch']}: {x:.2f}x")
    if gated:
        geomean = float(np.exp(np.mean(np.log(gated))))
        print(f"mxu gate: geomean over batched rho<=9 = {geomean:.2f}x "
              f"({len(gated)} configs)")
        if geomean < min_speedup:
            raise SystemExit(
                f"pallas-mxu geomean speedup {geomean:.2f}x < "
                f"{min_speedup}x over pallas-strips on batched rho<=9 "
                "configurations")


# ---------------------------------------------------- telemetry overhead
def bench_telemetry(frac, out_path: str, max_overhead_pct: float = 2.0,
                    rounds: int = 100, calls: int = 8) -> None:
    """Overhead of the instrumented runner hot path (the CI telemetry
    gate: benchmarks/ci_gates.py --gate telemetry).

    Three variants of the same fused batched run (block/LIFE, r=6, m=2,
    batch=4, steps=24 — a serving-shaped call where the dispatch is not
    degenerate), interleaved round-robin. The gate statistic is the
    MEDIAN OF PAIRED PER-ROUND DIFFERENCES (disabled minus direct,
    within the same round) over the median direct round: adjacent
    samples share whatever load the machine is under, so common-mode
    noise cancels where a ratio of independent mins does not (a loaded
    CI runner flips the sign of min-based ratios). The telemetry
    overhead is a fixed few machine instructions per ``run`` call, so
    the JSON records absolute us_per_run for all three variants
    alongside the relative gate:

    - ``direct``: the pre-PR fast path — exactly what
      ``BatchedRunner.run`` did before instrumentation: the LRU cache
      probe, the steps->int32 cast, and the ``batched_run`` dispatch,
      with none of the telemetry branches.
    - ``disabled``: ``BatchedRunner.run`` with telemetry off — the
      instrumented code with every obs helper short-circuiting. The
      gate: this must stay within ``max_overhead_pct`` of ``direct``.
    - ``enabled``: the same with telemetry on (informational; the
      opt-in cost of counters + histograms + spans per run).
    """
    from repro import obs

    r, m, batch, steps = 6, 2, 4, 24
    runner = BatchedRunner()
    states = runner.init_batch("block", frac, r, seeds=range(batch), m=m,
                               workload=LIFE)

    def run_runner(s):
        return runner.run("block", frac, r, s, steps=steps, m=m,
                          workload=LIFE)

    def run_direct(s):
        entry = runner._get("block", frac, r, m, LIFE, None, None, None)
        return entry.batched_run(
            s, jax.numpy.asarray(steps, jax.numpy.int32))

    prev = obs.enabled()
    variants = {
        "direct": (run_direct, False),
        "disabled": (run_runner, False),
        "enabled": (run_runner, True),
    }
    samples = {name: [] for name in variants}
    try:
        for fn, on in variants.values():  # warm every path once
            obs.enable(on)
            jax.block_until_ready(fn(states))
        for _ in range(rounds):
            for name, (fn, on) in variants.items():
                obs.enable(on)
                t0 = time.perf_counter()
                for _ in range(calls):
                    out = fn(states)
                jax.block_until_ready(out)
                samples[name].append((time.perf_counter() - t0) / calls)
    finally:
        obs.enable(prev)

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    us = {k: median(v) * 1e6 for k, v in samples.items()}
    pct = {}
    for k in ("disabled", "enabled"):
        diffs = [b - a for a, b in zip(samples["direct"], samples[k])]
        pct[k] = median(diffs) * 1e6 / us["direct"] * 100.0
    for name in variants:
        emit(f"telemetry/{name}", us[name],
             f"r={r};m={m};b={batch};steps={steps}")
    print(f"telemetry overhead: disabled {pct['disabled']:+.2f}% "
          f"enabled {pct['enabled']:+.2f}% (gate: disabled <= "
          f"{max_overhead_pct:.1f}%)")
    out = pathlib.Path(out_path)
    out.write_text(json.dumps({
        "backend": jax.default_backend(),
        "config": {"engine": "block", "workload": LIFE.name,
                   "fractal": frac.name, "r": r, "m": m, "batch": batch,
                   "steps": steps, "rounds": rounds,
                   "calls_per_sample": calls},
        "us_per_run": us,
        "gate": {"threshold_pct": max_overhead_pct,
                 "overhead_disabled_pct": pct["disabled"],
                 "overhead_enabled_pct": pct["enabled"]},
    }, indent=2))
    print(f"wrote {out}")
    # JSON first, so a regression still leaves the timings behind
    if pct["disabled"] > max_overhead_pct:
        raise SystemExit(
            f"telemetry-disabled runner overhead {pct['disabled']:.2f}% "
            f"> {max_overhead_pct:.1f}% over the direct fast path")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=9)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny level, 2 iters (CI end-to-end check)")
    ap.add_argument("--fusion-only", action="store_true",
                    help="run only the temporal-fusion k sweep")
    ap.add_argument("--no-fusion", action="store_true",
                    help="skip the temporal-fusion k sweep (CI runs it "
                         "as its own step)")
    ap.add_argument("--mxu-only", action="store_true",
                    help="run only the v5 MXU vs strips sweep + gate "
                         "(the CI MXU perf-gate step)")
    ap.add_argument("--telemetry", action="store_true",
                    help="run only the telemetry-overhead microbench + "
                         "gate (the CI telemetry perf-gate step)")
    ap.add_argument("--max-overhead-pct", type=float, default=2.0,
                    help="telemetry gate: max %% slowdown of the "
                         "instrumented-but-disabled runner hot path vs "
                         "the direct fast path")
    ap.add_argument("--telemetry-out", default="BENCH_telemetry.json")
    ap.add_argument("--mxu-ms", type=int, nargs="+", default=None,
                    help="block levels m for the MXU rho sweep "
                         "(default: {m, m+1} clipped to r)")
    ap.add_argument("--mxu-batches", type=int, nargs="+", default=(1, 8))
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="speedup gate threshold: best fused-k speedup "
                         "for the fusion sweep (default 1.0), geomean "
                         "batched mxu speedup for the mxu sweep "
                         "(default 1.5); benchmarks/ci_gates.py owns the "
                         "CI values")
    ap.add_argument("--out", default="BENCH_workloads.json")
    ap.add_argument("--fusion-out", default="BENCH_fusion.json")
    ap.add_argument("--mxu-out", default="BENCH_mxu.json")
    args = ap.parse_args()
    if args.smoke:
        args.r, args.m, args.iters = 5, 2, 2

    frac = fractals.SIERPINSKI
    if args.telemetry:
        bench_telemetry(frac, args.telemetry_out,
                        max_overhead_pct=args.max_overhead_pct)
        return
    if args.mxu_only:
        ms = args.mxu_ms or [m for m in (args.m, args.m + 1) if m <= args.r]
        bench_mxu(frac, args.r, ms, args.iters, tuple(args.mxu_batches),
                  args.mxu_out,
                  min_speedup=(1.5 if args.min_speedup is None
                               else args.min_speedup))
        return
    if not args.fusion_only:
        records = []
        for wl in WORKLOADS:
            for kind in ENGINES:
                records.append(bench_one(kind, frac, args.r, args.m, wl,
                                         args.iters))
            records.append(bench_batched(frac, args.r, args.m, wl,
                                         args.iters, args.batch))

        out = pathlib.Path(args.out)
        out.write_text(json.dumps({
            "fractal": frac.name, "r": args.r, "m": args.m,
            "backend": jax.default_backend(), "records": records},
            indent=2))
        print(f"wrote {out} ({len(records)} records)")

    if not args.no_fusion:
        bench_fusion(frac, args.r, args.m, args.iters, args.fusion_out,
                     min_speedup=(1.0 if args.min_speedup is None
                                  else args.min_speedup))


if __name__ == "__main__":
    main()
