"""Per-workload step timing on the Sierpinski triangle: one step of each
workload (life, totalistic highlife, heat, Gray-Scott) on the cell, block,
and Pallas-strips engines, plus the batched-runner throughput at batch 8.

    PYTHONPATH=src python benchmarks/workloads_bench.py [--r 9] [--m 2]
                                                        [--smoke]

Writes BENCH_workloads.json (one record per (workload, engine)) and prints
the common.emit CSV rows. ``--smoke`` shrinks the level so the script
doubles as a CI check that every (workload, engine) pair runs end to end.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.stencil import make_engine  # noqa: E402
from repro.workloads import (GRAY_SCOTT, HEAT, HIGHLIFE, LIFE,  # noqa: E402
                             BatchedRunner)
from benchmarks.common import emit, time_fn  # noqa: E402

ENGINES = ("cell", "block", "pallas-strips")
WORKLOADS = (LIFE, HIGHLIFE, HEAT, GRAY_SCOTT)


def bench_one(kind: str, frac, r: int, m: int, wl, iters: int) -> dict:
    eng = make_engine(kind, frac, r, m, workload=wl)
    state = eng.init_random(seed=0)
    us = time_fn(eng.step, state, iters=iters)
    cells = frac.volume(r)
    rec = {
        "workload": wl.name, "engine": kind, "fractal": frac.name,
        "r": r, "m": m, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "state_bytes": eng.memory_bytes(
            dtype_size=jax.numpy.dtype(wl.dtype).itemsize),
    }
    emit(f"workloads/{wl.name}/{kind}", us,
         f"r={r};m={m};mcups={rec['mcells_per_s']:.1f}")
    return rec


def bench_batched(frac, r: int, m: int, wl, iters: int, batch: int) -> dict:
    runner = BatchedRunner()
    states = runner.init_batch("cell", frac, r, seeds=range(batch),
                               workload=wl)
    us = time_fn(lambda s: runner.step("cell", frac, r, s, workload=wl),
                 states, iters=iters)
    cells = frac.volume(r) * batch
    rec = {
        "workload": wl.name, "engine": f"runner-cell-b{batch}",
        "fractal": frac.name, "r": r, "m": m, "us_per_step": us,
        "cells": cells, "mcells_per_s": cells / us,
        "builds": runner.stats.builds, "traces": runner.stats.traces,
    }
    emit(f"workloads/{wl.name}/runner-b{batch}", us,
         f"r={r};mcups={rec['mcells_per_s']:.1f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=9)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny level, 2 iters (CI end-to-end check)")
    ap.add_argument("--out", default="BENCH_workloads.json")
    args = ap.parse_args()
    if args.smoke:
        args.r, args.m, args.iters = 5, 2, 2

    frac = fractals.SIERPINSKI
    records = []
    for wl in WORKLOADS:
        for kind in ENGINES:
            records.append(bench_one(kind, frac, args.r, args.m, wl,
                                     args.iters))
        records.append(bench_batched(frac, args.r, args.m, wl, args.iters,
                                     args.batch))

    out = pathlib.Path(args.out)
    out.write_text(json.dumps({
        "fractal": frac.name, "r": args.r, "m": args.m,
        "backend": jax.default_backend(), "records": records}, indent=2))
    print(f"wrote {out} ({len(records)} records)")


if __name__ == "__main__":
    main()
