"""Paper Fig. 13: speedup of Squeeze over BB, S = T_bb / T_squeeze, per
block size. Derived from the fig12 measurements (same CPU caveat), plus
the machine-independent work-ratio bound s^2r / k^r that drives the
paper's observed growth of S with r."""
from repro.core import fractals
from benchmarks import fig12_times
from benchmarks.common import emit


def run():
    times = fig12_times.run(levels=(5, 7, 9))
    frac = fractals.SIERPINSKI
    for (r, name), us in sorted(times.items()):
        if name in ("bb",):
            continue
        s = times[(r, "bb")] / us
        bound = frac.side(r) ** 2 / frac.volume(r)
        emit(f"fig13/speedup/sierpinski/r={r}/{name}", None,
             f"S={s:.2f};work_ratio_bound={bound:.1f}")


if __name__ == "__main__":
    run()
