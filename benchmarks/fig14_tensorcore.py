"""Paper Fig. 14: the impact of tensor-core acceleration of the maps.

On this CPU container "tensor core on/off" maps to the two formulations:
  * MXU/matmul-encoded maps (nu_map_matmul / lambda_map_matmul — one dot
    per coordinate batch, the paper's MMA encoding), vs
  * the scalar per-level accumulation path (nu_map / lambda_map).
We report wall-ratio on CPU plus the op-structure facts that carry to
TPU (1 dot of (N,128)@(128,2) replaces r dependent int adds/muls).
The Pallas kernels run the same encoding in interpret mode (correctness
proof); their compiled-TPU speedup cannot be measured here.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractals, maps
from benchmarks.common import emit, time_fn


def run():
    frac = fractals.SIERPINSKI
    for r, n_coords in ((8, 1 << 14), (12, 1 << 16), (16, 1 << 18)):
        rng = np.random.default_rng(0)
        rows, cols = frac.compact_dims(r)
        cx = jnp.asarray(rng.integers(0, cols, n_coords).astype(np.int32))
        cy = jnp.asarray(rng.integers(0, rows, n_coords).astype(np.int32))
        ex, ey = maps.lambda_map(frac, r, cx, cy)

        lam_scalar = jax.jit(lambda a, b: maps.lambda_map(frac, r, a, b))
        lam_mma = jax.jit(lambda a, b: maps.lambda_map_matmul(frac, r, a, b))
        nu_scalar = jax.jit(lambda a, b: maps.nu_map(frac, r, a, b))
        nu_mma = jax.jit(lambda a, b: maps.nu_map_matmul(frac, r, a, b))

        t_ls = time_fn(lam_scalar, cx, cy)
        t_lm = time_fn(lam_mma, cx, cy)
        t_ns = time_fn(nu_scalar, ex, ey)
        t_nm = time_fn(nu_mma, ex, ey)
        emit(f"fig14/lambda/r={r}/N={n_coords}", t_lm,
             f"scalar_us={t_ls:.1f};mma_over_scalar={t_ls / t_lm:.2f}x")
        emit(f"fig14/nu/r={r}/N={n_coords}", t_nm,
             f"scalar_us={t_ns:.1f};mma_over_scalar={t_ns / t_nm:.2f}x")


if __name__ == "__main__":
    run()
