"""Paper Fig. 10: theoretical memory-reduction-factor of Squeeze vs the
expanded bounding-box, MRF(n) = s^2r / k^r, for Vicsek / Sierpinski /
Carpet. Paper's stated values at n = 2^16: ~400x, ~105x, ~3.4x."""
from repro.core import fractals
from benchmarks.common import emit

#: (fractal, n at which the paper reads the plot, paper's stated MRF)
PAPER_POINTS = [
    (fractals.VICSEK, 3 ** 10, 400.0),        # closest power of s to 2^16
    (fractals.SIERPINSKI, 2 ** 16, 105.0),
    (fractals.CARPET, 3 ** 10, 3.4),
]


def run():
    for frac, n, paper in PAPER_POINTS:
        r = frac.level_of_side(n)
        mrf = frac.mrf(r)
        ok = abs(mrf - paper) / paper < 0.25
        emit(f"fig10/mrf/{frac.name}/n={n}", None,
             f"mrf={mrf:.1f};paper~{paper};match={ok}")
    # the growth curve itself (per level), sierpinski
    f = fractals.SIERPINSKI
    for r in range(1, 21, 4):
        emit(f"fig10/curve/sierpinski/r={r}", None, f"mrf={f.mrf(r):.2f}")


if __name__ == "__main__":
    run()
