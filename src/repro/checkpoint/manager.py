"""Checkpointing: atomic, keep-last-k, elastic.

Layout:  <dir>/step_00000042/  — one ``.npy`` per leaf (path-mangled
names) + ``meta.json`` (treedef, shapes, dtypes, step). Writes go to a
``.tmp`` sibling then os.replace (atomic on POSIX), so a preemption
mid-save can never corrupt the latest complete step.

Arrays are stored *unsharded* (device_get on save); restore device_puts
against whatever sharding the (possibly different-sized) new mesh wants —
that is the elastic-rescale path: a 512-chip checkpoint restores onto 256
or 1024 chips unchanged.

With telemetry enabled (``SQUEEZE_TELEMETRY``), saves and restores
count on the default registry (``checkpoint.saves`` /
``checkpoint.restores``) with wall-time histograms
(``checkpoint.save_seconds`` — recorded by the writer, including the
async thread — and ``checkpoint.restore_seconds``) and a
``checkpoint.bytes`` gauge of the last save's payload.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from repro import obs

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        names.append("__".join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = True) -> str:
        """Atomic checkpoint of an arbitrary pytree at ``step``."""
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if blocking:
            return self._write(step, names, host_leaves)
        self.wait()
        self._async_thread = threading.Thread(
            target=self._write, args=(step, names, host_leaves), daemon=True)
        self._async_thread.start()
        return self._final_path(step)

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _final_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, names: List[str], leaves) -> str:
        t0 = time.perf_counter() if obs.enabled() else None
        final = self._final_path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta = {"step": step, "leaves": []}
        for name, arr in zip(names, leaves):
            fn = f"{len(meta['leaves']):05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            meta["leaves"].append({"name": name, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        if t0 is not None:
            obs.observe("checkpoint.save_seconds",
                        time.perf_counter() - t0)
            obs.inc("checkpoint.saves")
            obs.set_gauge("checkpoint.bytes",
                          sum(int(a.nbytes) for a in leaves))
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._final_path(s), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                put: Optional[Callable[[str, np.ndarray], Any]] = None
                ) -> Any:
        """Restore into the structure of ``like``.

        ``put(name, array)`` may device_put with a new sharding (elastic
        restore); default leaves arrays on host (jnp will ingest lazily).
        """
        t0 = time.perf_counter() if obs.enabled() else None
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self._final_path(step)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        by_name = {d["name"]: d for d in meta["leaves"]}

        names, leaves, treedef = _flatten_with_names(like)
        out = []
        for name, ref in zip(names, leaves):
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            d = by_name[name]
            arr = np.load(os.path.join(path, d["file"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {ref.shape}")
            out.append(put(name, arr) if put else arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if t0 is not None:
            obs.observe("checkpoint.restore_seconds",
                        time.perf_counter() - t0)
            obs.inc("checkpoint.restores")
        return tree
