"""Checkpointing: atomic, checksummed, keep-last-k, elastic.

Layout:  <dir>/step_00000042/  — one ``.npy`` per leaf (path-mangled
names) + ``meta.json`` (treedef, shapes, dtypes, per-leaf crc32,
step). Writes are crash-atomic: every leaf and the meta go to a
``.tmp`` sibling directory, each file is fsync'd, then one os.replace
(atomic on POSIX) publishes the step and the parent directory is
fsync'd — a preemption or power cut mid-save can never corrupt the
latest complete step, only leave an invisible ``.tmp``.

Integrity: ``meta.json`` carries a crc32 per leaf, verified on
``restore`` (set ``verify=False`` to skip). A flipped bit or truncated
file raises :class:`CheckpointCorruptError`;
``restore_with_fallback`` walks back to the newest *intact* step
instead — the serving layer's answer to disk rot under chaos
injection (counted on ``checkpoint.{corrupt,fallbacks}``).

Arrays are stored *unsharded* (device_get on save); restore device_puts
against whatever sharding the (possibly different-sized) new mesh wants —
that is the elastic-rescale path: a 512-chip checkpoint restores onto 256
or 1024 chips unchanged.

Sharded checkpoints (``save_sharded``) split every leaf into per-shard
chunks along a chosen axis — one ``.npy`` + one crc32 *per shard* per
leaf, written through the same crash-atomic ``_write``. ``meta.json``
records the split (``sharded: {leaf: {n_shards, axis}}``) and
``restore`` reassembles transparently, so a checkpoint written by an
8-shard mesh restores under a 4-shard (or 1-shard) mesh with no format
conversion — the elastic-reshard path of the distributed engine. A
single damaged shard chunk fails only its own crc, and
``restore_with_fallback`` walks to the previous intact step as usual.

With telemetry enabled (``SQUEEZE_TELEMETRY``), saves and restores
count on the default registry (``checkpoint.saves`` /
``checkpoint.restores``) with wall-time histograms
(``checkpoint.save_seconds`` — recorded by the writer, including the
async thread — and ``checkpoint.restore_seconds``) and a
``checkpoint.bytes`` gauge of the last save's payload.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

from repro import obs

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed its integrity check (bad crc32, unreadable or
    truncated leaf/meta file)."""


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        names.append("__".join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platforms without directory fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = True) -> str:
        """Atomic checkpoint of an arbitrary pytree at ``step``."""
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if blocking:
            return self._write(step, names, host_leaves)
        self.wait()
        self._async_thread = threading.Thread(
            target=self._write, args=(step, names, host_leaves), daemon=True)
        self._async_thread.start()
        return self._final_path(step)

    def save_sharded(self, step: int, tree: Any, n_shards: int,
                     axis: int = 0, blocking: bool = True) -> str:
        """Atomic checkpoint with every leaf split into ``n_shards``
        chunks along ``axis`` — one file + one crc32 per shard per leaf
        (``<name>@sNNN``), so damage to one shard's bytes is localized
        to one chunk's integrity check. ``meta.json`` records the
        split; :meth:`restore` reassembles transparently, making the
        checkpoint restorable under a mesh of any size (the shard axis
        is a storage detail, not a layout commitment)."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        names, leaves, _ = _flatten_with_names(tree)
        out_names, out_leaves, sharded = [], [], {}
        for name, leaf in zip(names, leaves):
            arr = np.asarray(jax.device_get(leaf))
            if arr.ndim == 0 or n_shards == 1:
                out_names.append(name)
                out_leaves.append(arr)
                continue
            sharded[name] = {"n_shards": n_shards, "axis": axis}
            for j, chunk in enumerate(
                    np.array_split(arr, n_shards, axis=axis)):
                out_names.append(f"{name}@s{j:03d}")
                out_leaves.append(np.ascontiguousarray(chunk))
        if blocking:
            return self._write(step, out_names, out_leaves,
                               sharded=sharded)
        self.wait()
        self._async_thread = threading.Thread(
            target=self._write, args=(step, out_names, out_leaves),
            kwargs={"sharded": sharded}, daemon=True)
        self._async_thread.start()
        return self._final_path(step)

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _final_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, names: List[str], leaves,
               sharded: Optional[dict] = None) -> str:
        t0 = time.perf_counter() if obs.enabled() else None
        final = self._final_path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta = {"step": step, "leaves": []}
        if sharded:
            meta["sharded"] = sharded
        for name, arr in zip(names, leaves):
            fn = f"{len(meta['leaves']):05d}.npy"
            with open(os.path.join(tmp, fn), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            meta["leaves"].append({"name": name, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "crc32": _crc32(arr)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # make the rename itself durable: without the directory fsync a
        # crash can undo the publish even though every file was synced
        _fsync_dir(self.dir)
        self._gc()
        if t0 is not None:
            obs.observe("checkpoint.save_seconds",
                        time.perf_counter() - t0)
            obs.inc("checkpoint.saves")
            obs.set_gauge("checkpoint.bytes",
                          sum(int(a.nbytes) for a in leaves))
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._final_path(s), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                put: Optional[Callable[[str, np.ndarray], Any]] = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``like``.

        ``put(name, array)`` may device_put with a new sharding (elastic
        restore); default leaves arrays on host (jnp will ingest lazily).
        ``verify=True`` checks each leaf against the crc32 recorded at
        save time and raises :class:`CheckpointCorruptError` on any
        mismatch or unreadable file (checkpoints written before
        checksums existed verify trivially). Leaves written by
        :meth:`save_sharded` are reassembled from their per-shard
        chunks — the restoring mesh need not match the saving one.
        """
        t0 = time.perf_counter() if obs.enabled() else None
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self._final_path(step)
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"step {step}: unreadable meta.json: {e}") from e
        by_name = {d["name"]: d for d in meta["leaves"]}
        sharded = meta.get("sharded", {})

        def read(name):
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            d = by_name[name]
            try:
                arr = np.load(os.path.join(path, d["file"]))
            except (OSError, ValueError, EOFError) as e:
                raise CheckpointCorruptError(
                    f"step {step}: unreadable leaf {name!r}: {e}") from e
            if verify and "crc32" in d and _crc32(arr) != d["crc32"]:
                obs.inc("checkpoint.corrupt")
                raise CheckpointCorruptError(
                    f"step {step}: leaf {name!r} failed its crc32 check")
            return arr

        names, leaves, treedef = _flatten_with_names(like)
        out = []
        for name, ref in zip(names, leaves):
            if name in sharded:
                info = sharded[name]
                arr = np.concatenate(
                    [read(f"{name}@s{j:03d}")
                     for j in range(int(info["n_shards"]))],
                    axis=int(info["axis"]))
            else:
                arr = read(name)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {ref.shape}")
            out.append(put(name, arr) if put else arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if t0 is not None:
            obs.observe("checkpoint.restore_seconds",
                        time.perf_counter() - t0)
            obs.inc("checkpoint.restores")
        return tree

    def restore_with_fallback(
            self, like: Any,
            put: Optional[Callable[[str, np.ndarray], Any]] = None
    ) -> Tuple[int, Any]:
        """Restore the newest *intact* step: try the latest checkpoint,
        and on a failed integrity check fall back to the previous step
        (and so on). Returns ``(step, tree)``.

        Raises ``FileNotFoundError`` if no checkpoint exists at all and
        :class:`CheckpointCorruptError` if every step is damaged.
        Fallbacks count on ``checkpoint.fallbacks``.
        """
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        last_err: Optional[Exception] = None
        for step in reversed(steps):
            try:
                return step, self.restore(like, step=step, put=put)
            except CheckpointCorruptError as e:
                last_err = e
                obs.inc("checkpoint.fallbacks")
                continue
        raise CheckpointCorruptError(
            f"every checkpoint under {self.dir} is corrupt "
            f"(last error: {last_err})")
