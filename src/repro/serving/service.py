"""Async continuous-batching fractal-simulation service.

The "millions of users" story made concrete: heterogeneous jobs
``(fractal, r, workload, steps, snapshot cadence)`` arrive on an
asyncio front door, pass admission control, and are bucketed by their
engine-compatibility key onto the :class:`BatchedRunner`'s compiled-
engine LRU — requests sharing a bucket batch into ONE vmapped XLA call
(the warm path), cold compiles are bounded by a semaphore, and new
requests join a running batch at segment boundaries (continuous
batching: nobody waits for a full drain).

Execution is segment-at-a-time: each launch advances every row by
``seg`` steps (the minimum distance to any row's next event — snapshot
boundary, completion, or the ``max_segment_steps`` cap) through
``runner.run(..., donate=True)`` — donation-based in-place stepping
between snapshot yields. Between segments the service checks deadlines
(timeout/cancel), preemption, and the chaos hooks.

Fault tolerance (the point):

  * a segment that raises (e.g. an injected in-step exception) is
    retried with exponential backoff + deterministic jitter; every row
    is rebuilt from its newest intact checkpoint (or recomputed from
    its seed), so a retry is bit-exact for CA workloads;
  * a segment that exceeds the watchdog hang threshold is abandoned,
    the compiled engine is evicted from the runner LRU
    (``runner.invalidate`` — kill + restart), and the batch recovers
    from checkpoints exactly as above;
  * SIGTERM preemption (via :class:`PreemptionHandler`) drains the
    in-flight segment, checkpoints every active row, resolves them
    ``preempted`` and sheds the queue — resubmitting the same rid
    resumes from the checkpoint;
  * a corrupted/truncated checkpoint is caught by the manager's crc32
    verification and falls back to the previous intact step
    (``restore_with_fallback``);
  * sustained failure trips the circuit breaker: admission rejects
    with retry-after instead of letting the queue collapse;
  * ``dist-*`` engine kinds ride the same state machine: their rows
    checkpoint the mesh-independent dense compact state as *sharded*
    checkpoints (``save_sharded`` — per-shard leaves, one crc32 each)
    and restore through ``engine.from_dense`` (re-padded + re-sharded
    for the engine's current mesh), so the service survives
    distributed faults — and a checkpoint written under one mesh size
    restores under another.

Every transition lands on the telemetry registry:
``serve.{admitted,rejected,completed,failed,timeouts,preempted,
retries,restarts,recoveries,batches,segments,joins,checkpoints}``
counters, ``serve.{latency,queue_wait,recovery}_seconds`` +
``serve.{batch_size,segment_steps}`` histograms, and
``serve.{queue_depth,inflight,breaker_open}`` gauges — the SLO surface
``benchmarks/serve_bench.py`` gates on. See DESIGN.md Section 8.
"""
from __future__ import annotations

import asyncio
import dataclasses
import os
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointManager)
from repro.runtime.fault import (FaultInjector, PreemptionHandler,
                                 Watchdog, backoff_delays)
from repro.serving.types import (AdmissionError, CircuitBreaker,
                                 ServiceConfig, SimRequest, SimResult)
from repro.workloads.runner import BatchedRunner


@dataclasses.dataclass
class _Pending:
    req: SimRequest
    future: asyncio.Future
    t_submit: float
    retries: int = 0
    recoveries: int = 0


@dataclasses.dataclass
class _Row:
    """One active request inside a bucket batch."""

    pending: _Pending
    state: object                  # jnp array, engine-native compact state
    done: int                      # completed steps
    mgr: Optional[CheckpointManager]
    t_start: float
    snapshots: Dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)
    #: set synchronously by _finish_row — the bucket loop filters on
    #: this, not on future.done(), because worker-thread resolution
    #: lands on the loop asynchronously (call_soon_threadsafe)
    resolved: bool = False

    @property
    def req(self) -> SimRequest:
        return self.pending.req

    def next_event(self, cap: int) -> int:
        """Steps to this row's next boundary (completion or snapshot)."""
        left = self.req.steps - self.done
        if self.req.snapshot_every:
            to_snap = (self.req.snapshot_every
                       - self.done % self.req.snapshot_every)
            left = min(left, to_snap)
        return max(1, min(left, cap))


class FractalService:
    """See module docstring. Construct, then either drive the asyncio
    API (``await start()`` / ``await submit(req)`` / ``await stop()``)
    or hand a whole list to the sync facade ``serve(requests)``."""

    def __init__(self, config: Optional[ServiceConfig] = None,
                 runner: Optional[BatchedRunner] = None,
                 injector: Optional[FaultInjector] = None):
        self.config = config or ServiceConfig()
        self.runner = runner or BatchedRunner()
        self.injector = injector
        cfg = self.config
        self.breaker = CircuitBreaker(cfg.breaker_threshold,
                                      cfg.breaker_cooldown_s)
        self.watchdog = Watchdog(name="serve",
                                 hang_threshold_s=cfg.hang_threshold_s)
        self.preemption: Optional[PreemptionHandler] = None
        self._pending: Dict[Tuple, Deque[_Pending]] = {}
        self._running: Set[Tuple] = set()
        self._tasks: Set[asyncio.Task] = set()
        self._queued = 0
        self._segments = 0
        self._started = False
        self._stopping = False
        self._draining = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._compile_sem: Optional[asyncio.Semaphore] = None

    # ------------------------------------------------------------ lifecycle
    async def start(self, install_signals: bool = False) -> None:
        """Bind to the running loop. ``install_signals=True`` traps
        SIGTERM/SIGUSR1 for preemption draining (restored on stop)."""
        if self._started:
            return
        self._loop = asyncio.get_running_loop()
        # + slack beyond max_inflight: a hang-abandoned worker thread
        # keeps its slot busy until its sleep/step returns
        self._executor = ThreadPoolExecutor(
            max_workers=self.config.max_inflight + 4,
            thread_name_prefix="serve")
        self._compile_sem = asyncio.Semaphore(
            self.config.compile_concurrency)
        self.preemption = PreemptionHandler(install=install_signals)
        if self.injector is not None and self.injector.handler is None:
            self.injector.handler = self.preemption
        self._started = True
        self._stopping = False
        self._draining = False

    async def stop(self) -> None:
        """Drain: wait for in-flight buckets (which consume the queue),
        then shed anything still pending and release resources."""
        self._stopping = True
        while self._tasks:
            await asyncio.gather(*list(self._tasks),
                                 return_exceptions=True)
        self._shed_all("preempted" if self._preempted() else "rejected")
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        if self.preemption is not None:
            self.preemption.uninstall()
        self._started = False

    def _preempted(self) -> bool:
        return self.preemption is not None and self.preemption.requested

    # ------------------------------------------------------------ admission
    def _admit(self, req: SimRequest) -> None:
        cfg = self.config
        if self._stopping or self._draining or self._preempted():
            obs.inc("serve.rejected", reason="draining")
            raise AdmissionError("draining", cfg.retry_after_s)
        if not self.breaker.allow():
            obs.inc("serve.rejected", reason="breaker_open")
            obs.set_gauge("serve.breaker_open", 1)
            raise AdmissionError("breaker_open",
                                 max(self.breaker.retry_after(),
                                     cfg.retry_after_s))
        if self._queued >= cfg.max_queue:
            obs.inc("serve.rejected", reason="queue_full")
            raise AdmissionError("queue_full", cfg.retry_after_s)
        obs.inc("serve.admitted", kind=req.kind)

    async def submit(self, req: SimRequest) -> SimResult:
        """Admit + enqueue ``req`` and await its result. Raises
        :class:`AdmissionError` when shed at the door."""
        if not self._started:
            raise RuntimeError("service not started")
        self._admit(req)
        fut = self._loop.create_future()
        p = _Pending(req, fut, time.monotonic())
        self._pending.setdefault(req.bucket, deque()).append(p)
        self._queued += 1
        obs.set_gauge("serve.queue_depth", self._queued)
        self._maybe_launch()
        return await fut

    async def _submit_safe(self, req: SimRequest) -> SimResult:
        try:
            return await self.submit(req)
        except AdmissionError as e:
            return SimResult(rid=req.rid, status="rejected",
                             retry_after_s=e.retry_after_s,
                             error=e.reason)

    def serve(self, requests: List[SimRequest],
              install_signals: bool = False) -> List[SimResult]:
        """Sync facade: start, submit everything, drain, stop.
        Admission rejections come back as ``rejected`` results."""
        async def go():
            await self.start(install_signals=install_signals)
            try:
                return await asyncio.gather(
                    *(self._submit_safe(r) for r in requests))
            finally:
                await self.stop()
        return asyncio.run(go())

    # ----------------------------------------------------------- scheduling
    def _maybe_launch(self) -> None:
        """Start bucket tasks for queued work while inflight slots are
        free (called on submit and on task completion; runs on the
        loop, so checks are race-free)."""
        if self._stopping and not self._queued:
            return
        for bucket, q in list(self._pending.items()):
            if not q or bucket in self._running:
                continue
            if len(self._running) >= self.config.max_inflight:
                break
            self._running.add(bucket)
            task = self._loop.create_task(self._run_bucket(bucket))
            self._tasks.add(task)
            task.add_done_callback(self._on_task_done(bucket))
            obs.set_gauge("serve.inflight", len(self._running))

    def _on_task_done(self, bucket):
        def cb(task: asyncio.Task) -> None:
            self._tasks.discard(task)
            self._running.discard(bucket)
            obs.set_gauge("serve.inflight", len(self._running))
            if not task.cancelled() and task.exception() is not None:
                # a bucket-task bug must not strand its queued peers
                self._shed_bucket(bucket, "failed",
                                  error=repr(task.exception()))
            self._maybe_launch()
        return cb

    # ---------------------------------------------------------- bucket loop
    async def _run_bucket(self, bucket) -> None:
        # the bucket IS the normalized EngineSpec — the runner accepts
        # it directly; a representative request supplies the live
        # frac/workload objects (registry-invisible customs included)
        q0 = self._pending.get(bucket)
        if not q0:
            return  # shed between scheduling and task start
        rep = q0[0].req
        kind = bucket.kind
        cfg = self.config
        run_in = self._loop.run_in_executor

        # bounded cold compile: only misses pay the semaphore
        if not self.runner.is_cached(bucket, frac=rep.frac,
                                     workload=rep.workload):
            async with self._compile_sem:
                await run_in(self._executor,
                             lambda: self.runner.engine_for(
                                 bucket, frac=rep.frac,
                                 workload=rep.workload))

        rows: List[_Row] = []
        attempt = 0                      # failures since last success
        delays = None                    # backoff schedule of this streak
        t_fail: Optional[float] = None   # recovery-time clock
        warm: Set[int] = set()           # batch sizes already launched
        obs.inc("serve.batches", kind=kind)

        while True:
            # -- continuous joining at the segment boundary
            q = self._pending.get(bucket)
            while q and len(rows) < cfg.max_batch:
                p = q.popleft()
                self._queued -= 1
                obs.set_gauge("serve.queue_depth", self._queued)
                obs.inc("serve.joins", kind=kind)
                row = await run_in(
                    self._executor, lambda p=p: self._load_row(p))
                if row.done >= row.req.steps:
                    # restored past its own step count (a finished job
                    # resubmitted): complete without stepping
                    await run_in(self._executor,
                                 lambda row=row: self._finish_row(
                                     row, "ok",
                                     host_state=self._host_state(
                                         row.req, row.state)))
                else:
                    rows.append(row)
                q = self._pending.get(bucket)
            if not rows:
                return  # checked synchronously after last await: no race

            # -- chaos boundary hook + preemption drain
            if self.injector is not None:
                self.injector.at_boundary(self._segments)
            if self._preempted():
                self._draining = True
                await run_in(self._executor,
                             lambda: self._drain_rows(rows))
                self._shed_all("preempted")
                return

            # -- deadlines (checked between launches; a segment is the
            #    cancellation granularity, as with any running XLA call)
            now = time.monotonic()
            for row in rows:
                deadline = (row.req.deadline_s
                            if row.req.deadline_s is not None
                            else cfg.default_deadline_s)
                if now - row.pending.t_submit > deadline:
                    self._finish_row(row, "timeout")
            rows = [r_ for r_ in rows if not r_.resolved]
            if not rows:
                continue

            # -- one segment: advance every row by seg steps
            seg = min(row.next_event(cfg.max_segment_steps)
                      for row in rows)
            seg_idx = self._segments
            self._segments += 1
            obs.inc("serve.segments", kind=kind)
            obs.observe("serve.segment_steps", seg, kind=kind)
            obs.observe("serve.batch_size", len(rows), kind=kind)
            states = jnp.stack([row.state for row in rows])

            def work(states=states, seg=seg, seg_idx=seg_idx):
                if self.injector is not None:
                    self.injector.in_step(seg_idx)
                out = self.runner.run(bucket, states=states, steps=seg,
                                      frac=rep.frac,
                                      workload=rep.workload,
                                      donate=True)
                return jax.block_until_ready(out)

            # a batch shape this bucket has not launched yet pays XLA
            # compilation on this call — give it the compile grace so a
            # trace never reads as a hang (steady state gets the tight
            # threshold back)
            timeout = (cfg.hang_threshold_s if len(rows) in warm
                       else max(cfg.hang_threshold_s,
                                cfg.compile_grace_s))
            self.watchdog.start_step()
            try:
                out = await asyncio.wait_for(
                    run_in(self._executor, work), timeout=timeout)
            except asyncio.TimeoutError:
                # hang: abandon the stuck thread, kill + restart the
                # compiled engine, recover the batch from checkpoints
                self.watchdog.flag_hang()
                obs.inc("serve.restarts", kind=kind)
                self.runner.invalidate(bucket, frac=rep.frac,
                                       workload=rep.workload)
                warm.clear()  # the restarted engine recompiles
                t_fail = t_fail or time.monotonic()
                attempt += 1
                rows, delays = await self._retry_or_fail(
                    rows, attempt, delays, "hang")
                if rows is None:
                    return
                continue
            except Exception as e:
                obs.inc("serve.retries", kind=kind)
                t_fail = t_fail or time.monotonic()
                attempt += 1
                rows, delays = await self._retry_or_fail(
                    rows, attempt, delays, repr(e))
                if rows is None:
                    return
                continue
            self.watchdog.end_step()
            warm.add(len(rows))
            self.breaker.record_success()
            obs.set_gauge("serve.breaker_open", 0)
            if t_fail is not None:
                obs.observe("serve.recovery_seconds",
                            time.monotonic() - t_fail, kind=kind)
                obs.inc("serve.recoveries", kind=kind)
                for row in rows:
                    row.pending.recoveries += 1
                t_fail = None
            attempt, delays = 0, None

            # -- unstack, snapshot/checkpoint, complete
            for i, row in enumerate(rows):
                row.state = out[i]
                row.done += seg
            await run_in(self._executor,
                         lambda: self._after_segment(rows, seg_idx))
            rows = [r_ for r_ in rows if not r_.resolved]

    # ------------------------------------------------------ failure handling
    async def _retry_or_fail(self, rows: List[_Row], attempt: int,
                             delays, reason: str):
        """Common recovery path for hangs and in-step failures: breaker
        accounting, bounded retries, jittered backoff, and a row rebuild
        from the newest intact checkpoints. Returns ``(rows, delays)``
        or ``(None, None)`` once the batch is resolved failed."""
        cfg = self.config
        self.breaker.record_failure()
        if self.breaker.state != "closed":
            obs.set_gauge("serve.breaker_open", 1)
        for row in rows:
            row.pending.retries += 1
        if attempt > cfg.max_retries:
            for row in rows:
                self._finish_row(row, "failed",
                                 error=f"retries exhausted: {reason}")
            return None, None
        if delays is None:
            delays = backoff_delays(cfg.backoff_base_s,
                                    cfg.backoff_cap_s,
                                    seed=cfg.backoff_seed)
        await asyncio.sleep(next(delays))
        rebuilt = await self._loop.run_in_executor(
            self._executor,
            lambda: [self._reload_row(row) for row in rows])
        return rebuilt, delays

    def _reload_row(self, row: _Row) -> _Row:
        """Recovery rebuild: back to the newest intact checkpoint (or
        the seed). Worker thread."""
        state, done, _ = self._restore_state(row.req)
        row.state, row.done = state, done
        return row

    # -------------------------------------------------------- rows / state
    def _mgr_for(self, rid: str) -> Optional[CheckpointManager]:
        if not self.config.ckpt_dir:
            return None
        return CheckpointManager(
            os.path.join(self.config.ckpt_dir, rid),
            keep=self.config.keep_checkpoints)

    def _engine_of(self, req: SimRequest):
        return self.runner.engine_for(req.bucket, frac=req.frac,
                                      workload=req.workload)

    @staticmethod
    def _is_dist(req: SimRequest) -> bool:
        return req.kind.startswith("dist-")

    def _host_state(self, req: SimRequest, state) -> np.ndarray:
        """Host copy of a row's state for results, snapshots and
        checkpoints. Distributed rows strip the engine's padding
        blocks first: the user-facing (and checkpointed) artifact is
        the mesh-independent dense compact state, so a checkpoint
        written under one mesh restores under any other."""
        if self._is_dist(req):
            state = self._engine_of(req).to_dense(state)
        return np.asarray(jax.device_get(state))

    def _save_row(self, row: "_Row", host: np.ndarray) -> str:
        """Checkpoint one row (worker thread). Distributed rows write
        sharded checkpoints — per-shard leaves with one crc32 each,
        restorable under a different mesh (the elastic path)."""
        req = row.req
        if self._is_dist(req):
            eng = self._engine_of(req)
            return row.mgr.save_sharded(
                row.done, {"state": host}, n_shards=eng.n_shards,
                axis=host.ndim - 3)
        return row.mgr.save(row.done, {"state": host})

    def _restore_state(self, req: SimRequest):
        """(state, done, mgr): the newest intact checkpoint if one
        exists, else the seeded initial state. Worker thread.
        Distributed checkpoints hold the dense state and re-enter the
        engine via ``from_dense`` (re-padded + re-sharded for the
        engine's current mesh)."""
        engine = self._engine_of(req)
        init = engine.init_random(req.seed)
        mgr = self._mgr_for(req.rid)
        dist = self._is_dist(req)
        if mgr is not None and mgr.all_steps():
            like = {"state": engine.to_dense(init) if dist else init}
            try:
                step, tree = mgr.restore_with_fallback(like)
                state = (engine.from_dense(tree["state"]) if dist
                         else jnp.asarray(tree["state"]))
                return state, int(step), mgr
            except (CheckpointCorruptError, KeyError, ValueError):
                pass  # unusable checkpoint family: recompute from seed
        return init, 0, mgr

    def _load_row(self, p: _Pending) -> _Row:
        state, done, mgr = self._restore_state(p.req)
        return _Row(pending=p, state=state, done=done, mgr=mgr,
                    t_start=time.monotonic())

    def _after_segment(self, rows: List[_Row], seg_idx: int) -> None:
        """Snapshot/checkpoint boundaries + completions. Worker thread
        (device_get + disk I/O); future resolution hops to the loop."""
        for row in rows:
            req = row.req
            finished = row.done >= req.steps
            at_snap = (req.snapshot_every
                       and row.done % req.snapshot_every == 0)
            if not (finished or at_snap):
                continue
            host = self._host_state(req, row.state)
            if at_snap and not finished:
                row.snapshots[row.done] = host
            if row.mgr is not None:
                path = self._save_row(row, host)
                obs.inc("serve.checkpoints")
                if self.injector is not None:
                    self.injector.on_checkpoint(req.rid, path, seg_idx)
            if finished:
                self._finish_row(row, "ok", host_state=host)

    def _drain_rows(self, rows: List[_Row]) -> None:
        """Preemption: checkpoint every active row at its current step,
        then resolve it ``preempted``. Worker thread."""
        for row in rows:
            host = self._host_state(row.req, row.state)
            if row.mgr is not None:
                self._save_row(row, host)
                obs.inc("serve.checkpoints")
            self._finish_row(row, "preempted", host_state=host)

    # ------------------------------------------------------------- results
    def _finish_row(self, row: _Row, status: str,
                    host_state: Optional[np.ndarray] = None,
                    error: Optional[str] = None) -> None:
        if row.resolved:
            return
        row.resolved = True
        p = row.pending
        now = time.monotonic()
        res = SimResult(
            rid=p.req.rid, status=status, state=host_state,
            snapshots=sorted(row.snapshots.items()),
            steps_done=row.done, latency_s=now - p.t_submit,
            queue_wait_s=row.t_start - p.t_submit,
            retries=p.retries, recoveries=p.recoveries, error=error)
        self._count_outcome(status, p.req.kind)
        obs.observe("serve.latency_seconds", res.latency_s,
                    kind=p.req.kind, status=status)
        obs.observe("serve.queue_wait_seconds", res.queue_wait_s,
                    kind=p.req.kind)
        self._set_result(p.future, res)

    _OUTCOMES = {"ok": "serve.completed", "failed": "serve.failed",
                 "timeout": "serve.timeouts",
                 "preempted": "serve.preempted",
                 "rejected": "serve.shed"}

    def _count_outcome(self, status: str, kind: str) -> None:
        obs.inc(self._OUTCOMES.get(status, "serve.other"), kind=kind)

    def _set_result(self, fut: asyncio.Future, res: SimResult) -> None:
        """Resolve a future from any thread."""
        def do():
            if not fut.done():
                fut.set_result(res)
        if self._loop is not None:
            try:
                running = asyncio.get_running_loop()
            except RuntimeError:
                running = None
            if running is self._loop:
                do()
            else:
                self._loop.call_soon_threadsafe(do)

    def _shed_bucket(self, bucket, status: str,
                     error: Optional[str] = None) -> None:
        q = self._pending.get(bucket)
        while q:
            p = q.popleft()
            self._queued -= 1
            self._count_outcome(status, p.req.kind)
            self._set_result(p.future, SimResult(
                rid=p.req.rid, status=status, steps_done=0,
                latency_s=time.monotonic() - p.t_submit, error=error,
                retry_after_s=self.config.retry_after_s))
        obs.set_gauge("serve.queue_depth", self._queued)

    def _shed_all(self, status: str) -> None:
        for bucket in list(self._pending):
            self._shed_bucket(bucket, status)
