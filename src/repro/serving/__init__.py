"""Fault-tolerant continuous-batching fractal-simulation serving.

Public surface::

    from repro.serving import (FractalService, ServiceConfig, SimRequest,
                               SimResult, AdmissionError)
    from repro.runtime.fault import Fault, FaultInjector   # chaos harness

See DESIGN.md Section 8 for the architecture, the chaos matrix and the
recovery state machine.
"""
from repro.serving.service import FractalService  # noqa: F401
from repro.serving.types import (  # noqa: F401
    AdmissionError, CircuitBreaker, ServiceConfig, SimRequest, SimResult)
