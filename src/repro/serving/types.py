"""Request/response types, service configuration, admission control and
the circuit breaker for the fractal-simulation service.

Kept free of jax and of ``service.py``'s asyncio machinery so tests and
benchmarks can construct/inspect these without touching the event loop.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import TYPE_CHECKING, Any, Hashable, List, Optional, Tuple

from repro.workloads.base import StencilWorkload
from repro.workloads.rules import LIFE

if TYPE_CHECKING:  # annotation-only; keeps import time jax-free
    from repro.tuning.spec import EngineSpec

_RIDS = itertools.count()


def _next_rid() -> str:
    return f"req{next(_RIDS)}"


@dataclasses.dataclass
class SimRequest:
    """One fractal-simulation job.

    ``rid`` doubles as the durable identity: a request resubmitted with
    the same ``rid`` after a preemption resumes from its newest intact
    checkpoint instead of step 0. ``snapshot_every`` is both the
    user-visible yield cadence and the recovery granularity (a fault
    loses at most ``snapshot_every`` steps of recompute).
    """

    frac: Hashable                     # NBBFractal (hashable)
    r: int
    steps: int
    workload: StencilWorkload = LIFE
    m: int = 0
    kind: str = "block"
    k: Optional[int] = None            # fusion depth (None = heuristic)
    seed: int = 0
    snapshot_every: int = 0            # 0 = final state only
    deadline_s: Optional[float] = None
    rid: str = dataclasses.field(default_factory=_next_rid)

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError("steps must be >= 1")
        if self.snapshot_every < 0:
            raise ValueError("snapshot_every must be >= 0")

    @property
    def bucket(self) -> "EngineSpec":
        """Engine-compatibility key: the NORMALIZED
        :class:`repro.tuning.spec.EngineSpec` of this request — the
        same object the BatchedRunner LRU and the tuning table key on,
        so requests batch together exactly when they would share one
        compiled entry (alias kinds, an explicit ``k`` equal to the
        resolved default, etc. all collapse). Computed once per request
        (the tuning-table consult and its ``engine.tune.*`` telemetry
        fire on first access); mutating the identity fields afterwards
        does not re-bucket."""
        b = self.__dict__.get("_bucket")
        if b is None:
            from repro.tuning.spec import EngineSpec
            b = EngineSpec.from_args(
                self.kind, self.frac, self.r, self.m, self.workload,
                fusion_k=self.k).normalize()
            self.__dict__["_bucket"] = b
        return b


@dataclasses.dataclass
class SimResult:
    """Outcome of one request. ``status``:

    ``ok``        — ran to ``steps`` (``state`` is the final compact
                    state, host-side);
    ``timeout``   — deadline expired at a segment boundary;
    ``failed``    — retries exhausted on a persistent failure;
    ``preempted`` — drained mid-run (checkpointed at ``steps_done``;
                    resubmit with the same rid to resume);
    ``rejected``  — admission refused (queue full / breaker open /
                    draining); ``retry_after_s`` hints when to come
                    back.
    """

    rid: str
    status: str = "ok"
    state: Optional[Any] = None
    snapshots: List[Tuple[int, Any]] = dataclasses.field(
        default_factory=list)
    steps_done: int = 0
    latency_s: float = 0.0
    queue_wait_s: float = 0.0
    retries: int = 0
    recoveries: int = 0
    retry_after_s: Optional[float] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class AdmissionError(RuntimeError):
    """Raised by ``submit`` when a request is shed at the door
    (queue full, circuit breaker open, or the service is draining).
    Carries ``retry_after_s`` — reject-with-retry-after, not collapse."""

    def __init__(self, reason: str, retry_after_s: float):
        super().__init__(
            f"admission refused ({reason}); retry after "
            f"{retry_after_s:.2f}s")
        self.reason = reason
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class ServiceConfig:
    """Knobs of :class:`repro.serving.FractalService`."""

    # ---- admission / queueing
    max_queue: int = 64            # queued-but-unscheduled bound
    max_batch: int = 8             # rows per bucket batch
    max_inflight: int = 2          # concurrently running bucket batches
    compile_concurrency: int = 1   # concurrent cold engine builds
    default_deadline_s: float = 60.0
    retry_after_s: float = 0.5     # hint on queue-full rejections
    # ---- segments (continuous-batching granularity)
    max_segment_steps: int = 64    # hang-detection granularity bound
    # ---- retries / backoff on transient failures
    max_retries: int = 3
    backoff_base_s: float = 0.02
    backoff_cap_s: float = 0.5
    backoff_seed: int = 0
    # ---- watchdog (hang detection on one segment's wall time)
    hang_threshold_s: float = 10.0
    #: wall-time allowance when a segment's batch shape has not run
    #: before (first launch per (bucket, B) pays XLA compilation, which
    #: dwarfs steady-state segments and must not read as a hang); also
    #: applies to the first launch after an engine restart (recompile)
    compile_grace_s: float = 60.0
    # ---- circuit breaker
    breaker_threshold: int = 5     # consecutive failures to open
    breaker_cooldown_s: float = 1.0
    # ---- durability
    ckpt_dir: Optional[str] = None  # None: no durable snapshots
    keep_checkpoints: int = 3


class CircuitBreaker:
    """Consecutive-failure breaker: closed -> open after ``threshold``
    failures in a row; open sheds load for ``cooldown_s``; the first
    probe after cooldown (half-open) closes it on success or re-opens
    on failure. Time source injectable for tests."""

    def __init__(self, threshold: int = 5, cooldown_s: float = 1.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._failures = 0
        self._opened_at: Optional[float] = None
        self._half_open = False

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return "half-open" if self._half_open else "closed"
        if self._clock() - self._opened_at >= self.cooldown_s:
            return "half-open"
        return "open"

    def allow(self) -> bool:
        """Admission check. In half-open, admits (the probe)."""
        s = self.state
        if s == "open":
            return False
        if s == "half-open" and self._opened_at is not None:
            # transition open -> half-open happens on first probe
            self._opened_at = None
            self._half_open = True
        return True

    def retry_after(self) -> float:
        if self._opened_at is None:
            return 0.0
        return max(0.0, self.cooldown_s
                   - (self._clock() - self._opened_at))

    def record_failure(self) -> None:
        self._failures += 1
        if self._half_open or self._failures >= self.threshold:
            self._opened_at = self._clock()
            self._half_open = False
            self._failures = 0

    def record_success(self) -> None:
        self._failures = 0
        self._half_open = False
        self._opened_at = None
