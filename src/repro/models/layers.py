"""Shared neural layers (raw JAX, param trees of jnp arrays): norms, RoPE,
embeddings, dense/gated MLPs. Initialisation is truncated-normal
(scale/sqrt(fan_in) for output projections, standard for the rest)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jnp.ndarray


def trunc_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, shape, cfg: ModelConfig, *, out: bool = False):
    import math
    fan_in = shape[0] if not out else max(1, math.prod(shape[:-1]))
    scale = cfg.init_scale if not out else cfg.init_scale / (fan_in ** 0.5)
    return trunc_normal(key, shape, scale, jnp.dtype(cfg.param_dtype))


# ------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.zeros((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p, x: Array, cfg: ModelConfig, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * (1.0 + p["scale"].astype(jnp.float32)) \
            + p["bias"].astype(jnp.float32)
    else:  # rms, (1+scale) parameterisation (gemma/llama-compatible)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps)
        out = out * (1.0 + p["scale"].astype(jnp.float32))
    return out.astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_sincos(positions: Array, head_dim: int, theta: float):
    """positions (…, S) int32 -> (sin, cos) each (…, S, head_dim//2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x: (B, S, H, D); sin/cos: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None]
        cos = cos[None]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


# -------------------------------------------------------------- embeddings
def init_embed(key, cfg: ModelConfig):
    p = {"tok_embed": trunc_normal(key, (cfg.vocab_padded, cfg.d_model),
                                   cfg.init_scale,
                                   jnp.dtype(cfg.param_dtype))}
    if cfg.pos_embed == "learned":
        p["pos_embed"] = trunc_normal(
            jax.random.fold_in(key, 1),
            (min(cfg.max_seq, 65536), cfg.d_model),
            0.02, jnp.dtype(cfg.param_dtype))
    return p


def embed_tokens(p, tokens: Array, cfg: ModelConfig,
                 pos_offset: Array | int = 0) -> Array:
    x = jnp.take(p["tok_embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_embed == "learned":
        s = tokens.shape[-1]
        pos = pos_offset + jnp.arange(s)
        pos = jnp.clip(pos, 0, p["pos_embed"].shape[0] - 1)
        x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(x.dtype)
    return x


def unembed(p_embed, p_head, x: Array, cfg: ModelConfig, mesh=None) -> Array:
    table = p_embed["tok_embed"] if cfg.tie_embeddings else p_head["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    if mesh is not None:
        # pin vocab-sharded logits (prevents an (B,S,V) all-gather)
        from repro.utils.sharding import MeshAxes, constraint
        axes = MeshAxes().present(mesh)
        if axes.model and cfg.vocab_padded % mesh.shape[axes.model] == 0:
            from jax.sharding import PartitionSpec as P
            lead = axes.batch if axes.batch else None
            logits = constraint(logits, mesh, P(lead, None, axes.model))
    if cfg.logit_softcap is not None:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), cfg),
         "w_down": dense_init(ks[1], (f, d), cfg, out=True)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d, f), cfg)
    return p


def apply_mlp(p, x: Array, cfg: ModelConfig) -> Array:
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if cfg.mlp_kind == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    elif cfg.mlp_kind == "geglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
