"""Mixture-of-Experts layer: top-k routing with capacity-bounded compact
dispatch (GShard-style), sort-based (no O(T*E*C) one-hot tensors).

Tokens live in the expanded [B*S] domain; experts compute in compact
[E, C] buffers; gather/scatter maps translate between the two — the same
compact/expanded storage duality as the paper's fractal scheme, with a
data-dependent (router) map instead of a static one.

Supports Mixtral (8e top-2) and Arctic (128e top-2 + parallel dense
residual MLP). Router in fp32; returns the switch-style load-balance aux
loss.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp
from repro.utils.sharding import MeshAxes, constraint

Array = jnp.ndarray


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], (d, e), cfg),
         "w_gate": dense_init(ks[1], (e, d, f), cfg),
         "w_up": dense_init(ks[2], (e, d, f), cfg),
         "w_down": dense_init(ks[3], (e, f, d), cfg, out=True)}
    if m.dense_residual_ff:
        p["dense"] = init_mlp(ks[4], cfg, d_ff=m.dense_residual_ff)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def _route(p, xf: Array, cfg: ModelConfig):
    """Router in fp32: (top_p, top_e, aux). xf: (..., T, d)."""
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    logits = jnp.einsum("...td,de->...te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # switch-style load-balance aux: E * sum_e f_e * P_e
    f_e = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32),
                   axis=tuple(range(top_e.ndim - 1)) + (top_e.ndim - 1,))
    p_e = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(f_e * p_e)
    return top_p, top_e, aux


def _dispatch_compact(xf: Array, top_p: Array, top_e: Array, e: int,
                      cap: int):
    """Sort-based capacity dispatch within ONE token group.

    xf (T, d) -> (expert_in (E, cap, d), dest (T*k,), st (T*k,), sg)."""
    t, d = xf.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)
    flat_g = top_p.reshape(-1).astype(xf.dtype)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)                     # stable
    se = flat_e[order]
    st = flat_t[order]
    sg = flat_g[order]
    start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - start[se]
    keep = pos_in_e < cap
    dest = jnp.where(keep, se * cap + pos_in_e, e * cap)  # overflow -> dump
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[dest].set(xf[st])
    return buf[: e * cap].reshape(e, cap, d), dest, st, sg


def _combine_compact(expert_out: Array, dest: Array, st: Array, sg: Array,
                     t: int):
    e, cap, d = expert_out.shape
    dt = expert_out.dtype
    out_flat = jnp.concatenate(
        [expert_out.reshape(e * cap, d), jnp.zeros((1, d), dt)], axis=0)
    vals = out_flat[dest] * sg[:, None]
    return jnp.zeros((t, d), dt).at[st].add(vals)


def _expert_ffn(p, expert_in: Array, cfg: ModelConfig) -> Array:
    """(..., E, C, d) -> (..., E, C, d) via the stacked expert weights."""
    dt = expert_in.dtype
    up = jnp.einsum("...ecd,edf->...ecf", expert_in, p["w_up"].astype(dt))
    gate = jnp.einsum("...ecd,edf->...ecf", expert_in,
                      p["w_gate"].astype(dt))
    if cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.silu(gate) * up
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"].astype(dt))


def n_token_groups(cfg: ModelConfig, mesh: Optional[Mesh], n_tokens: int
                   ) -> int:
    """Capacity-dispatch group count.

    ``cfg.moe.dispatch_groups`` pins it explicitly (the group count is
    *semantic*: capacity is bounded per group, so different groupings drop
    different tokens — an unsharded reference must group identically to
    reproduce a sharded run). Default (None) derives it from the mesh's
    batch-sharding degree, keeping every dispatch gather/scatter local to
    a data shard."""
    g = cfg.moe.dispatch_groups
    if g is None:
        if mesh is None:
            return 1
        axes = MeshAxes().present(mesh)
        g = 1
        for a in axes.batch:
            g *= mesh.shape[a]
    return g if (g > 1 and n_tokens % g == 0) else 1


def apply_moe(p, x: Array, cfg: ModelConfig, mesh: Optional[Mesh] = None
              ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    With a mesh, dispatch is SHARD-LOCAL (beyond-paper optimization,
    EXPERIMENTS.md §Perf/arctic): tokens are grouped by their batch shard
    and sorted/capacity-bounded within the group, so every dispatch
    gather/scatter is local to a data shard — XLA otherwise lowers the
    global data-dependent scatter to full-size dense all-reduces
    (observed: 5 x ~56 GiB f32 ARs per step on arctic-480b). Tokens then
    stay put and only expert weights travel (FSDP gather), which is the
    cheaper side for d_ff-small experts like arctic's."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = m.n_experts
    xf = x.reshape(t, d)

    top_p, top_e, aux = _route(p, xf, cfg)

    g = n_token_groups(cfg, mesh, t)
    t_local = t // g
    cap = _capacity(t_local, cfg)

    if g == 1:
        expert_in, dest, st, sg = _dispatch_compact(xf, top_p, top_e, e, cap)
        expert_in = constraint(expert_in, mesh, _expert_spec(cfg, mesh))
        expert_out = _expert_ffn(p, expert_in, cfg)
        out = _combine_compact(expert_out, dest, st, sg, t)
    else:
        # grouping may also run meshless (dispatch_groups pinned in the
        # config): every constraint degrades to identity, the math is
        # identical to the sharded shard-local dispatch
        axes = (MeshAxes().present(mesh) if mesh is not None
                else MeshAxes(batch=(), fsdp=None, model=None))
        lead = axes.batch if axes.batch else None
        xg = xf.reshape(g, t_local, d)
        xg = constraint(xg, mesh, P(lead, None, None))
        # grouped buffers (g, E, C, d): groups pinned to the batch shards
        # (all dispatch indexing local), experts EP over 'model' if it fits
        ep = (axes.model if axes.model
              and e % mesh.shape[axes.model] == 0 else None)
        g_spec = P(lead, ep, None, None)
        disp = jax.vmap(lambda xx, tp, te: _dispatch_compact(
            xx, tp, te, e, cap))
        expert_in, dest, st, sg = disp(
            xg, top_p.reshape(g, t_local, -1), top_e.reshape(g, t_local, -1))
        expert_in = constraint(expert_in, mesh, g_spec)  # (g, E, C, d)
        expert_out = _expert_ffn(p, expert_in, cfg)
        expert_out = constraint(expert_out, mesh, g_spec)
        out = jax.vmap(_combine_compact, in_axes=(0, 0, 0, 0, None))(
            expert_out, dest, st, sg, t_local)
        out = constraint(out, mesh, P(lead, None, None))
        out = out.reshape(t, d)

    out = out.reshape(b, s, d)
    if m.dense_residual_ff:
        out = out + apply_mlp(p["dense"], x, cfg)
    return out, aux.astype(jnp.float32)


def _expert_spec(cfg: ModelConfig, mesh: Optional[Mesh]) -> P:
    """(E, C, d) buffers: EP over 'model' when E divides, else C over it."""
    if mesh is None:
        return P()
    axes = MeshAxes().present(mesh)
    e = cfg.moe.n_experts
    if axes.model and e % mesh.shape[axes.model] == 0:
        return P(axes.model, axes.fsdp, None)
    return P(None, axes.model, None)
