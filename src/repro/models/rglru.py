"""Griffin recurrent block with the RG-LRU cell [arXiv:2402.19427]
(RecurrentGemma's mixer).

Block: y = W_out( GeLU(W_gate x)  ⊙  RGLRU( Conv1D_4( W_x x ) ) ).
RG-LRU: r_t, i_t gates from the branch input; a_t = exp(-c softplus(L) r_t);
h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t u_t). Training uses an associative
scan (parallel over S); decode carries (h, conv window) — O(1)/token.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.ssm import _causal_conv

Array = jnp.ndarray
C_RGLRU = 8.0


def _d_rec(cfg: ModelConfig) -> int:
    return cfg.rec.d_rec or cfg.d_model


def init_rec(key, cfg: ModelConfig):
    d, dr = cfg.d_model, _d_rec(cfg)
    k = cfg.rec.conv_width
    ks = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "w_x": dense_init(ks[0], (d, dr), cfg),
        "w_gate": dense_init(ks[1], (d, dr), cfg),
        "conv_w": dense_init(ks[2], (k, dr), cfg),
        "conv_b": jnp.zeros((dr,), pd),
        "w_rg": dense_init(ks[3], (dr, dr), cfg),
        "b_rg": jnp.zeros((dr,), pd),
        "w_ig": dense_init(ks[4], (dr, dr), cfg),
        "b_ig": jnp.zeros((dr,), pd),
        # softplus(lambda) ~ 0.105 -> a_max ~ exp(-0.84) at r=1
        "lambda_p": jnp.full((dr,), -2.2, pd),
        "w_out": dense_init(ks[5], (dr, d), cfg, out=True),
    }


def init_rec_cache(cfg: ModelConfig, batch: int):
    dr = _d_rec(cfg)
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rec.conv_width - 1, dr),
                          jnp.dtype(cfg.dtype)),
    }


def _rglru(p, u: Array, h0: Optional[Array]
           ) -> Tuple[Array, Array]:
    """u (B,S,dr) post-conv branch input; h0 (B,dr) or None.
    Returns (h (B,S,dr) fp32, h_last)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", uf,
                                  p["w_rg"].astype(jnp.float32))
                       + p["b_rg"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", uf,
                                  p["w_ig"].astype(jnp.float32))
                       + p["b_ig"].astype(jnp.float32))
    log_a = -C_RGLRU * r * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def apply_rec(p, x: Array, cfg: ModelConfig, cache=None
              ) -> Tuple[Array, Optional[dict]]:
    """x (B,S,d) -> (out, new_cache)."""
    dt_ = x.dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_))
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x,
                                  p["w_gate"].astype(dt_)),
                       approximate=True)

    if cache is None:
        u = _causal_conv(u, p["conv_w"], p["conv_b"])
        h, _ = _rglru(p, u, None)
        new_cache = None
    else:
        k = cfg.rec.conv_width
        s = x.shape[1]
        window = jnp.concatenate([cache["conv"], u], axis=1)
        out = jnp.zeros_like(u)
        for j in range(k):
            out = out + window[:, j:j + s] * \
                p["conv_w"][j][None, None].astype(dt_)
        u = out + p["conv_b"][None, None].astype(dt_)
        h, h_last = _rglru(p, u, cache["h"])
        new_cache = {"h": h_last, "conv": window[:, -(k - 1):]}

    y = h.astype(dt_) * gate
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_)), new_cache
