"""Model configuration: one dataclass family covering the 10 assigned
architectures (dense / MoE / SSM / hybrid-recurrent / enc-dec / VLM-backbone).

Layer structure is expressed as a repeating ``unit`` of LayerSpecs scanned
``n_units`` times, plus an optional unrolled ``tail`` (for layer counts not
divisible by the unit length, e.g. recurrentgemma's 38 = 12*3 + 2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    #: Arctic-style dense residual MLP running in parallel with the experts
    dense_residual_ff: Optional[int] = None
    aux_loss_weight: float = 0.01
    #: token groups for capacity-bounded dispatch. None = derive from the
    #: mesh's batch-sharding degree (shard-local dispatch; the math then
    #: DEPENDS on the mesh, because capacity is bounded per group). Set it
    #: explicitly to pin the dispatch semantics independently of how the
    #: step is sharded — e.g. the sharded-equality suite pins it so the
    #: unsharded reference drops the same tokens as the 8-device run.
    dispatch_groups: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD (state-space duality) layer parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RecSpec:
    """Griffin RG-LRU recurrent block parameters."""
    conv_width: int = 4
    #: lru width; None -> d_model
    d_rec: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating unit."""
    kind: str = "attn"          # "attn" | "rec" | "ssm"
    window: Optional[int] = None  # sliding-window size; None = global attn

    def __post_init__(self):
        assert self.kind in ("attn", "rec", "ssm"), self.kind


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    unit: Tuple[LayerSpec, ...]
    n_units: int
    tail: Tuple[LayerSpec, ...] = ()
    family: str = "decoder"     # "decoder" | "encdec"
    head_dim: Optional[int] = None   # None -> d_model // n_heads
    # encoder (enc-dec archs only)
    n_enc_units: int = 0
    enc_seq: int = 1500         # stub frontend frames (whisper 30s)
    # VLM stub frontend
    n_patches: int = 0          # >0: patch-embedding prefix (llava)
    # flavor knobs
    mlp_kind: str = "swiglu"    # "swiglu" | "geglu" | "gelu"
    norm: str = "rms"           # "rms" | "ln"
    post_norms: bool = False    # gemma2 pre+post block norms
    qkv_bias: bool = False      # qwen
    tie_embeddings: bool = False
    emb_scale: bool = False     # gemma: embed * sqrt(d)
    logit_softcap: Optional[float] = None  # gemma2 final softcap
    attn_softcap: Optional[float] = None   # gemma2 attention softcap
    rope_theta: float = 10000.0
    pos_embed: str = "rope"     # "rope" | "learned"
    max_seq: int = 524288       # learned pos table size cap
    moe: Optional[MoESpec] = None
    ssm: SSMSpec = SSMSpec()
    rec: RecSpec = RecSpec()
    # numerics
    param_dtype: str = "float32"
    dtype: str = "bfloat16"     # compute dtype
    remat: str = "full"         # "full" | "none" — scan-unit checkpointing
    # Megatron-SP-style sequence sharding of inter-layer activations over
    # the model axis (EXPERIMENTS.md §Perf): shrinks the remat-saved unit
    # boundaries (the dominant train memory term at d_model >= 8k) at the
    # cost of per-layer AG/RS on the sequence dim.
    seq_shard: bool = False
    # int8 KV cache with per-(batch, head, position) scales — halves the
    # dominant decode roofline term (KV reads); beyond-paper (§Perf).
    kv_quant: bool = False
    # init
    init_scale: float = 0.02

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.n_units + len(self.tail)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 (Megatron-style) so TP sharding divides."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return all(s.kind != "attn" for s in self.unit + self.tail)

    @property
    def is_sub_quadratic(self) -> bool:
        """Eligible for long_500k: every attn layer is windowed, or no attn
        at all, or (gemma2-style) attention alternates local/global with a
        bounded-window local majority and O(n)-per-token global decode."""
        attn = [s for s in self.unit + self.tail if s.kind == "attn"]
        if not attn:
            return True
        rec = [s for s in self.unit + self.tail if s.kind != "attn"]
        windowed = [s for s in attn if s.window is not None]
        # all-windowed, or hybrid with recurrent layers, or local+global mix
        return len(windowed) == len(attn) or bool(rec) or bool(windowed)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND."""
        d, v = self.d_model, self.vocab_padded
        hd = self.head_dim_
        total = v * d  # tok embed
        if not self.tie_embeddings:
            total += v * d
        specs = list(self.unit) * self.n_units + list(self.tail)
        for s in specs:
            if s.kind == "attn":
                total += d * self.n_heads * hd  # q
                total += 2 * d * self.n_kv_heads * hd  # kv
                total += self.n_heads * hd * d  # o
            elif s.kind == "rec":
                dr = self.rec.d_rec or d
                total += 2 * d * dr + dr * d + 3 * dr  # x,gate,out + lru
            elif s.kind == "ssm":
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                total += d * (2 * di + 2 * self.ssm.n_groups *
                              self.ssm.d_state + nh)
                total += di * d
            if s.kind != "ssm":
                if self.moe is not None:
                    total += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                    total += d * self.moe.n_experts
                    if self.moe.dense_residual_ff:
                        total += 3 * d * self.moe.dense_residual_ff
                else:
                    n_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    total += n_mats * d * self.d_ff
        # encoder stack (approx: same attn+mlp shape)
        for _ in range(self.n_enc_units):
            total += (d * self.n_heads * hd * 2 + 2 * d * self.n_kv_heads * hd
                      + 3 * d * self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of E experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        n_layers = self.n_layers
        expert_p = 3 * d * self.moe.d_ff_expert
        inactive = (self.moe.n_experts - self.moe.top_k) * expert_p * n_layers
        return full - inactive
