"""LM substrate: config-driven model zoo (dense / MoE / SSM / hybrid /
enc-dec / VLM backbone) in raw JAX with scan-over-layers."""
from repro.models.config import (LayerSpec, ModelConfig, MoESpec, RecSpec,
                                 SSMSpec)
from repro.models.model import (decode_step, forward, greedy_generate,
                                init_cache, init_params, prefill, train_loss)

__all__ = ["LayerSpec", "ModelConfig", "MoESpec", "RecSpec", "SSMSpec",
           "decode_step", "forward", "greedy_generate", "init_cache",
           "init_params", "prefill", "train_loss"]
