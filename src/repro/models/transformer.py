"""Decoder-only LM assembly: repeating unit of layers scanned ``n_units``
times (stacked params => compact HLO, fast multi-pod compiles) plus an
unrolled tail, with optional per-unit activation rematerialisation.

Covers dense (tinyllama/qwen/smollm), local+global alternating (gemma2),
SWA MoE (mixtral), MoE + dense residual (arctic), hybrid RG-LRU (recurrent-
gemma), attention-free SSD (mamba2), and the VLM backbone (llava, patch-
prefix stub).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embed, init_mlp, init_norm,
                                 trunc_normal, unembed)
from repro.utils.sharding import constraint

Array = jnp.ndarray


# ======================================================================
# single layer
# ======================================================================
def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 3)
    p: dict = {"pre_norm": init_norm(cfg)}
    if spec.kind == "attn":
        p["attn"] = attn_mod.init_attn(ks[0], cfg)
    elif spec.kind == "rec":
        p["rec"] = rec_mod.init_rec(ks[0], cfg)
    else:
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    if cfg.post_norms:
        p["post_norm"] = init_norm(cfg)
    if spec.kind != "ssm":  # mamba2 layers are mixer-only
        p["mlp_norm"] = init_norm(cfg)
        if cfg.moe is not None:
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg)
        if cfg.post_norms:
            p["mlp_post_norm"] = init_norm(cfg)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int):
    if spec.kind == "attn":
        return attn_mod.init_attn_cache(cfg, spec, batch, max_len)
    if spec.kind == "rec":
        return rec_mod.init_rec_cache(cfg, batch)
    return ssm_mod.init_ssm_cache(cfg, batch)


def apply_layer(p, x: Array, cfg: ModelConfig, spec: LayerSpec,
                pos_offset, cache=None, mesh: Optional[Mesh] = None
                ) -> Tuple[Array, Any, Array]:
    """-> (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["pre_norm"], x, cfg)
    if spec.kind == "attn":
        mixed, new_cache = attn_mod.apply_attn(
            p["attn"], h, cfg, spec, pos_offset, cache)
    elif spec.kind == "rec":
        mixed, new_cache = rec_mod.apply_rec(p["rec"], h, cfg, cache)
    else:
        mixed, new_cache = ssm_mod.apply_ssm(p["ssm"], h, cfg, cache)
    if cfg.post_norms:
        mixed = apply_norm(p["post_norm"], mixed, cfg)
    x = x + mixed

    if spec.kind != "ssm":
        h = apply_norm(p["mlp_norm"], x, cfg)
        if cfg.moe is not None:
            m, aux = moe_mod.apply_moe(p["moe"], h, cfg, mesh)
        else:
            m = apply_mlp(p["mlp"], h, cfg)
        if cfg.post_norms:
            m = apply_norm(p["mlp_post_norm"], m, cfg)
        x = x + m
    if mesh is not None:
        x = constraint(x, mesh, activation_spec(cfg, mesh, x))
    return x, new_cache, aux


def activation_spec(cfg: ModelConfig, mesh, x: Array):
    """Inter-layer activation spec: batch-sharded, plus sequence over the
    model axis when cfg.seq_shard is on and S divides (Megatron SP)."""
    from jax.sharding import PartitionSpec as P
    from repro.utils.sharding import MeshAxes
    axes = MeshAxes().present(mesh)
    lead = axes.batch or None
    if (cfg.seq_shard and axes.model
            and x.shape[1] % mesh.shape[axes.model] == 0):
        return P(lead, axes.model, None)
    return P(lead, None, None)


# ======================================================================
# unit (the repeating group of layers) + full parameter tree
# ======================================================================
def init_unit(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.unit))
    return {f"l{i}": init_layer(ks[i], cfg, spec)
            for i, spec in enumerate(cfg.unit)}


def init_unit_cache(cfg: ModelConfig, specs, batch: int, max_len: int):
    return {f"l{i}": init_layer_cache(cfg, spec, batch, max_len)
            for i, spec in enumerate(specs)}


def apply_unit(p_unit, x: Array, cfg: ModelConfig, specs, pos_offset,
               cache=None, mesh: Optional[Mesh] = None):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for i, spec in enumerate(specs):
        li = f"l{i}"
        x, nc, a = apply_layer(p_unit[li], x, cfg, spec, pos_offset,
                               None if cache is None else cache[li], mesh)
        if cache is not None:
            new_cache[li] = nc
        aux = aux + a
    return x, new_cache, aux


def _sliced_unit_specs(units_params, mesh: Optional[Mesh]):
    """Per-leaf PartitionSpecs for a scan-sliced unit (stack dim removed).

    Pinning the slice inside the scan body keeps the FSDP all-gather
    *per-iteration*: without it XLA hoists one giant all-gather of the
    whole stacked parameter tree out of the while loop (observed: +1.5 TB
    temp on qwen1.5-110b)."""
    if mesh is None:
        return None
    from jax.sharding import PartitionSpec as P
    from repro.utils.sharding import param_specs
    stacked = param_specs({"units": units_params}, mesh)["units"]
    return jax.tree.map(lambda s: P(*s[1:]), stacked)


def _pin_unit(p_unit, unit_specs, mesh: Optional[Mesh]):
    if unit_specs is None or mesh is None:
        return p_unit
    return jax.tree.map(lambda x, s: constraint(x, mesh, s),
                        p_unit, unit_specs)


def init_params(key, cfg: ModelConfig):
    k_embed, k_units, k_tail, k_head = jax.random.split(key, 4)
    params = {"embed": init_embed(k_embed, cfg)}
    unit_keys = jax.random.split(k_units, cfg.n_units)
    params["units"] = jax.vmap(lambda k: init_unit(k, cfg))(unit_keys)
    if cfg.tail:
        tks = jax.random.split(k_tail, len(cfg.tail))
        params["tail"] = {f"t{i}": init_layer(tks[i], cfg, spec)
                          for i, spec in enumerate(cfg.tail)}
    params["final_norm"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        params["head"] = {"lm_head": trunc_normal(
            k_head, (cfg.vocab_padded, cfg.d_model), cfg.init_scale,
            jnp.dtype(cfg.param_dtype))}
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    proto = init_unit_cache(cfg, cfg.unit, batch, max_len)
    units = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_units,) + a.shape, a.dtype), proto)
    cache = {"units": units}
    if cfg.tail:
        cache["tail"] = {f"t{i}": init_layer_cache(cfg, spec, batch, max_len)
                         for i, spec in enumerate(cfg.tail)}
    return cache


# ======================================================================
# forward
# ======================================================================
def forward(params, tokens: Array, cfg: ModelConfig, *,
            pos_offset=0, cache=None, prefix_embeds: Optional[Array] = None,
            mesh: Optional[Mesh] = None):
    """tokens (B, S) int32 -> (logits (B, S_total, V), new_cache, aux)."""
    x = embed_tokens(params["embed"], tokens, cfg, pos_offset=pos_offset)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constraint(x, mesh, activation_spec(cfg, mesh, x)) \
        if mesh is not None else x
    pos_offset = jnp.asarray(pos_offset, jnp.int32)

    has_cache = cache is not None
    unit_specs = _sliced_unit_specs(params["units"], mesh)

    def unit_body(carry, xs):
        xc, aux = carry
        if has_cache:
            p_unit, c_unit = xs
        else:
            p_unit, c_unit = xs, None
        p_unit = _pin_unit(p_unit, unit_specs, mesh)
        xc, new_c, a = apply_unit(p_unit, xc, cfg, cfg.unit, pos_offset,
                                  c_unit, mesh)
        return (xc, aux + a), new_c

    if cfg.remat == "full":
        unit_body = jax.checkpoint(unit_body)

    xs = (params["units"], cache["units"]) if has_cache else params["units"]
    (x, aux), new_unit_cache = jax.lax.scan(
        unit_body, (x, jnp.zeros((), jnp.float32)), xs)

    new_cache = {"units": new_unit_cache} if has_cache else None
    if cfg.tail:
        if has_cache:
            new_cache["tail"] = {}
        for i, spec in enumerate(cfg.tail):
            ti = f"t{i}"
            c = cache["tail"][ti] if has_cache else None
            x, nc, a = apply_layer(params["tail"][ti], x, cfg, spec,
                                   pos_offset, c, mesh)
            aux = aux + a
            if has_cache:
                new_cache["tail"][ti] = nc

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], params.get("head"), x, cfg, mesh)
    return logits, new_cache, aux


# ======================================================================
# losses & steps
# ======================================================================
def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean CE over positions with label >= 0; logits fp32 (B,S,V).

    Gather-free formulation (iota-select + reduce instead of
    take_along_axis) so a vocab-sharded logits tensor reduces locally +
    psum instead of all-gathering (B,S,V) — essential for the 256k-vocab
    archs on the production mesh."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    vio = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(vio == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(params, batch, cfg: ModelConfig,
               mesh: Optional[Mesh] = None) -> Tuple[Array, dict]:
    logits, _, aux = forward(
        params, batch["tokens"], cfg,
        prefix_embeds=batch.get("prefix_embeds"), mesh=mesh)
    if "prefix_embeds" in batch and batch["prefix_embeds"] is not None:
        npfx = batch["prefix_embeds"].shape[1]
        logits = logits[:, npfx:]
    ce = cross_entropy(logits, batch["labels"])
    # z-loss for logit drift control (PaLM-style)
    z = jax.nn.logsumexp(logits, axis=-1)
    zl = 1e-4 * jnp.mean(jnp.square(z))
    total = ce + zl
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_weight * aux
    return total, {"ce": ce, "z_loss": zl, "moe_aux": aux}
