"""Family dispatch: one public API over decoder-only and enc-dec models.

  init_params(key, cfg)                  -> param tree
  train_loss(params, batch, cfg, mesh)   -> (loss, metrics)
  train_step is assembled in launch/train.py (optimizer in the loop)
  prefill / decode_step                  -> serving
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import encdec, transformer
from repro.models.config import ModelConfig

Array = jnp.ndarray


def init_params(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    return transformer.init_params(key, cfg)


def train_loss(params, batch, cfg: ModelConfig,
               mesh: Optional[Mesh] = None):
    if cfg.family == "encdec":
        return encdec.train_loss(params, batch, cfg, mesh)
    return transformer.train_loss(params, batch, cfg, mesh)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len)
    return transformer.init_cache(cfg, batch, max_len)


def forward(params, batch, cfg: ModelConfig, *, mesh=None):
    """Training/prefill-style forward for any family."""
    if cfg.family == "encdec":
        return encdec.forward(params, batch["frames"], batch["tokens"], cfg,
                              mesh=mesh)
    return transformer.forward(params, batch["tokens"], cfg,
                               prefix_embeds=batch.get("prefix_embeds"),
                               mesh=mesh)


def prefill(params, batch, cfg: ModelConfig, cache, *, mesh=None):
    """Fill the KV cache from a prompt; returns (last_logits, cache, extras).

    For enc-dec, also returns the per-unit cross K/V under extras."""
    if cfg.family == "encdec":
        memory = encdec.encode(params, batch["frames"], cfg, mesh)
        memory_kv = encdec.encode_memory_kv(params, memory, cfg)
        logits, cache, _ = encdec.forward(
            params, None, batch["tokens"], cfg, pos_offset=0, cache=cache,
            memory_kv=memory_kv, mesh=mesh)
        return logits[:, -1], cache, {"memory_kv": memory_kv}
    logits, cache, _ = transformer.forward(
        params, batch["tokens"], cfg, pos_offset=0, cache=cache,
        prefix_embeds=batch.get("prefix_embeds"), mesh=mesh)
    return logits[:, -1], cache, {}


def decode_step(params, tokens: Array, pos_offset, cfg: ModelConfig,
                cache, *, extras=None, mesh=None):
    """One decode step: tokens (B, 1) at absolute position ``pos_offset``.
    Returns (logits (B, V), new_cache)."""
    if cfg.family == "encdec":
        logits, cache, _ = encdec.forward(
            params, None, tokens, cfg, pos_offset=pos_offset, cache=cache,
            memory_kv=(extras or {})["memory_kv"], mesh=mesh)
        return logits[:, -1], cache
    logits, cache, _ = transformer.forward(
        params, tokens, cfg, pos_offset=pos_offset, cache=cache, mesh=mesh)
    return logits[:, -1], cache


def _select_token(logits: Array, key, temperature: float, top_k: int
                  ) -> Array:
    """Greedy (temperature<=0) or top-k temperature sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(params, batch, cfg: ModelConfig, *, max_new: int,
             max_len: int, temperature: float = 0.0, top_k: int = 0,
             seed: int = 0, mesh=None):
    """KV-cached decoding loop: greedy by default, top-k temperature
    sampling when temperature > 0."""
    b = batch["tokens"].shape[0]
    cache = init_cache(cfg, b, max_len)
    last, cache, extras = prefill(params, batch, cfg, cache, mesh=mesh)
    start = batch["tokens"].shape[1]
    key0 = jax.random.PRNGKey(seed)

    def body(carry, i):
        last_logits, cache_c = carry
        k = jax.random.fold_in(key0, i)
        tok = _select_token(last_logits, k, temperature, top_k)[:, None]
        logits, cache_c = decode_step(params, tok, start + i, cfg, cache_c,
                                      extras=extras, mesh=mesh)
        return (logits, cache_c), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (last, cache),
                                jnp.arange(max_new, dtype=jnp.int32))
    return toks.T  # (B, max_new)


def greedy_generate(params, batch, cfg: ModelConfig, *, max_new: int,
                    max_len: int, mesh=None):
    """Greedy decoding loop (serving example path)."""
    return generate(params, batch, cfg, max_new=max_new, max_len=max_len,
                    temperature=0.0, mesh=mesh)
