"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training runs the chunked SSD algorithm (intra-chunk quadratic block +
inter-chunk linear state recurrence); decoding carries the (B, H, P, N)
state and the depthwise-conv window — O(1) per token, which is what makes
``long_500k`` decode trivial for the SSM arch.

The pure-jnp chunked scan below is the dry-run/CPU path; the TPU deploy
path for the intra-chunk block is the Pallas kernel
``kernels/ssd_chunk.py`` (validated against this implementation AND the
sequential per-token recurrence in tests/test_kernels_ssd.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jnp.ndarray


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": dense_init(ks[0], (d, d_proj), cfg),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), cfg),
        "conv_b": jnp.zeros((conv_dim,), pd),
        "A_log": jnp.zeros((nh,), pd),           # A = -exp(0) = -1 at init
        "D": jnp.ones((nh,), pd),
        "dt_bias": jnp.zeros((nh,), pd),
        "norm_scale": jnp.zeros((d_in,), pd),
        "out_proj": dense_init(ks[2], (d_in, d), cfg, out=True),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, nh, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim),
                          jnp.dtype(cfg.dtype)),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv; x (B,S,C), w (K,C)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for j in range(k):
        shift = k - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * w[j][None, None, :].astype(x.dtype)
    return out + b[None, None, :].astype(x.dtype)


def _ssd_chunked(xh: Array, dt: Array, a: Array, bm: Array, cm: Array,
                 chunk: int) -> Array:
    """Chunked SSD scan. xh (B,S,H,P); dt (B,S,H); a (H,) negative;
    bm/cm (B,S,G,N). Returns (B,S,H,P), fp32."""
    b, s, h, p = xh.shape
    g = bm.shape[2]
    hpg = h // g
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc, ll = sp // chunk, chunk

    xc = xh.reshape(b, nc, ll, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, ll, h).astype(jnp.float32)
    bc = bm.reshape(b, nc, ll, g, 1, -1).astype(jnp.float32)
    cc = cm.reshape(b, nc, ll, g, 1, -1).astype(jnp.float32)
    bh = jnp.broadcast_to(bc, (b, nc, ll, g, hpg, bc.shape[-1])
                          ).reshape(b, nc, ll, h, -1)
    ch = jnp.broadcast_to(cc, (b, nc, ll, g, hpg, cc.shape[-1])
                          ).reshape(b, nc, ll, h, -1)

    da = dtc * a[None, None, None, :]              # (b,nc,L,h)
    da_t = jnp.cumsum(da, axis=2).transpose(0, 1, 3, 2)  # (b,nc,h,L)
    dt_t = dtc.transpose(0, 1, 3, 2)               # (b,nc,h,L)

    # intra-chunk (the "duality" quadratic block)
    cb = jnp.einsum("bclhn,bcmhn->bchlm", ch, bh)
    seg = da_t[..., :, None] - da_t[..., None, :]   # (b,nc,h,L,L)
    tri = jnp.tril(jnp.ones((ll, ll), bool))
    decay = jnp.where(tri[None, None, None], jnp.exp(seg), 0.0)
    scores = cb * decay * dt_t[..., None, :]
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", scores, xc)

    # chunk-final states
    w = jnp.exp(da_t[..., -1:] - da_t) * dt_t       # (b,nc,h,L)
    states = jnp.einsum("bchm,bcmhp,bcmhn->bchpn", w, xc, bh)

    # inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(da_t[..., -1])            # (b,nc,h)
    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                           # emit state BEFORE chunk
    _, prev = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, bh.shape[-1]), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)            # (b,nc,h,p,n)

    y_off = jnp.einsum("bclhn,bchpn->bclhp", ch, prev) \
        * jnp.exp(da_t).transpose(0, 1, 3, 2)[..., None]
    y = (y_diag + y_off).reshape(b, sp, h, p)
    return y[:, :s]


def apply_ssm(p, x: Array, cfg: ModelConfig, cache=None
              ) -> Tuple[Array, Optional[dict]]:
    """x (B,S,d) -> (out (B,S,d), new_cache)."""
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    d_in, nh, conv_dim = _dims(cfg)
    g, n = s_cfg.n_groups, s_cfg.d_state
    hd = s_cfg.head_dim
    dt_ = x.dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt_))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]

    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (b,w-1+s,c)
        k = s_cfg.conv_width
        out = jnp.zeros_like(xbc)
        for j in range(k):
            out = out + window[:, j:j + s] * \
                p["conv_w"][j][None, None].astype(dt_)
        xbc = out + p["conv_b"][None, None].astype(dt_)
        new_conv = window[:, -(k - 1):]
    xbc = jax.nn.silu(xbc)

    xs = xbc[..., :d_in].reshape(b, s, nh, hd)
    bm = xbc[..., d_in:d_in + g * n].reshape(b, s, g, n)
    cm = xbc[..., d_in + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None:
        y = _ssd_chunked(xs, dt, a, bm, cm, s_cfg.chunk)
        new_state = None
    else:
        # recurrent decode: state (b,h,p,n)
        hpg = nh // g
        bh = jnp.repeat(bm, hpg, axis=2).astype(jnp.float32)  # (b,s,h,n)
        chh = jnp.repeat(cm, hpg, axis=2).astype(jnp.float32)
        state = cache["state"]
        ys = []
        for i in range(s):  # s == 1 in decode
            da = jnp.exp(dt[:, i] * a[None])                  # (b,h)
            upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, i],
                             xs[:, i].astype(jnp.float32), bh[:, i])
            state = state * da[..., None, None] + upd
            ys.append(jnp.einsum("bhpn,bhn->bhp", state, chh[:, i]))
        y = jnp.stack(ys, axis=1)
        new_state = state

    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * (
        1.0 + p["norm_scale"].astype(jnp.float32))
    out = jnp.einsum("bsk,kd->bsd", y.astype(dt_), p["out_proj"].astype(dt_))
    new_cache = None if cache is None else {"state": new_state,
                                            "conv": new_conv}
    return out, new_cache
