"""Encoder-decoder backbone (whisper-small). The conv/mel frontend is a
STUB per the assignment: the encoder consumes precomputed frame embeddings
(B, T_enc, d) from ``input_specs()``; everything downstream (bidirectional
encoder stack, causal decoder with cross-attention, KV caches) is real.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import attention as attn_mod
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embed, init_mlp, init_norm,
                                 trunc_normal, unembed)
from repro.models.transformer import cross_entropy
from repro.utils.sharding import batch_spec, constraint

Array = jnp.ndarray
_SPEC = LayerSpec(kind="attn")


# ----------------------------------------------------------------- params
def _init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"pre_norm": init_norm(cfg),
            "attn": attn_mod.init_attn(k1, cfg),
            "mlp_norm": init_norm(cfg),
            "mlp": init_mlp(k2, cfg)}


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"pre_norm": init_norm(cfg),
            "attn": attn_mod.init_attn(k1, cfg),
            "cross_norm": init_norm(cfg),
            "cross": attn_mod.init_cross_attn(k2, cfg),
            "mlp_norm": init_norm(cfg),
            "mlp": init_mlp(k3, cfg)}


def init_params(key, cfg: ModelConfig):
    ke, ku, kd, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ku, cfg.n_enc_units)
    dec_keys = jax.random.split(kd, cfg.n_units)
    return {
        "embed": init_embed(ke, cfg),
        "enc_pos": {"pos_embed": trunc_normal(
            kp, (cfg.enc_seq, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype))},
        "enc_units": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_units": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": init_norm(cfg),
        "final_norm": init_norm(cfg),
        "head": {"lm_head": trunc_normal(
            jax.random.fold_in(key, 9), (cfg.vocab_padded, cfg.d_model),
            cfg.init_scale, jnp.dtype(cfg.param_dtype))},
    }


# ---------------------------------------------------------------- encoder
def encode(params, frames: Array, cfg: ModelConfig,
           mesh: Optional[Mesh] = None) -> Array:
    """frames (B, T, d) stub embeddings -> encoder memory (B, T, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    t = x.shape[1]
    pos = params["enc_pos"]["pos_embed"]
    x = x + pos[jnp.clip(jnp.arange(t), 0, pos.shape[0] - 1)].astype(
        x.dtype)[None]

    def body(carry, p_layer):
        h = apply_norm(p_layer["pre_norm"], carry, cfg)
        a, _ = attn_mod.apply_attn(p_layer["attn"], h, cfg, _SPEC, 0,
                                   causal=False)
        carry = carry + a
        h = apply_norm(p_layer["mlp_norm"], carry, cfg)
        carry = carry + apply_mlp(p_layer["mlp"], h, cfg)
        if mesh is not None:
            carry = constraint(carry, mesh, batch_spec(mesh, extra_dims=2))
        return carry, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_units"])
    return apply_norm(params["enc_norm"], x, cfg)


def encode_memory_kv(params, memory: Array, cfg: ModelConfig):
    """Per-decoder-unit cross K/V, stacked for the scan (decode cache)."""
    def one(p_layer):
        return attn_mod.encode_memory_kv(p_layer["cross"], memory, cfg)
    return jax.lax.map(one, params["dec_units"])


# ---------------------------------------------------------------- decoder
def _dec_layer(p_layer, x, memory_kv, cfg, pos_offset, cache, mesh):
    h = apply_norm(p_layer["pre_norm"], x, cfg)
    a, new_cache = attn_mod.apply_attn(p_layer["attn"], h, cfg, _SPEC,
                                       pos_offset, cache)
    x = x + a
    h = apply_norm(p_layer["cross_norm"], x, cfg)
    x = x + attn_mod.apply_cross_attn(p_layer["cross"], h, memory_kv, cfg)
    h = apply_norm(p_layer["mlp_norm"], x, cfg)
    x = x + apply_mlp(p_layer["mlp"], h, cfg)
    if mesh is not None:
        x = constraint(x, mesh, batch_spec(mesh, extra_dims=2))
    return x, new_cache


def forward(params, frames: Array, tokens: Array, cfg: ModelConfig, *,
            pos_offset=0, cache=None, memory_kv=None,
            mesh: Optional[Mesh] = None):
    """Full enc-dec forward. For decode pass ``cache`` + ``memory_kv``
    (from encode_memory_kv) and frames=None.

    Returns (logits, new_cache, aux=0)."""
    if memory_kv is None:
        memory = encode(params, frames, cfg, mesh)
        memory_kv = encode_memory_kv(params, memory, cfg)

    x = embed_tokens(params["embed"], tokens, cfg, pos_offset=pos_offset)
    has_cache = cache is not None
    pos_offset = jnp.asarray(pos_offset, jnp.int32)

    def body(carry, xs):
        if has_cache:
            p_layer, mem_kv, c = xs
        else:
            p_layer, mem_kv = xs
            c = None
        new_x, new_c = _dec_layer(p_layer, carry, mem_kv, cfg, pos_offset,
                                  c, mesh)
        return new_x, new_c

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    xs = ((params["dec_units"], memory_kv, cache["units"]) if has_cache
          else (params["dec_units"], memory_kv))
    x, new_unit_cache = jax.lax.scan(body, x, xs)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], params.get("head"), x, cfg, mesh)
    new_cache = {"units": new_unit_cache} if has_cache else None
    return logits, new_cache, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    proto = attn_mod.init_attn_cache(cfg, _SPEC, batch, max_len)
    return {"units": jax.tree.map(
        lambda a: jnp.zeros((cfg.n_units,) + a.shape, a.dtype), proto)}


def train_loss(params, batch, cfg: ModelConfig,
               mesh: Optional[Mesh] = None):
    logits, _, aux = forward(params, batch["frames"], batch["tokens"], cfg,
                             mesh=mesh)
    ce = cross_entropy(logits, batch["labels"])
    z = jax.nn.logsumexp(logits, axis=-1)
    total = ce + 1e-4 * jnp.mean(jnp.square(z))
    return total, {"ce": ce, "moe_aux": aux}
