"""GQA attention with RoPE, sliding windows, logit softcap, and KV caches.

Serving caches:
  * global-attention layers keep a full (B, KVH, S_max, hd) cache;
  * sliding-window layers keep a **ring buffer** of exactly ``window``
    slots (slot = pos % window) — the expanded->compact index map
    nu_ring(t) = t mod W, the temporal analogue of the paper's compact
    scheme: O(W) memory regardless of stream length,
    which is what makes long_500k decode feasible for windowed archs.

Keys/values are RoPE-rotated *before* caching, so ring overwrites need no
re-rotation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import apply_rope, dense_init, rope_sincos

Array = jnp.ndarray
NEG = -1e30


def init_attn(key, cfg: ModelConfig):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, h, hd), cfg),
         "wk": dense_init(ks[1], (d, kvh, hd), cfg),
         "wv": dense_init(ks[2], (d, kvh, hd), cfg),
         "wo": dense_init(ks[3], (h, hd, d), cfg, out=True)}
    if cfg.qkv_bias:
        z = jnp.zeros
        pd = jnp.dtype(cfg.param_dtype)
        p["bq"] = z((h, hd), pd)
        p["bk"] = z((kvh, hd), pd)
        p["bv"] = z((kvh, hd), pd)
    return p


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    max_len: int):
    """Zeroed KV cache for one attention layer (optionally int8)."""
    size = min(spec.window, max_len) if spec.window else max_len
    shape = (batch, cfg.n_kv_heads, size, cfg.head_dim_)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    dt = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _kv_quantize(x: Array):
    """(B,KVH,S,hd) -> int8 values + per-(b,h,s) absmax scales."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _qkv(p, x: Array, cfg: ModelConfig, positions: Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.pos_embed == "rope":
        sin, cos = rope_sincos(positions, cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Array,
          cfg: ModelConfig) -> Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KVH,hd); mask: (B|1,Sq,Skv) bool."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / (hd ** 0.5))
    if cfg.attn_softcap is not None:
        c = cfg.attn_softcap
        s = c * jnp.tanh(s / c)
    s = jnp.where(mask[:, None, None], s, NEG)
    p_attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p_attn.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


# ---------------------------------------------------------------- chunked
#: switch to the online-softmax path above this many score elements
_CHUNK_THRESHOLD = 4 * 1024 * 1024
_BQ = 1024
_BK = 1024


def _sdpa_chunked(q: Array, k: Array, v: Array, cfg: ModelConfig, *,
                  q0, k0, causal: bool, window: Optional[int]) -> Array:
    """Flash-style online-softmax attention in plain XLA: lax.scan over
    query blocks x key blocks keeps the materialised score tile at
    (B, H, BQ, BK) instead of (B, H, S, S) — the XLA analogue of the
    Pallas kernel in kernels/attention.py (which is the TPU deploy path;
    this path is what the CPU dry-run lowers).

    Positions: qpos = q0 + i, kpos = k0 + j. Out-of-range (padded) kv
    masked via kpos >= k0 only within [0, Skv).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / (hd ** 0.5)

    pad_q = (-sq) % _BQ
    pad_k = (-skv) % _BK
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (sq + pad_q) // _BQ, (skv + pad_k) // _BK

    qb = qp.reshape(b, nq, _BQ, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(b, nk, _BK, kvh, hd).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, nk, _BK, kvh, hd).transpose(1, 0, 3, 2, 4)
    # qb: (nq, B, KVH, G, BQ, hd); kb/vb: (nk, B, KVH, BK, hd)

    def q_block(qi, q_tile):
        qpos = q0 + qi * _BQ + jnp.arange(_BQ, dtype=jnp.int32)

        def kv_step(carry, inp):
            m_prev, l_prev, acc = carry
            ki, k_tile, v_tile = inp
            j = ki * _BK + jnp.arange(_BK, dtype=jnp.int32)  # local index
            kpos = k0 + j
            s = jnp.einsum("bkgqd,bktd->bkgqt", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            if cfg.attn_softcap is not None:
                c = cfg.attn_softcap
                s = c * jnp.tanh(s / c)
            mask = (j[None, :] < skv)
            mask = jnp.broadcast_to(mask, (_BQ, _BK))
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(s - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, v_tile.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        init = (jnp.full((b, kvh, g, _BQ), NEG, jnp.float32),
                jnp.zeros((b, kvh, g, _BQ), jnp.float32),
                jnp.zeros((b, kvh, g, _BQ, hd), jnp.float32))
        # remat the kv step: the (BQ, BK) probability tile is recomputed
        # in backward instead of being stashed per step (bounds the scan
        # residuals at carry size — the flash trick, XLA edition)
        (m, lsum, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init,
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return out  # (B, KVH, G, BQ, hd)

    outs = jax.lax.map(lambda args: jax.checkpoint(q_block)(*args),
                       (jnp.arange(nq, dtype=jnp.int32), qb))
    # (nq, B, KVH, G, BQ, hd) -> (B, nq*BQ, H, hd)
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(
        b, nq * _BQ, h, hd)
    return outs[:, :sq].astype(q.dtype)


def apply_attn(p, x: Array, cfg: ModelConfig, spec: LayerSpec,
               pos_offset, cache=None, causal: bool = True
               ) -> Tuple[Array, Optional[dict]]:
    """Self-attention. cache=None: training/prefill-no-cache mode.
    With cache: appends the S new positions then attends over the cache
    (ring semantics for windowed layers). causal=False: encoder
    (bidirectional, no cache)."""
    b, sq, _ = x.shape
    qpos = pos_offset + jnp.arange(sq, dtype=jnp.int32)  # (Sq,)
    q, k_new, v_new = _qkv(p, x, cfg, qpos[None].repeat(b, 0))

    if not causal:
        mask = jnp.ones((1, sq, sq), bool)
        out = _sdpa(q, k_new, v_new, mask, cfg)
        dt = x.dtype
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), None

    if cache is None:
        if sq * sq > _CHUNK_THRESHOLD:
            out = _sdpa_chunked(q, k_new, v_new, cfg, q0=pos_offset,
                                k0=pos_offset, causal=True,
                                window=spec.window)
        else:
            kpos = qpos
            mask = kpos[None, None, :] <= qpos[None, :, None]
            if spec.window is not None:
                mask &= (kpos[None, None, :]
                         > qpos[None, :, None] - spec.window)
            out = _sdpa(q, k_new, v_new, mask, cfg)
        new_cache = None
    else:
        size = cache["k"].shape[2]
        k_t = k_new.swapaxes(1, 2)  # (B,KVH,S,hd)
        v_t = v_new.swapaxes(1, 2)
        quant = cfg.kv_quant
        if quant:
            k_w, ks_w = _kv_quantize(k_t)
            v_w, vs_w = _kv_quantize(v_t)
        else:
            k_w, v_w = k_t, v_t
        kc, vc = cache["k"], cache["v"]
        ksc, vsc = cache.get("k_scale"), cache.get("v_scale")
        if spec.window is not None and size == spec.window:
            # ring write: slot = pos % window (vectorised scatter)
            slots = (qpos % size).astype(jnp.int32)
            kc = kc.at[:, :, slots, :].set(k_w)
            vc = vc.at[:, :, slots, :].set(v_w)
            if quant:
                ksc = ksc.at[:, :, slots].set(ks_w)
                vsc = vsc.at[:, :, slots].set(vs_w)
            new_len = pos_offset + sq
            # slot s holds position p = largest p' < new_len, p' % W == s
            last = new_len - 1
            slot_ids = jnp.arange(size, dtype=jnp.int32)
            held = last - ((last - slot_ids) % size)
            valid = (held >= 0) & (held >= new_len - size)
            kpos_b = jnp.broadcast_to(held[None], (b, size))
            mask = (kpos_b[:, None, :] <= qpos[None, :, None]) & \
                   (kpos_b[:, None, :] > qpos[None, :, None] - spec.window) \
                   & valid[None, None, :]
        else:
            kc = jax.lax.dynamic_update_slice(kc, k_w, (0, 0, pos_offset, 0))
            vc = jax.lax.dynamic_update_slice(vc, v_w, (0, 0, pos_offset, 0))
            if quant:
                ksc = jax.lax.dynamic_update_slice(
                    ksc, ks_w, (0, 0, pos_offset))
                vsc = jax.lax.dynamic_update_slice(
                    vsc, vs_w, (0, 0, pos_offset))
            kpos = jnp.arange(size, dtype=jnp.int32)
            mask = kpos[None, None, :] <= qpos[None, :, None]
            if spec.window is not None:
                mask &= kpos[None, None, :] > qpos[None, :, None] - spec.window
        if quant:
            k_read = _kv_dequantize(kc, ksc, x.dtype)
            v_read = _kv_dequantize(vc, vsc, x.dtype)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            k_read, v_read = kc, vc
            new_cache = {"k": kc, "v": vc}
        out = _sdpa(q, k_read.swapaxes(1, 2), v_read.swapaxes(1, 2), mask,
                    cfg)

    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


# ---------------------------------------------------------- cross-attention
def init_cross_attn(key, cfg: ModelConfig):
    return init_attn(key, cfg)


def apply_cross_attn(p, x: Array, memory_kv, cfg: ModelConfig) -> Array:
    """Decoder cross-attention over precomputed encoder K/V
    (memory_kv = {"k": (B,T,KVH,hd), "v": ...})."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    t = memory_kv["k"].shape[1]
    mask = jnp.ones((1, x.shape[1], t), bool)
    out = _sdpa(q, memory_kv["k"], memory_kv["v"], mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encode_memory_kv(p, mem: Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    dt = mem.dtype
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return {"k": k, "v": v}
