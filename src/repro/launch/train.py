"""Training driver: checkpointed, watchdogged, restartable.

Single-host usage (examples/tests):
    python -m repro.launch.train --arch smollm-135m --steps 200 ...

The loop is structured for fault tolerance:
  * the data pipeline is stateless (batch = f(seed, step)), so resuming
    at step N replays nothing and skips nothing;
  * checkpoints are atomic and carry (params, opt_state, step);
  * a PreemptionHandler turns SIGTERM into checkpoint-and-exit;
  * runtime.fault.run_with_restarts supervises (tests kill mid-run and
    assert bit-exact continuation).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.fault import PreemptionHandler, SimulatedFailure, Watchdog


@dataclasses.dataclass
class TrainResult:
    step: int
    losses: list
    stragglers: int
    restored_from: Optional[int]


def train(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, data, *,
          steps: int, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, mesh=None, seed: int = 0,
          fail_at: Optional[int] = None,
          preemption: Optional[PreemptionHandler] = None,
          log_every: int = 10,
          on_step: Optional[Callable] = None) -> TrainResult:
    """Run (or resume) training to ``steps`` total steps."""
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init(opt_cfg, params)
    # distinct buffers per leaf: jax dedups literal zeros, and donating the
    # same buffer twice (m and v of one param) is a runtime error
    opt_state = jax.tree.map(lambda a: jax.numpy.array(a, copy=True),
                             opt_state)
    start_step = 0
    restored_from = None

    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if manager and manager.latest_step() is not None:
        restored_from = manager.latest_step()
        state = manager.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        params = jax.tree.map(jax.numpy.asarray, params)
        opt_state = jax.tree.map(jax.numpy.asarray, opt_state)
        start_step = restored_from

    step_fn = jax.jit(specs_lib.make_train_step(cfg, opt_cfg, mesh),
                      donate_argnums=(0, 1))
    watchdog = Watchdog()
    losses = []
    step = start_step
    for step in range(start_step, steps):
        batch = data.batch(step)
        watchdog.start_step()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        watchdog.end_step()
        losses.append(loss)
        if on_step:
            on_step(step, metrics)
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        done = step + 1
        want_ckpt = manager and (done % ckpt_every == 0 or done == steps)
        if preemption is not None and preemption.requested:
            if manager:
                manager.save(done, {"params": params, "opt": opt_state})
            print(f"preempted at step {done}; checkpointed and exiting")
            return TrainResult(done, losses, watchdog.stragglers,
                               restored_from)
        if want_ckpt:
            manager.save(done, {"params": params, "opt": opt_state})
        if fail_at is not None and done == fail_at:
            raise SimulatedFailure(f"injected failure after step {done}")
    return TrainResult(steps, losses, watchdog.stragglers, restored_from)


def main():
    import argparse
    from repro import configs
    from repro.data.pipeline import SyntheticMarkov

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)
    data = SyntheticMarkov(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    t0 = time.time()
    res = train(cfg, opt_cfg, data, steps=args.steps,
                ckpt_dir=args.ckpt_dir,
                preemption=PreemptionHandler())
    dt = time.time() - t0
    print(f"done: {res.step} steps in {dt:.1f}s; "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    first, last = np.mean(res.losses[:5]), np.mean(res.losses[-5:])
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
