"""Serving driver: continuous batched greedy decoding.

A minimal-but-real serving loop: requests arrive with prompts, are padded
into a fixed batch, prefilled once, then decoded step-by-step with the
per-layer KV caches (ring buffers on windowed layers). Decode steps are a
single jit'd function; batching amortizes the weights read (the dominant
decode roofline term).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    temperature: float = 0.0    # 0 = greedy
    top_k: int = 0
    out: Optional[np.ndarray] = None


class BatchServer:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 4096,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.mesh = mesh

    def serve(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(requests)
        s_max = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, s_max), np.int32)
        for i, r in enumerate(requests):
            toks[i, s_max - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                        jnp.dtype(cfg.dtype))

        max_new = max(r.max_new for r in requests)
        temperature = max(r.temperature for r in requests)
        top_k = max(r.top_k for r in requests)
        t0 = time.time()
        out = model_lib.generate(
            self.params, batch, cfg, max_new=max_new,
            max_len=min(self.max_len, s_max + max_new),
            temperature=temperature, top_k=top_k, mesh=self.mesh)
        out = np.asarray(out)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = out[i, :r.max_new]
        tput = b * max_new / dt
        print(f"served {b} requests x {max_new} tokens "
              f"in {dt:.2f}s ({tput:.1f} tok/s)")
        return requests


def main():
    import argparse
    from repro import configs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchServer(cfg, params)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=args.prompt_len
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.batch)]
    server.serve(reqs)
    for i, r in enumerate(reqs[:2]):
        print(f"req {i}: {r.out[:10]}")


if __name__ == "__main__":
    main()
