"""Input specs (ShapeDtypeStruct stand-ins — weak-type-correct, shardable,
zero allocation) and sharding assignments for every (arch x shape) cell,
plus the jit-able train / prefill / decode step builders.

Sharding policy (see utils/sharding.py for the param side):
  * batch dim    -> ("pod", "data") when divisible;
  * KV heads     -> "model" when divisible, else the cache SEQUENCE dim
    goes to "model" (flash-decoding/split-K style sequence parallelism —
    this is what keeps decode_32k/long_500k per-chip KV small for kv=8
    archs on a 16-wide model axis);
  * SSM/RG-LRU state channels -> "model" when divisible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.common import SHAPES
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.utils.sharding import MeshAxes, param_specs

Array = jnp.ndarray
F32 = jnp.float32
I32 = jnp.int32


# ======================================================================
# batch input specs
# ======================================================================
def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    seq, gbatch, kind = SHAPES[shape_name]
    s_text = seq - (cfg.n_patches or 0)
    out: Dict[str, Any] = {}
    if kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((gbatch, s_text), I32)
        out["labels"] = jax.ShapeDtypeStruct((gbatch, s_text), I32)
    elif kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((gbatch, s_text), I32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((gbatch, 1), I32)
    if cfg.family == "encdec" and kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (gbatch, cfg.enc_seq, cfg.d_model), F32)
    if cfg.n_patches and kind != "decode":
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (gbatch, cfg.n_patches, cfg.d_model), F32)
    return out


def _batch_axes(mesh: Mesh, dim: int) -> Optional[Tuple[str, ...]]:
    axes = MeshAxes().present(mesh).batch
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes if (axes and size > 1 and dim % size == 0) else None


def batch_shardings(specs, mesh: Mesh):
    def one(leaf):
        lead = _batch_axes(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(lead, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, specs)


# ======================================================================
# cache specs + shardings
# ======================================================================
def cache_specs(cfg: ModelConfig, shape_name: str):
    seq, gbatch, kind = SHAPES[shape_name]
    assert kind == "decode"
    cache = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, gbatch, seq))
    extras = {}
    if cfg.family == "encdec":
        # memory_kv from the (stubbed) encoder output
        def mk():
            from repro.models import encdec
            params = encdec.init_params(jax.random.PRNGKey(0), cfg)
            mem = jnp.zeros((gbatch, cfg.enc_seq, cfg.d_model),
                            jnp.dtype(cfg.dtype))
            return encdec.encode_memory_kv(params, mem, cfg)
        extras["memory_kv"] = jax.eval_shape(mk)
    return cache, extras


def _model_axis(mesh: Mesh) -> Optional[str]:
    axes = MeshAxes().present(mesh)
    return axes.model


def cache_shardings(cache, cfg: ModelConfig, mesh: Mesh):
    """Path/shape-driven specs for KV caches and recurrent states."""
    model = _model_axis(mesh)
    msize = mesh.shape[model] if model else 1

    def one(path_tuple, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", "")))
                for k in path_tuple]
        name = keys[-1] if keys else ""
        stacked = (keys and keys[0] in ("units",)) or \
            (name in ("k", "v") and leaf.ndim == 5)  # stacked memory_kv
        b_dim = 1 if stacked else 0
        dims: list = [None] * leaf.ndim
        if leaf.shape[b_dim] > 1:
            dims[b_dim] = _batch_axes(mesh, leaf.shape[b_dim])
        rest = list(range(b_dim + 1, leaf.ndim))
        if name in ("k", "v") and len(rest) == 3:
            # cache layout (KVH, S, hd); memory_kv layout (T, KVH, hd)
            if leaf.shape[rest[0]] == cfg.n_kv_heads:
                kvh_d, s_d = rest[0], rest[1]
            else:
                s_d, kvh_d = rest[0], rest[1]
            if model and leaf.shape[kvh_d] % msize == 0:
                dims[kvh_d] = model
            elif model and leaf.shape[s_d] % msize == 0:
                dims[s_d] = model      # split-K sequence parallelism
        elif name in ("k_scale", "v_scale") and len(rest) == 2:
            kvh_d, s_d = rest
            if model and leaf.shape[kvh_d] % msize == 0:
                dims[kvh_d] = model
            elif model and leaf.shape[s_d] % msize == 0:
                dims[s_d] = model
        elif name == "state" and len(rest) == 3:
            nh_d = rest[0]
            if model and leaf.shape[nh_d] % msize == 0:
                dims[nh_d] = model
        elif name == "h" and len(rest) == 1:
            if model and leaf.shape[rest[0]] % msize == 0:
                dims[rest[0]] = model
        elif name == "conv" and len(rest) == 2:
            c_d = rest[1]
            if model and leaf.shape[c_d] % msize == 0:
                dims[c_d] = model
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache)


# ======================================================================
# step builders
# ======================================================================
def serve_config(cfg: ModelConfig) -> ModelConfig:
    """Serving runs bf16 params (no optimizer master copy)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def opt_config(cfg: ModelConfig, **over) -> adamw.AdamWConfig:
    big = cfg.param_count() > 5e10
    kw = dict(quantize_moments=big)
    kw.update(over)
    return adamw.AdamWConfig(**kw)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    mesh: Optional[Mesh] = None, accum_steps: int = 1):
    """One optimizer step. ``accum_steps`` > 1 microbatches the global
    batch along dim 0 with a lax.scan of grad accumulations — activation
    working set shrinks ~accum_steps x at equal math (the knob that fits
    the heaviest train cells; EXPERIMENTS.md §Perf)."""
    def grads_of(params, batch):
        def loss_fn(p):
            return model_lib.train_loss(p, batch, cfg, mesh)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   {"g": g, "l": l, "m": m})
                return acc, None

            zero = {"g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params),
                    "l": jnp.zeros((), jnp.float32),
                    "m": {"ce": jnp.zeros((), jnp.float32),
                          "z_loss": jnp.zeros((), jnp.float32),
                          "moe_aux": jnp.zeros((), jnp.float32)}}
            acc, _ = jax.lax.scan(body, zero, micro)
            scale = 1.0 / accum_steps
            grads = jax.tree.map(lambda g: g * scale, acc["g"])
            loss = acc["l"] * scale
            metrics = jax.tree.map(lambda m: m * scale, acc["m"])
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, {**metrics, **om, "loss": loss}
    return train_step


def make_prefill_fn(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def prefill_fn(params, batch):
        logits, _, _ = model_lib.forward(params, batch, cfg, mesh=mesh)
        return logits[:, -1]
    return prefill_fn


def make_decode_fn(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def decode_fn(params, tokens, pos, cache, extras):
        logits, cache = model_lib.decode_step(
            params, tokens, pos, cfg, cache, extras=extras, mesh=mesh)
        return logits, cache
    return decode_fn


def param_shardings(params_or_specs, mesh: Mesh):
    specs = param_specs(params_or_specs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def opt_state_shardings(opt_state_specs, params_specs, mesh: Mesh):
    """m/v mirror the param specs (the int8 layout is shape-preserving, so
    ``q`` takes the param's spec and ``scale`` the spec minus its last
    dim); step replicates."""
    p_sh = param_shardings(params_specs, mesh)

    def build(sub):
        if isinstance(sub, dict) and set(sub) == {"q", "scale"}:
            q_sh = jax.tree.map(lambda _l, s: s, sub["q"], p_sh)
            s_sh = jax.tree.map(
                lambda _l, s: NamedSharding(
                    mesh, P(*(list(s.spec[:-1]) + [None]))
                    if len(s.spec) else P()),
                sub["scale"], p_sh)
            return {"q": q_sh, "scale": s_sh}
        return jax.tree.map(lambda _l, s: s, sub, p_sh)

    out = {"step": NamedSharding(mesh, P())}
    for k in ("m", "v"):
        out[k] = build(opt_state_specs[k])
    if "ef" in opt_state_specs:
        out["ef"] = jax.tree.map(lambda _l, s: s, opt_state_specs["ef"], p_sh)
    return out


# ======================================================================
# the full cell assembly (used by dryrun and benchmarks)
# ======================================================================
def model_flops_for(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (single forward/decode token),
    with N = active params for MoE."""
    seq, gbatch, kind = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n_active * gbatch * seq
    if kind == "prefill":
        return 2.0 * n_active * gbatch * seq
    return 2.0 * n_active * gbatch * 1  # one decode token per sequence


@functools.lru_cache(maxsize=None)
def _param_struct(arch: str, serve: bool):
    cfg = configs.get_config(arch)
    if serve:
        cfg = serve_config(cfg)
    return jax.eval_shape(
        lambda k: model_lib.init_params(k, cfg), jax.random.PRNGKey(0))


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               seq_shard: bool = False, kv_quant: bool = False,
               accum_steps: int = 1):
    """Returns (fn, arg_structs, in_shardings, donate_argnums, meta)
    ready for jax.jit(...).lower(*arg_structs)."""
    cfg = configs.get_config(arch)
    if seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard=True)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    seq, gbatch, kind = SHAPES[shape_name]

    if kind == "train":
        p_struct = _param_struct(arch, serve=False)
        ocfg = opt_config(cfg)
        o_struct = jax.eval_shape(lambda p: adamw.init(ocfg, p), p_struct)
        b_spec = batch_specs(cfg, shape_name)
        fn = make_train_step(cfg, ocfg, mesh, accum_steps=accum_steps)
        p_sh = param_shardings(p_struct, mesh)
        in_sh = (p_sh, opt_state_shardings(o_struct, p_struct, mesh),
                 batch_shardings(b_spec, mesh))
        return (fn, (p_struct, o_struct, b_spec), in_sh, (0, 1),
                {"cfg": cfg, "kind": kind})

    scfg = serve_config(cfg)
    p_struct = _param_struct(arch, serve=True)
    p_sh = param_shardings(p_struct, mesh)

    if kind == "prefill":
        b_spec = batch_specs(scfg, shape_name)
        fn = make_prefill_fn(scfg, mesh)
        in_sh = (p_sh, batch_shardings(b_spec, mesh))
        return fn, (p_struct, b_spec), in_sh, (), {"cfg": scfg, "kind": kind}

    # decode
    cache, extras = cache_specs(scfg, shape_name)
    tok = jax.ShapeDtypeStruct((gbatch, 1), I32)
    pos = jax.ShapeDtypeStruct((), I32)
    fn = make_decode_fn(scfg, mesh)
    cache_sh = cache_shardings(cache, scfg, mesh)
    extras_sh = cache_shardings(extras, scfg, mesh)
    tok_sh = NamedSharding(mesh, P(_batch_axes(mesh, gbatch), None))
    in_sh = (p_sh, tok_sh, NamedSharding(mesh, P()), cache_sh, extras_sh)
    return (fn, (p_struct, tok, pos, cache, extras), in_sh, (3,),
            {"cfg": scfg, "kind": kind})
