import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape
x mesh) cell against the production mesh with 512 placeholder host
devices; print memory_analysis() (proves it fits) and cost_analysis()
(FLOPs/bytes for the roofline), plus the parsed collective schedule.

Run one cell:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
                                  [--multi-pod]
Run the matrix: python -m repro.launch.dryrun --all --out results.jsonl
(The matrix driver execs one fresh process per cell so compile arenas are
reclaimed between 100B-scale lowers.)
"""


def run_cell(arch: str, shape: str, multi_pod: bool,
             data: int = 16, model: int = 16,
             seq_shard: bool = False, kv_quant: bool = False,
             accum: int = 1) -> dict:
    import jax
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs as specs_lib
    from repro.utils import hlo as hlo_lib

    t0 = time.time()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod, data=data,
                                         model=model)
    n_chips = mesh.size
    fn, args, in_sh, donate, meta = specs_lib.build_cell(
        arch, shape, mesh, seq_shard=seq_shard, kv_quant=kv_quant,
        accum_steps=accum)

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    pod = 'multi-pod 2x16x16' if multi_pod else 'single-pod 16x16'
    print(f"== {arch} x {shape} on {pod} ({n_chips} chips)")
    print(mem)
    ca = compiled.cost_analysis() or {}
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    txt = compiled.as_text()
    coll = hlo_lib.collective_bytes(txt)
    mf = specs_lib.model_flops_for(meta["cfg"], shape)
    roof = hlo_lib.roofline_from_compiled(compiled, n_chips,
                                          model_flops=mf, hlo_text=txt)

    def _b(x):
        return int(x) if x else 0

    per_dev_bytes = (_b(getattr(mem, "argument_size_in_bytes", 0))
                     + _b(getattr(mem, "temp_size_in_bytes", 0))
                     + _b(getattr(mem, "output_size_in_bytes", 0))
                     - _b(getattr(mem, "alias_size_in_bytes", 0)))
    mesh_name = (f"2x{data}x{model}" if multi_pod else f"{data}x{model}")
    row = {
        "arch": arch, "shape": shape,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "ok": True,
        "per_device_bytes": per_dev_bytes,
        "collectives": coll,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **roof.row(),
    }
    print(json.dumps(row))
    return row


ALL_CELLS_NOTE = """Matrix: 10 archs x 4 shapes, long_500k only for
sub-quadratic archs (DESIGN.md §Arch-applicability), x 2 meshes."""


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data", type=int, default=16,
                    help="data-axis size (perf experiments)")
    ap.add_argument("--model", type=int, default=16,
                    help="model-axis size (perf experiments)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="Megatron-SP activation boundaries (perf)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (perf; decode cells)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (perf)")
    ap.add_argument("--all", action="store_true", help=ALL_CELLS_NOTE)
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--meshes", default="single,multi",
                    help="comma list: single,multi")
    args = ap.parse_args()

    if not args.all:
        row = run_cell(args.arch, args.shape, args.multi_pod,
                       data=args.data, model=args.model,
                       seq_shard=args.seq_shard, kv_quant=args.kv_quant,
                       accum=args.accum)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
        return

    # matrix driver: one subprocess per cell (fresh compile arena)
    import subprocess
    import sys
    from repro import configs
    meshes = args.meshes.split(",")
    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    for arch, shape, _ in configs.cells():
        for mp in meshes:
            mesh_name = "2x16x16" if mp == "multi" else "16x16"
            if (arch, shape, mesh_name) in done:
                print(f"skip {arch} x {shape} x {mesh_name} (done)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp == "multi":
                cmd.append("--multi-pod")
            if args.out:
                cmd += ["--out", args.out]
            print("RUN", " ".join(cmd), flush=True)
            rc = subprocess.run(cmd).returncode
            if rc != 0 and args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "ok": False, "rc": rc}) + "\n")


if __name__ == "__main__":
    try:
        main()
    except Exception:
        traceback.print_exc()
        raise
