"""Production mesh builders. Functions, not module constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax

from repro.utils.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False, data: int = 16,
                         model: int = 16):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

    ``data``/``model`` may be re-split (same chip count) for the §Perf
    mesh-layout experiments — e.g. (data=64, model=4) narrows TP, which
    shrinks the per-device activation all-reduce payload linearly
    (payload ~ B/dp) at equal compute."""
    assert data * model == 256, "single pod is 256 chips"
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    assert n % model_axis == 0
    shape = (n // model_axis, model_axis)
    return make_mesh(shape, ("data", "model"))
