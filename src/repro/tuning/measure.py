"""Measurement discipline for the autotuner.

Two tools, both reused by the benchmark suite (``benchmarks/common.py``
re-exports them):

* :func:`time_interleaved` — interleaved min-of-rounds timing. All
  candidates are warmed (compile excluded), then timed round-robin for
  ``rounds`` passes; a candidate's score is its *minimum* over rounds.
  Interleaving spreads slow drift (thermal, other tenants) evenly over
  the field instead of biasing whichever candidate ran last, and min-of
  rejects one-sided noise (a measurement can only be too slow, never
  too fast).

* :func:`roofline_step_seconds` — a memory-bandwidth lower bound on one
  fused stencil launch, from the measured copy bandwidth of this host.
  A candidate that beats this bound did not do the work (caching
  artifact, dead-code elimination, wrong shapes) — the search logs a
  warning and distrusts the number rather than shipping it.
"""
from __future__ import annotations

import logging
import math
import time
from typing import Callable, Dict, Iterable, Mapping, Optional

log = logging.getLogger("repro.tuning")

_bandwidth_cache: Dict[int, float] = {}


def geomean(xs: Iterable[float]) -> float:
    vals = [float(x) for x in xs]
    if not vals:
        return 1.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def time_interleaved(fns: Mapping[str, Callable[[], object]],
                     rounds: int = 5,
                     warmup: int = 2) -> Dict[str, float]:
    """Best-of-``rounds`` wall time per zero-arg callable, interleaved.

    Each callable is invoked ``warmup`` times first (unmeasured —
    absorbs compilation), then the field is timed round-robin; the
    returned score is each candidate's minimum single-call seconds.
    Device work is synchronized with ``jax.block_until_ready`` so async
    dispatch does not undercount.
    """
    import jax
    for fn in fns.values():
        for _ in range(max(1, warmup)):
            jax.block_until_ready(fn())
    best = {name: float("inf") for name in fns}
    for _ in range(max(1, rounds)):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def measured_bandwidth_gbs(nbytes: int = 1 << 24,
                           rounds: int = 5) -> float:
    """Achievable device copy bandwidth (GB/s, read+write counted),
    measured once per process with a float32 roundtrip copy."""
    if nbytes in _bandwidth_cache:
        return _bandwidth_cache[nbytes]
    import jax
    import jax.numpy as jnp
    n = nbytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    copy = jax.jit(lambda a: a + 1.0)
    jax.block_until_ready(copy(x))
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(copy(x))
        best = min(best, time.perf_counter() - t0)
    gbs = (2 * nbytes) / best / 1e9
    _bandwidth_cache[nbytes] = gbs
    return gbs


def roofline_step_seconds(n_blocks: int, rho: int, k: int,
                          itemsize: int = 4,
                          bandwidth_gbs: Optional[float] = None) -> float:
    """Memory-bandwidth lower bound on one *advanced step* of a depth-k
    fused launch over a compact layout of ``n_blocks`` blocks of side
    ``rho``: the launch must at minimum read the (rho+2k)-wide haloed
    state and write the rho-wide core, amortized over the k steps it
    advances. Loose by design — it only has to catch measurements that
    are impossibly fast, not predict real kernels.
    """
    if bandwidth_gbs is None:
        bandwidth_gbs = measured_bandwidth_gbs()
    w = rho + 2 * k
    bytes_moved = n_blocks * (w * w + rho * rho) * itemsize
    return bytes_moved / (bandwidth_gbs * 1e9) / k
