"""``EngineSpec`` — the canonical engine-configuration identity.

One frozen, hashable, JSON-serializable dataclass names a simulation
configuration everywhere in the stack:

  * ``make_engine(spec)`` builds an engine from it (core/stencil.py);
  * the ``BatchedRunner`` LRU keys compiled entries on
    ``spec.normalize()`` (workloads/runner.py);
  * ``SimRequest.bucket`` batches serving traffic by it
    (serving/types.py);
  * the tuning table (tuning/table.py) persists autotuned winners
    under ``spec.tuning_key()``.

``normalize()`` is the single normalization code path the runner's old
``_resolve_key``/``_resolve_k`` pair and ``make_engine``'s ``'pallas'``
alias rewrite collapsed into: it rewrites kind aliases, zeroes knobs
that do not apply to the kind (fusion depth on non-block kinds,
exchange/mesh on single-device kinds, macro-tile packing on non-MXU
kinds), and resolves the tunable knobs left ``None`` through the
precedence rule

    explicit argument  >  tuning-table hit  >  static heuristic

counting one ``engine.tune.{hit,miss,fallback}`` telemetry outcome per
table consult. Two configurations batch/cache/serve together exactly
when their normalized specs compare equal.

The fractal identity is ``(s, mask-or-name)``: registry fractals
serialize by name, anything else by its slot-position mask — both
reconstructible via ``build_frac()`` without the original object.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple, Union

#: every engine kind ``make_engine`` accepts, post-alias (the kind
#: registry; tests iterate this)
KINDS: Tuple[str, ...] = (
    "bb", "lambda", "cell", "block",
    "pallas-blocks", "pallas-strips", "pallas-fused", "pallas-mxu",
    "dist-block", "dist-fused", "dist-mxu",
    "bb3d", "cell3d", "block3d", "pallas-3d", "pallas-3d-mxu",
)

#: kind aliases rewritten by ``canonical()`` — shared by ``make_engine``
#: and the runner so both label telemetry with the same kind string
KIND_ALIASES: Dict[str, str] = {"pallas": "pallas-strips"}

#: kinds with block tiles: these fuse over depth-k halos (same prefix
#: rule the runner used)
_BLOCK_PREFIX = ("block", "pallas", "dist")

#: kinds whose kernels lane-pack P blocks per MXU macro-tile
MXU_KINDS = frozenset({"pallas-mxu", "dist-mxu", "pallas-3d-mxu"})

_EXCHANGES = ("auto", "p2p", "gather")

#: sentinel: "consult the active default tuning table"
_DEFAULT_TABLE = object()

FracId = Union[str, Tuple[Tuple[int, ...], ...]]


def is_block_kind(kind: str) -> bool:
    return kind.startswith(_BLOCK_PREFIX)


def is_dist_kind(kind: str) -> bool:
    return kind.startswith("dist-")


def _frac_identity(frac) -> Tuple[int, FracId]:
    """(s, mask-or-name) of a fractal object: the registry name when the
    object IS that registry entry, else its slot-position mask."""
    s = int(frac.s)
    name = getattr(frac, "name", None)
    positions = tuple(tuple(int(c) for c in p) for p in frac.positions)
    if name is not None:
        from repro.core.fractals import REGISTRY
        from repro.core.fractals3d import REGISTRY3D
        reg = REGISTRY.get(name) or REGISTRY3D.get(name)
        if reg is not None and reg.s == s and tuple(
                tuple(int(c) for c in p) for p in reg.positions
        ) == positions:
            return s, name
    return s, positions


def _mesh_shape(mesh) -> Optional[Tuple[int, ...]]:
    """Bucket a mesh (jax Mesh | shape tuple | None) to its shape."""
    if mesh is None:
        return None
    if isinstance(mesh, (tuple, list)):
        return tuple(int(d) for d in mesh)
    return tuple(int(d) for d in mesh.devices.shape)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Canonical engine-configuration identity (see module docstring).

    ``frac`` is a registry fractal name or a slot-position mask (tuple
    of (x, y[, z]) coordinates); ``s`` the fractal's per-level scaling
    factor; ``workload`` a registry workload name. ``fusion_k``,
    ``macro_p`` and ``exchange`` are the tunable knobs (``None`` /
    ``'auto'`` = resolve via table-then-heuristic in ``normalize``);
    ``mesh_shape``/``axis`` bucket the dist-kind device mesh.
    """

    kind: str
    s: int
    frac: FracId
    r: int
    m: int = 0
    workload: str = "life"
    fusion_k: Optional[int] = None
    macro_p: Optional[int] = None
    exchange: str = "auto"
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis: str = "data"

    # ------------------------------------------------------ construction
    @classmethod
    def from_args(cls, kind: str, frac, r: int, m: int = 0,
                  workload=None, fusion_k: Optional[int] = None,
                  macro_p: Optional[int] = None, mesh=None,
                  axis: str = "data",
                  exchange: str = "auto") -> "EngineSpec":
        """Capture the identity of a legacy ``make_engine``/runner
        argument list (fractal/workload/mesh *objects*)."""
        s, ident = _frac_identity(frac)
        wl_name = workload if isinstance(workload, str) else (
            "life" if workload is None else workload.name)
        return cls(kind=kind, s=s, frac=ident, r=int(r), m=int(m),
                   workload=wl_name, fusion_k=fusion_k, macro_p=macro_p,
                   exchange=exchange, mesh_shape=_mesh_shape(mesh),
                   axis=axis)

    # ------------------------------------------------------- predicates
    @property
    def is_block(self) -> bool:
        return is_block_kind(self.kind)

    @property
    def is_dist(self) -> bool:
        return is_dist_kind(self.kind)

    @property
    def is_mxu(self) -> bool:
        return self.kind in MXU_KINDS or (
            KIND_ALIASES.get(self.kind, self.kind) in MXU_KINDS)

    @property
    def rho(self) -> int:
        """Block tile side: s**m (1 for non-block kinds)."""
        return self.s ** self.m if self.is_block else 1

    # ----------------------------------------------------- normalization
    def canonical(self) -> "EngineSpec":
        """Alias-rewritten, knob-zeroed form (validation included):

        * ``'pallas'`` -> ``'pallas-strips'`` for every consumer (the
          runner used to rewrite it while direct ``make_engine`` calls
          did not, so the two disagreed on telemetry kind labels);
        * non-block kinds have nothing to fuse: ``fusion_k`` -> 1,
          ``m`` -> 0 (no block tiles, so equal configurations share one
          slot instead of one per supplied ``m``);
        * ``exchange``/``mesh_shape``/``axis`` are dist-only knobs,
          zeroed elsewhere; ``macro_p`` is MXU-only.
        """
        kind = KIND_ALIASES.get(self.kind, self.kind)
        if kind not in KINDS:
            raise ValueError(
                f"unknown engine kind {self.kind!r}; known: "
                f"{sorted(KINDS + tuple(KIND_ALIASES))}")
        if self.fusion_k is not None and self.fusion_k < 1:
            raise ValueError(
                f"fusion_k must be >= 1, got {self.fusion_k}")
        if self.macro_p is not None and self.macro_p < 1:
            raise ValueError(
                f"macro_p must be >= 1, got {self.macro_p}")
        if self.exchange not in _EXCHANGES:
            raise ValueError(
                f"exchange must be one of {_EXCHANGES}, "
                f"got {self.exchange!r}")
        block = is_block_kind(kind)
        dist = is_dist_kind(kind)
        return dataclasses.replace(
            self,
            kind=kind,
            m=self.m if block else 0,
            fusion_k=self.fusion_k if block else 1,
            macro_p=self.macro_p if kind in MXU_KINDS else None,
            exchange=self.exchange if dist else "auto",
            mesh_shape=self.mesh_shape if dist else None,
            axis=self.axis if dist else "data",
        )

    def normalize(self, table: Any = _DEFAULT_TABLE) -> "EngineSpec":
        """The single configuration identity: ``canonical()`` with every
        tunable knob resolved to a concrete value via

            explicit argument > tuning-table hit > static heuristic.

        ``table``: the default sentinel consults the active table
        (tuning/table.py — shipped ``tables/default.json`` unless
        overridden by ``SQUEEZE_TUNING_TABLE`` or disabled by
        ``SQUEEZE_TUNING=off``); pass an explicit ``TuningTable`` or
        ``None`` (heuristic only, no telemetry) to pin it. One
        ``engine.tune.{hit,miss,fallback}`` counter is recorded per
        table consult. Idempotent: a fully resolved spec passes through
        unchanged without consulting the table.
        """
        spec = self.canonical()
        if not spec.is_block:
            return spec
        k, p, ex = spec.fusion_k, spec.macro_p, spec.exchange
        need_k = k is None
        need_p = p is None and spec.kind in MXU_KINDS
        need_ex = ex == "auto" and spec.is_dist
        if need_k or need_p or need_ex:
            entry = None
            if table is not None:
                from repro.tuning.table import consult
                entry = consult(spec, table if table is not _DEFAULT_TABLE
                                else None)
            if entry is not None:
                if need_k and entry.fusion_k is not None:
                    # the fused kernels cap k at rho (one block ring)
                    k = max(1, min(entry.fusion_k, spec.rho))
                if need_p and entry.macro_p is not None:
                    p = entry.macro_p
                if need_ex and entry.exchange in ("p2p", "gather"):
                    ex = entry.exchange
            if k is None:
                from repro.core.stencil import default_fusion_k
                k = default_fusion_k(spec.rho)
        return dataclasses.replace(spec, fusion_k=k, macro_p=p,
                                   exchange=ex)

    def tuning_key(self) -> str:
        """Stable JSON string keying this configuration in a tuning
        table: the canonical identity *minus* the tunable knobs (which
        are the table's values, not its key), mesh bucketed by shape."""
        c = self.canonical()
        ident = {
            "kind": c.kind, "s": c.s,
            "frac": c.frac if isinstance(c.frac, str)
            else [list(p) for p in c.frac],
            "r": c.r, "m": c.m, "workload": c.workload,
            "mesh_shape": (list(c.mesh_shape)
                           if c.mesh_shape is not None else None),
            "axis": c.axis,
        }
        return json.dumps(ident, sort_keys=True, separators=(",", ":"))

    # ------------------------------------------------- JSON round-trip
    def to_json(self) -> Dict[str, Any]:
        """Plain-JSON dict; ``from_json`` round-trips it exactly."""
        d = dataclasses.asdict(self)
        if not isinstance(self.frac, str):
            d["frac"] = [list(p) for p in self.frac]
        if self.mesh_shape is not None:
            d["mesh_shape"] = list(self.mesh_shape)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "EngineSpec":
        frac = d["frac"]
        if not isinstance(frac, str):
            frac = tuple(tuple(int(c) for c in p) for p in frac)
        mesh = d.get("mesh_shape")
        return cls(
            kind=d["kind"], s=int(d["s"]), frac=frac, r=int(d["r"]),
            m=int(d.get("m", 0)), workload=d.get("workload", "life"),
            fusion_k=d.get("fusion_k"), macro_p=d.get("macro_p"),
            exchange=d.get("exchange", "auto"),
            mesh_shape=tuple(int(x) for x in mesh)
            if mesh is not None else None,
            axis=d.get("axis", "data"))

    # ------------------------------------------- object reconstruction
    def build_frac(self):
        """The fractal object this spec names (registry lookup for
        name identities, reconstruction for mask identities)."""
        from repro.core.fractals import REGISTRY, NBBFractal
        from repro.core.fractals3d import REGISTRY3D, NBBFractal3D
        if isinstance(self.frac, str):
            frac = REGISTRY.get(self.frac) or REGISTRY3D.get(self.frac)
            if frac is None:
                raise KeyError(
                    f"unknown fractal name {self.frac!r} in EngineSpec "
                    f"(custom fractals serialize by position mask)")
            if frac.s != self.s:
                raise ValueError(
                    f"fractal {self.frac!r} has s={frac.s}, spec says "
                    f"s={self.s}")
            return frac
        ndim = len(self.frac[0])
        name = f"nbb{ndim}d-s{self.s}-k{len(self.frac)}"
        cls = NBBFractal3D if ndim == 3 else NBBFractal
        return cls(name, self.s, self.frac)

    def build_workload(self):
        """The workload object this spec names (registry lookup; pass
        custom workload objects explicitly to ``make_engine``/runner
        calls — they serialize by name only)."""
        from repro.workloads import rules
        registry = dict(rules.WORKLOADS)
        for extra in (rules.LIFE3D, rules.HEAT3D):
            registry.setdefault(extra.name, extra)
        try:
            return registry[self.workload]
        except KeyError:
            raise KeyError(
                f"unknown workload name {self.workload!r} in EngineSpec; "
                f"registry has {sorted(registry)} (pass the workload "
                f"object explicitly for custom workloads)") from None

    def build_mesh(self):
        """A device mesh matching ``mesh_shape``/``axis`` (None when the
        spec has no mesh — dist engines then default to all devices)."""
        if self.mesh_shape is None:
            return None
        import math

        import jax
        from jax.sharding import Mesh

        import numpy as np
        n = math.prod(self.mesh_shape)
        devs = jax.devices()
        if len(devs) < n:
            raise ValueError(
                f"spec wants a {self.mesh_shape} mesh ({n} devices), "
                f"but only {len(devs)} are available")
        names = tuple(f"{self.axis}{i}" if i else self.axis
                      for i in range(len(self.mesh_shape)))
        return Mesh(np.array(devs[:n]).reshape(self.mesh_shape), names)
