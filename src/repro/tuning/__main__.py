"""``python -m repro.tuning`` — re-tune, inspect, and diff tables.

Subcommands:

* ``tune``  — run the sweep for a preset (or explicit spec JSON) and
  write the winners to a table file, printing the diff against the
  table previously at that path;
* ``show``  — print a table (default: the shipped one);
* ``diff``  — key-level diff of two table files.

Examples::

    python -m repro.tuning tune --preset default \
        --out src/repro/tuning/tables/default.json
    python -m repro.tuning tune --spec '{"kind":"block","s":2,...}'
    python -m repro.tuning diff old.json new.json
    python -m repro.tuning show
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.tuning.presets import preset_specs
from repro.tuning.search import tune_many
from repro.tuning.spec import EngineSpec
from repro.tuning.table import DEFAULT_TABLE_PATH, TuningTable


def _cmd_tune(args: argparse.Namespace) -> int:
    specs = []
    if args.preset:
        specs += preset_specs(args.preset)
    for raw in args.spec or []:
        specs.append(EngineSpec.from_json(json.loads(raw)))
    if not specs:
        print("nothing to tune: pass --preset and/or --spec",
              file=sys.stderr)
        return 2
    old = None
    if args.out and os.path.exists(args.out):
        old = TuningTable.load(args.out)
    base = TuningTable(old.entries) if (old and args.merge) else None
    table, results = tune_many(
        specs, steps=args.steps, rounds=args.rounds, seed=args.seed,
        max_candidates=args.max_candidates, table=base)
    for res in results:
        mark = " [SUSPECT]" if res.suspect else ""
        print(f"{res.spec.tuning_key()}\n    best={res.best.label} "
              f"baseline={res.baseline.label} "
              f"speedup={res.speedup:.2f}x{mark}")
        if res.parity_failures:
            print(f"    parity failures: {res.parity_failures}")
    if args.out:
        table.meta.setdefault("generator", "python -m repro.tuning")
        table.save(args.out)
        print(f"wrote {len(table)} entries to {args.out}")
        if old is not None:
            print(json.dumps(table.diff(old), indent=2))
    else:
        print(json.dumps(table.to_json(), indent=2))
    return 0


def _cmd_show(args: argparse.Namespace) -> int:
    table = TuningTable.load(args.path)
    print(json.dumps(table.to_json(), indent=2))
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    old = TuningTable.load(args.old)
    new = TuningTable.load(args.new)
    diff = new.diff(old)
    print(json.dumps(diff, indent=2))
    return 1 if (diff["added"] or diff["removed"] or diff["changed"]) \
        else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tuning",
                                 description=__doc__.split("\n\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("tune", help="run the sweep and write a table")
    t.add_argument("--preset", choices=["ci", "default"], default=None)
    t.add_argument("--spec", action="append",
                   help="EngineSpec as JSON (repeatable)")
    t.add_argument("--out", default=None,
                   help="table path to write (default: print)")
    t.add_argument("--merge", action="store_true",
                   help="merge into the existing table at --out")
    t.add_argument("--steps", type=int, default=8)
    t.add_argument("--rounds", type=int, default=3)
    t.add_argument("--seed", type=int, default=0)
    t.add_argument("--max-candidates", type=int, default=None)
    t.set_defaults(fn=_cmd_tune)

    s = sub.add_parser("show", help="print a table")
    s.add_argument("path", nargs="?", default=DEFAULT_TABLE_PATH)
    s.set_defaults(fn=_cmd_show)

    d = sub.add_parser("diff",
                       help="diff two tables (exit 1 on differences)")
    d.add_argument("old")
    d.add_argument("new")
    d.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
