"""Versioned tuning tables: persisted autotuner winners.

A :class:`TuningTable` maps normalized :class:`~repro.tuning.spec.
EngineSpec` identities (``spec.tuning_key()`` — canonical identity
minus the tunable knobs, mesh bucketed by shape) to a
:class:`TableEntry` holding the measured-best knob values. Tables are
plain versioned JSON so they can ship in the repo, diff cleanly, and
survive refactors: ``src/repro/tuning/tables/default.json`` is the
table shipped with the package and consulted by ``EngineSpec.
normalize()`` whenever a tunable knob is left unset.

Environment knobs:

* ``SQUEEZE_TUNING=off|0|false|no`` disables table consults entirely —
  every lookup records an ``engine.tune.fallback`` and the static
  heuristics apply (the pre-tuner behavior, used by tests that pin
  heuristic-resolved defaults);
* ``SQUEEZE_TUNING_TABLE=/path/to/table.json`` swaps the shipped table
  for a custom one (unreadable/invalid paths degrade to fallback with
  a one-time warning, never an exception).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.tuning.spec import EngineSpec

log = logging.getLogger("repro.tuning")

#: bump when the on-disk schema changes; loaders reject other versions
TABLE_VERSION = 1

#: shipped default table (packaged with the repo)
DEFAULT_TABLE_PATH = os.path.join(
    os.path.dirname(__file__), "tables", "default.json")

_OFF_VALUES = frozenset({"0", "off", "false", "no"})


def tuning_enabled() -> bool:
    """False when ``SQUEEZE_TUNING`` opts out of table consults."""
    return os.environ.get(
        "SQUEEZE_TUNING", "on").strip().lower() not in _OFF_VALUES


@dataclasses.dataclass(frozen=True)
class TableEntry:
    """Measured-best knob values for one configuration. ``None`` /
    ``'auto'`` fields mean "no opinion" — the next precedence tier
    (static heuristic) resolves them. ``meta`` carries measurement
    provenance (speedup vs heuristic, timing, host) and is ignored by
    lookups."""

    fusion_k: Optional[int] = None
    macro_p: Optional[int] = None
    exchange: str = "auto"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        d = {"fusion_k": self.fusion_k, "macro_p": self.macro_p,
             "exchange": self.exchange}
        if self.meta:
            d["meta"] = self.meta
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TableEntry":
        return cls(fusion_k=d.get("fusion_k"),
                   macro_p=d.get("macro_p"),
                   exchange=d.get("exchange", "auto"),
                   meta=dict(d.get("meta", {})))


class TuningTable:
    """In-memory tuning table with JSON load/save and diff."""

    def __init__(self, entries: Optional[Dict[str, TableEntry]] = None,
                 meta: Optional[Dict[str, Any]] = None) -> None:
        self.entries: Dict[str, TableEntry] = dict(entries or {})
        self.meta: Dict[str, Any] = dict(meta or {})

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[Tuple[str, TableEntry]]:
        return iter(sorted(self.entries.items()))

    # ----------------------------------------------------------- lookup
    def get(self, spec: EngineSpec) -> Optional[TableEntry]:
        return self.entries.get(spec.tuning_key())

    def put(self, spec: EngineSpec, entry: TableEntry) -> None:
        self.entries[spec.tuning_key()] = entry

    # ------------------------------------------------------ persistence
    def to_json(self) -> Dict[str, Any]:
        return {
            "version": TABLE_VERSION,
            "meta": self.meta,
            "entries": {k: e.to_json() for k, e in sorted(
                self.entries.items())},
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TuningTable":
        version = d.get("version")
        if version != TABLE_VERSION:
            raise ValueError(
                f"tuning table version {version!r} unsupported "
                f"(want {TABLE_VERSION})")
        return cls(entries={k: TableEntry.from_json(e)
                            for k, e in d.get("entries", {}).items()},
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path, "r", encoding="utf-8") as fh:
            return cls.from_json(json.load(fh))

    # ------------------------------------------------------------- diff
    def diff(self, other: "TuningTable") -> Dict[str, Any]:
        """Key-level diff vs ``other`` (self = new, other = old):
        added / removed / changed (with old+new knob values)."""
        mine, theirs = self.entries, other.entries
        added = sorted(set(mine) - set(theirs))
        removed = sorted(set(theirs) - set(mine))
        changed = {}
        for key in sorted(set(mine) & set(theirs)):
            a, b = theirs[key], mine[key]
            if (a.fusion_k, a.macro_p, a.exchange) != (
                    b.fusion_k, b.macro_p, b.exchange):
                changed[key] = {"old": a.to_json(), "new": b.to_json()}
        for d in changed.values():
            d["old"].pop("meta", None)
            d["new"].pop("meta", None)
        return {"added": added, "removed": removed, "changed": changed}


# --------------------------------------------------- default-table cache
_cache_lock = threading.Lock()
_cache: Dict[str, Optional[TuningTable]] = {}
_warned: set = set()


def _active_table_path() -> str:
    return os.environ.get("SQUEEZE_TUNING_TABLE", DEFAULT_TABLE_PATH)


def default_table() -> Optional[TuningTable]:
    """The active table (shipped default unless ``SQUEEZE_TUNING_TABLE``
    overrides it), cached per path. ``None`` when the file is missing
    or invalid — consults then degrade to heuristic fallback."""
    path = _active_table_path()
    with _cache_lock:
        if path in _cache:
            return _cache[path]
    try:
        table: Optional[TuningTable] = TuningTable.load(path)
    except FileNotFoundError:
        table = None
        if path != DEFAULT_TABLE_PATH and path not in _warned:
            _warned.add(path)
            log.warning("tuning table %s not found; falling back to "
                        "static heuristics", path)
    except (ValueError, OSError, json.JSONDecodeError) as exc:
        table = None
        if path not in _warned:
            _warned.add(path)
            log.warning("failed to load tuning table %s (%s); falling "
                        "back to static heuristics", path, exc)
    with _cache_lock:
        _cache[path] = table
    return table


def reset_default_table_cache() -> None:
    """Drop the cached table (tests / after ``save`` to the active
    path)."""
    with _cache_lock:
        _cache.clear()
        _warned.clear()


def consult(spec: EngineSpec,
            table: Optional[TuningTable] = None) -> Optional[TableEntry]:
    """One table lookup for ``EngineSpec.normalize()``, with telemetry.

    ``table=None`` means "the active default table". Records exactly one
    ``engine.tune.{hit,miss,fallback}`` counter: *hit* = entry found,
    *miss* = table consulted but has no entry for this key, *fallback* =
    no table was consulted (tuning disabled or table unavailable).
    """
    from repro import obs
    if table is None:
        if not tuning_enabled():
            obs.inc("engine.tune.fallback", kind=spec.kind)
            return None
        table = default_table()
        if table is None:
            obs.inc("engine.tune.fallback", kind=spec.kind)
            return None
    entry = table.get(spec)
    if entry is None:
        obs.inc("engine.tune.miss", kind=spec.kind)
    else:
        obs.inc("engine.tune.hit", kind=spec.kind)
    return entry
