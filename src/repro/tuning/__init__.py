"""Autotuner subsystem: EngineSpec identity, tuning tables, search.

``EngineSpec`` is the single configuration identity used across the
stack (engine construction, runner cache keys, serving buckets, tuning
table keys); ``normalize()`` resolves its tunable knobs via

    explicit argument > tuning-table hit > static heuristic.

See DESIGN.md Section 11 and ``python -m repro.tuning --help``.
"""
from repro.tuning.measure import (geomean, roofline_step_seconds,
                                  time_interleaved)
from repro.tuning.presets import preset_specs
from repro.tuning.search import (Candidate, TuneResult, candidate_space,
                                 tune_many, tune_spec)
from repro.tuning.spec import KIND_ALIASES, KINDS, EngineSpec
from repro.tuning.table import (DEFAULT_TABLE_PATH, TABLE_VERSION,
                                TableEntry, TuningTable, consult,
                                default_table,
                                reset_default_table_cache,
                                tuning_enabled)

__all__ = [
    "EngineSpec", "KINDS", "KIND_ALIASES",
    "TuningTable", "TableEntry", "TABLE_VERSION", "DEFAULT_TABLE_PATH",
    "consult", "default_table", "reset_default_table_cache",
    "tuning_enabled",
    "tune_spec", "tune_many", "candidate_space", "Candidate",
    "TuneResult",
    "time_interleaved", "geomean", "roofline_step_seconds",
    "preset_specs",
]
