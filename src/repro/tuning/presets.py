"""Named tuning config sets.

``default`` is the sweep that produced the shipped table
(``tables/default.json``); ``ci`` is the bounded subset the perf gate
re-measures on every run (``benchmarks/ci_gates.py --gate tuner``).

The shipped set deliberately avoids the configuration identities that
the test suite pins to heuristic-resolved defaults (e.g. block/
sierpinski r=5 m=2 in tests/test_temporal_fusion.py) — those tests
also set ``SQUEEZE_TUNING=off``, but keeping the identities disjoint
means a stale table cannot shadow a heuristic regression either way.
Dist kinds are excluded: their winners depend on the device mesh of
the tuning host, so they are tuned on demand via the CLI rather than
shipped.
"""
from __future__ import annotations

from typing import List

from repro.tuning.spec import EngineSpec


def preset_specs(name: str) -> List[EngineSpec]:
    if name == "ci":
        return [
            EngineSpec("block", 2, "sierpinski", 6, 2, "life"),
            EngineSpec("block", 2, "sierpinski", 6, 2, "heat"),
            EngineSpec("pallas-mxu", 2, "sierpinski", 6, 2, "life"),
        ]
    if name == "default":
        return [
            EngineSpec("block", 2, "sierpinski", 6, 2, "life"),
            EngineSpec("block", 2, "sierpinski", 6, 2, "heat"),
            EngineSpec("block", 2, "sierpinski", 6, 2, "gray-scott"),
            EngineSpec("block", 3, "carpet", 4, 1, "life"),
            EngineSpec("block", 3, "vicsek", 4, 1, "life"),
            EngineSpec("pallas-strips", 2, "sierpinski", 6, 2, "life"),
            EngineSpec("pallas-fused", 2, "sierpinski", 6, 2, "life"),
            EngineSpec("pallas-mxu", 2, "sierpinski", 6, 2, "life"),
            EngineSpec("pallas-mxu", 2, "sierpinski", 6, 2, "heat"),
            EngineSpec("pallas-mxu", 3, "carpet", 4, 1, "life"),
        ]
    raise KeyError(f"unknown preset {name!r}; have: ci, default")
