"""Autotuner search: sweep the discrete knob space per EngineSpec.

For one configuration identity (kind, fractal, r, m, workload, mesh)
the tunable space is

* temporal-fusion depth ``k`` in 1..rho,
* MXU macro-tile packing ``P`` (MXU kinds only): the lane heuristic's
  choice plus halvings/doublings of it, clamped to [1, n_blocks],
* halo-exchange mode in {p2p, gather} (dist kinds only).

Every candidate is parity-gated against the static-heuristic engine on
the same initial state before it may win (bit-exact for integer CA
workloads, allclose for float PDEs) — a fast wrong kernel is not a
winner. Timing is interleaved min-of-rounds (see tuning/measure.py),
and winners are cross-checked against the memory-bandwidth roofline:
a time below the bound indicates a measurement artifact, so the search
logs a warning and flags the result rather than trusting it.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Optional, Tuple

from repro.tuning.measure import (roofline_step_seconds, time_interleaved)
from repro.tuning.spec import EngineSpec
from repro.tuning.table import TableEntry, TuningTable

log = logging.getLogger("repro.tuning")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One concrete knob assignment in the sweep."""

    fusion_k: int
    macro_p: Optional[int] = None
    exchange: str = "auto"

    @property
    def label(self) -> str:
        parts = [f"k{self.fusion_k}"]
        if self.macro_p is not None:
            parts.append(f"P{self.macro_p}")
        if self.exchange != "auto":
            parts.append(self.exchange)
        return "-".join(parts)


@dataclasses.dataclass
class TuneResult:
    """Outcome of tuning one spec: the winner, the heuristic baseline,
    the full timing matrix, and the quality gates that vouch for it."""

    spec: EngineSpec               # canonical identity (knobs cleared)
    best: Candidate
    baseline: Candidate
    times: Dict[str, float]        # candidate label -> best seconds/step
    speedup: float                 # heuristic time / best time (>= 1.0)
    parity_failures: List[str]     # labels rejected by the parity gate
    roofline_s: float              # lower bound, seconds per step
    suspect: bool                  # best beat the roofline bound

    def entry(self) -> TableEntry:
        return TableEntry(
            fusion_k=self.best.fusion_k,
            macro_p=self.best.macro_p,
            exchange=self.best.exchange,
            meta={"speedup": round(self.speedup, 4),
                  "baseline": self.baseline.label,
                  "best_s": self.times[self.best.label],
                  "suspect": self.suspect},
        )


def _heuristic_candidate(spec: EngineSpec) -> Candidate:
    """The knob assignment the static heuristics would pick (the
    pre-tuner default and the baseline every winner is scored
    against)."""
    resolved = spec.normalize(table=None)
    return Candidate(fusion_k=resolved.fusion_k, macro_p=None,
                     exchange="p2p" if spec.is_dist else "auto")


def candidate_space(spec: EngineSpec, n_blocks: int,
                    max_candidates: Optional[int] = None
                    ) -> List[Candidate]:
    """The bounded discrete sweep for ``spec`` (see module docstring).
    Always contains the heuristic baseline so the winner can never be
    slower than it on the same measurement matrix."""
    spec = spec.canonical()
    if not spec.is_block:
        raise ValueError(
            f"kind {spec.kind!r} has no tunable knobs (non-block kind)")
    rho = spec.rho
    ks = list(range(1, rho + 1))
    exchanges = ["p2p", "gather"] if spec.is_dist else ["auto"]
    cands: List[Candidate] = []
    for k in ks:
        ps: List[Optional[int]] = [None]
        if spec.kind in ("pallas-mxu", "dist-mxu", "pallas-3d-mxu"):
            w = rho + 2 * k
            default_p = max(1, min(128 // max(1, w), n_blocks))
            for p in {1, default_p // 2, default_p,
                      min(2 * default_p, n_blocks)}:
                if p >= 1 and p not in ps:
                    ps.append(int(p))
        for p in ps:
            for ex in exchanges:
                cands.append(Candidate(k, p, ex))
    base = _heuristic_candidate(spec)
    if base not in cands:
        cands.insert(0, base)
    if max_candidates is not None and len(cands) > max_candidates:
        keep = [c for c in cands if c == base]
        keep += [c for c in cands if c != base]
        cands = keep[:max_candidates]
    return cands


def _states_equal(workload, a, b) -> bool:
    import jax.numpy as jnp
    import numpy as np
    a, b = np.asarray(a), np.asarray(b)
    if workload.dtype == jnp.uint8:
        return bool(np.array_equal(a, b))
    return bool(np.allclose(a, b, rtol=1e-4, atol=1e-4))


def tune_spec(spec: EngineSpec, *, steps: int = 8, rounds: int = 3,
              seed: int = 0, max_candidates: Optional[int] = None,
              parity_steps: Optional[int] = None) -> TuneResult:
    """Sweep, parity-gate, time, and pick the winner for one spec.

    ``steps`` is the fused-run length each timed call advances (scores
    are seconds per advanced step); ``parity_steps`` defaults to
    ``steps``. Engines are built with the tuning table *disabled* — the
    sweep measures knobs, it must not read its own output.
    """
    from repro.core.stencil import make_engine
    base = dataclasses.replace(spec.canonical(), fusion_k=None,
                               macro_p=None, exchange="auto")
    baseline = _heuristic_candidate(base)
    cands = candidate_space(base, _n_blocks_for(base),
                            max_candidates=max_candidates)
    mesh = base.build_mesh()
    frac = base.build_frac()
    workload = base.build_workload()

    engines = {}
    for cand in cands:
        cand_spec = dataclasses.replace(
            base, fusion_k=cand.fusion_k, macro_p=cand.macro_p,
            exchange=cand.exchange)
        engines[cand.label] = make_engine(
            cand_spec, frac=frac, workload=workload, mesh=mesh,
            table=None)

    ref_engine = engines[baseline.label]
    state0 = ref_engine.init_random(seed)
    n_parity = parity_steps if parity_steps is not None else steps

    ref_out = ref_engine.to_expanded(ref_engine.run(state0, n_parity))
    parity_failures: List[str] = []
    for cand in cands:
        if cand.label == baseline.label:
            continue
        eng = engines[cand.label]
        out = eng.to_expanded(eng.run(eng.init_random(seed), n_parity))
        if not _states_equal(workload, ref_out, out):
            parity_failures.append(cand.label)
            log.error("tuning parity FAILED for %s candidate %s — "
                      "excluded from the sweep", spec.tuning_key(),
                      cand.label)
    ok = [c for c in cands if c.label not in parity_failures]

    fns = {c.label: (lambda e=engines[c.label], s0=state0:
                     e.run(s0, steps)) for c in ok}
    raw = time_interleaved(fns, rounds=rounds)
    times = {label: t / steps for label, t in raw.items()}

    layout = ref_engine.layout if hasattr(ref_engine, "layout") else None
    itemsize = 1 if _is_uint8(workload) else 4
    roofline = roofline_step_seconds(
        _n_blocks_for(base), base.rho, baseline.fusion_k,
        itemsize=itemsize) if layout is not None else 0.0

    best = min(ok, key=lambda c: times[c.label])
    suspect = bool(roofline and times[best.label] < roofline)
    if suspect:
        log.warning(
            "tuning winner %s for %s measured %.3g s/step, below the "
            "roofline bound %.3g s/step — measurement artifact likely; "
            "treat with suspicion", best.label, spec.tuning_key(),
            times[best.label], roofline)
    speedup = times[baseline.label] / times[best.label]
    return TuneResult(spec=base, best=best, baseline=baseline,
                      times=times, speedup=speedup,
                      parity_failures=parity_failures,
                      roofline_s=roofline, suspect=suspect)


def tune_many(specs, *, steps: int = 8, rounds: int = 3, seed: int = 0,
              max_candidates: Optional[int] = None,
              table: Optional[TuningTable] = None
              ) -> Tuple[TuningTable, List[TuneResult]]:
    """Tune each spec and collect winners into ``table`` (a fresh one
    by default). Winners that failed the roofline sanity check are
    still recorded (flagged ``suspect`` in entry meta) but logged."""
    table = table if table is not None else TuningTable()
    results = []
    for spec in specs:
        res = tune_spec(spec, steps=steps, rounds=rounds, seed=seed,
                        max_candidates=max_candidates)
        table.put(res.spec, res.entry())
        results.append(res)
        log.info("tuned %s: best=%s (%.2fx vs heuristic %s)",
                 res.spec.tuning_key(), res.best.label, res.speedup,
                 res.baseline.label)
    return table, results


def _is_uint8(workload) -> bool:
    import jax.numpy as jnp
    return workload.dtype == jnp.uint8


def _n_blocks_for(spec: EngineSpec) -> int:
    """Block count of the spec's layout (cheap: counts occupied blocks
    without building mask tables)."""
    frac = spec.build_frac()
    if spec.kind in ("bb3d", "cell3d", "block3d", "pallas-3d",
                     "pallas-3d-mxu"):
        from repro.core.compact3d import BlockLayout3D
        return BlockLayout3D(frac, spec.r, spec.m).n_blocks
    from repro.core.compact import BlockLayout
    return BlockLayout(frac, spec.r, spec.m).n_blocks
