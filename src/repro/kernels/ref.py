"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose (or exact
equality, for the integer space maps) against these references.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import maps
from repro.core.compact import BlockLayout
from repro.core.fractals import NBBFractal
from repro.core.baselines import life_rule, _moore_counts

Array = jnp.ndarray


def nu_ref(frac: NBBFractal, r: int, ex: Array, ey: Array
           ) -> Tuple[Array, Array, Array]:
    """Oracle for the nu kernel: (cx, cy, valid) via the integer path."""
    return maps.nu_with_membership(frac, r, ex, ey)


def lambda_ref(frac: NBBFractal, r: int, cx: Array, cy: Array
               ) -> Tuple[Array, Array]:
    """Oracle for the lambda kernel."""
    return maps.lambda_map(frac, r, cx, cy)


def life_blocks_ref(layout: BlockLayout, state: Array) -> Array:
    """Oracle for the fused block-level game-of-life step kernels."""
    padded = layout.pad_with_halo(state)
    counts = _moore_counts(padded)
    nxt = life_rule(state, counts)
    return nxt * layout.dev_micro_mask[None]


def stencil_blocks_ref(layout: BlockLayout, state: Array, workload) -> Array:
    """Oracle for the workload-parameterized block-level step kernels:
    the plain-jnp SqueezeBlockEngine step."""
    from repro.core.stencil import SqueezeBlockEngine
    return SqueezeBlockEngine(layout, workload).step(state)


def ssd_ref(x: Array, dt: Array, a: Array, bm: Array, cm: Array,
            chunk: int) -> Array:
    """Oracle for the SSD chunk kernel: the pure-jnp chunked scan from
    models/ssm.py (n_groups=1; bm/cm (B,S,N))."""
    from repro.models.ssm import _ssd_chunked
    return _ssd_chunked(x, dt, a, bm[:, :, None, :], cm[:, :, None, :],
                        chunk)


def attention_ref(q: Array, k: Array, v: Array, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> Array:
    """Oracle for the flash attention kernel.

    q: (B, H, Sq, D); k, v: (B, H, Sk, D) (kv heads already broadcast to H).
    Sliding ``window`` means key positions in (qpos - window, qpos].
    """
    *_, sq, d = q.shape
    sk = k.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    # right-aligned positions (decode-friendly)
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
