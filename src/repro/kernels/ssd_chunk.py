"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block — the
attention-free archs' compute hot spot (the quadratic-in-chunk "duality"
matmuls of [arXiv:2405.21060], Listing 1).

Per grid step, one (batch, chunk) pair is processed entirely in VMEM:

    scores  = (C B^T) ⊙ exp(segsum(dA)) ⊙ dt        (L, L) per head
    y_diag  = scores @ x                              MXU
    w       = exp(dA_L - dA) * dt
    state   = (w ⊙ x)^T @ B                           MXU (chunk-final)

The inter-chunk linear recurrence (tiny: one (H,P,N) state per chunk)
stays in XLA — it is sequential and bandwidth-trivial. ops.ssd_chunk_scan
composes kernel + recurrence and matches models/ssm._ssd_chunked exactly
(ref.ssd_ref), which is also the oracle used by the tests.

Heads are grouped n_groups=1 style: B/C shared across heads (the Mamba-2
default), looped per-head inside the kernel (h <= 48 for mamba2-780m;
each head's tiles are (L, L)/(L, P)/(L, N) — MXU-aligned at L=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

Array = jnp.ndarray


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, *, nheads: int):
    """Blocks: x (1,L,H,P), dt (1,L,H), a (1,H) [dt*A premultiplied is NOT
    passed; a holds A per head], b/c (1,L,N) -> y (1,L,H,P),
    state (1,H,P,N)."""
    x = x_ref[0].astype(jnp.float32)          # (L, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, H)
    a = a_ref[0].astype(jnp.float32)          # (H,)
    bm = b_ref[0].astype(jnp.float32)         # (L, N)
    cm = c_ref[0].astype(jnp.float32)         # (L, N)
    ll = x.shape[0]

    da = dt * a[None, :]                      # (L, H)
    da_cs = jnp.cumsum(da, axis=0)            # (L, H)
    cb = jax.lax.dot_general(                 # (L, L), shared across heads
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    tri = jnp.tril(jnp.ones((ll, ll), jnp.bool_))

    for h in range(nheads):                   # unrolled; each iter is MXU work
        seg = da_cs[:, h][:, None] - da_cs[:, h][None, :]
        decay = jnp.where(tri, jnp.exp(seg), 0.0)
        scores = cb * decay * dt[:, h][None, :]        # (L, L)
        y_h = jax.lax.dot_general(                     # (L, P)
            scores, x[:, h, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        y_ref[0, :, h, :] = y_h
        w = jnp.exp(da_cs[-1, h] - da_cs[:, h]) * dt[:, h]   # (L,)
        xw = x[:, h, :] * w[:, None]                   # (L, P)
        state_ref[0, h] = jax.lax.dot_general(         # (P, N)
            xw, bm, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x: Array, dt: Array, a: Array, bm: Array, cm: Array, *,
                   chunk: int = 128, interpret=None) -> Array:
    """Full SSD scan: Pallas intra-chunk kernel + XLA inter-chunk
    recurrence. x (B,S,H,P); dt (B,S,H) fp32 post-softplus; a (H,)
    negative; bm/cm (B,S,N) (n_groups=1). Returns (B,S,H,P) fp32.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere)."""
    interpret = resolve_interpret(interpret)
    b, s, h, p = x.shape
    n = bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xc = x.reshape(b * nc, chunk, h, p)
    dtc = dt.reshape(b * nc, chunk, h)
    bc = bm.reshape(b * nc, chunk, n)
    cc = cm.reshape(b * nc, chunk, n)
    a2 = jnp.broadcast_to(a.astype(jnp.float32)[None], (b * nc, h))

    kernel = functools.partial(_ssd_chunk_kernel, nheads=h)
    y_diag, states = pl.pallas_call(
        kernel,
        grid=(b * nc,),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nc, chunk, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b * nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, a2, bc, cc)

    # ---- inter-chunk recurrence + off-diagonal contribution (XLA)
    y_diag = y_diag.reshape(b, nc, chunk, h, p)
    states = states.reshape(b, nc, h, p, n)
    da = dt.reshape(b, nc, chunk, h).astype(jnp.float32) \
        * a.astype(jnp.float32)[None, None, None, :]
    da_cs = jnp.cumsum(da, axis=2)                       # (b,nc,L,h)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])            # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry
    _, prev = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                 # (b,nc,h,p,n)

    cmr = cm.reshape(b, nc, chunk, n).astype(jnp.float32)
    y_off = jnp.einsum("bcln,bchpn->bclhp", cmr, prev) \
        * jnp.exp(da_cs)[..., None]
    y = (y_diag + y_off).reshape(b, sp, h, p)
    return y[:, :s]
