"""Pallas TPU kernels for the perf-critical hot spots: the MXU-encoded
space maps (the paper's tensor-core contribution), the fused block-level
compact stencil, and blocked flash attention for the LM substrate.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrappers), ref.py (pure-jnp oracles used by the allclose tests)."""
from repro.kernels.ops import (default_interpret, flash_attention,
                               lambda_map_tc, life_step_blocks,
                               life_step_strips, nu_map_tc)

__all__ = ["default_interpret", "flash_attention", "lambda_map_tc",
           "life_step_blocks", "life_step_strips", "nu_map_tc"]
