"""Pallas TPU kernel for the lambda(w) map — the [7]-style tensor-core
encoding on the MXU (see nu_map.py for the scheme; lambda uses a (TILE, 2r)
code matrix [tau_x | tau_y] against a block-diagonal weight matrix)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fractals import NBBFractal
from repro.core.maps import lambda_weight_matrix
from repro.kernels.common import resolve_interpret

RPAD = 128
LANES = 128


def _lambda_kernel(coords_ref, w_ref, out_ref, *, frac: NBBFractal, r: int):
    """coords_ref: (2, TILE) int32 [cx; cy]; w_ref: (RPAD, LANES) fp32
    -> out_ref: (2, TILE) int32 [ex; ey]."""
    cx = coords_ref[0, :]
    cy = coords_ref[1, :]

    tx_cols, ty_cols = [], []
    for mu in range(1, r + 1):
        w = cx if (mu % 2 == 1) else cy
        beta = (w // (frac.k ** ((mu - 1) // 2))) % frac.k
        # arithmetic H_lambda: tau(beta) via one-hot over replica indices
        tx = jnp.zeros_like(beta)
        ty = jnp.zeros_like(beta)
        for i, (px, py) in enumerate(frac.positions):
            hit = (beta == i).astype(jnp.int32)
            tx = tx + px * hit
            ty = ty + py * hit
        tx_cols.append(tx.astype(jnp.float32))
        ty_cols.append(ty.astype(jnp.float32))

    codes = jnp.stack(tx_cols + ty_cols, axis=1)  # (TILE, 2r)
    codes = jnp.pad(codes, ((0, 0), (0, RPAD - 2 * r)))

    res = jax.lax.dot_general(
        codes, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    out_ref[0, :] = res[:, 0].astype(jnp.int32)
    out_ref[1, :] = res[:, 1].astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("frac", "r", "tile", "interpret"))
def lambda_map_pallas(frac: NBBFractal, r: int, cx, cy, *,
                      tile: int = 256, interpret=None):
    """MXU-encoded lambda(w) over a batch of compact coordinates.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere)."""
    interpret = resolve_interpret(interpret)
    if 2 * r > RPAD:
        raise ValueError(f"2r={2*r} exceeds the padded contraction dim {RPAD}")
    shape = cx.shape
    flat_n = 1
    for d in shape:
        flat_n *= d
    npad = max(tile, ((flat_n + tile - 1) // tile) * tile)
    coords = jnp.zeros((2, npad), jnp.int32)
    coords = coords.at[0, :flat_n].set(cx.reshape(-1).astype(jnp.int32))
    coords = coords.at[1, :flat_n].set(cy.reshape(-1).astype(jnp.int32))

    import numpy as np
    w = np.zeros((RPAD, LANES), np.float32)
    w[:2 * r, :2] = lambda_weight_matrix(frac, r)

    out = pl.pallas_call(
        functools.partial(_lambda_kernel, frac=frac, r=r),
        grid=(npad // tile,),
        in_specs=[pl.BlockSpec((2, tile), lambda i: (0, i)),
                  pl.BlockSpec((RPAD, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((2, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, npad), jnp.int32),
        interpret=interpret,
    )(coords, jnp.asarray(w))
    ex = out[0, :flat_n].reshape(shape)
    ey = out[1, :flat_n].reshape(shape)
    return ex, ey
