"""Blocked (flash-style) attention forward kernel for the LM substrate.

Grid: (batch*heads, q_blocks, kv_blocks) with the kv dimension innermost;
running max/denominator live in VMEM scratch across kv steps (the classic
online-softmax scheme, IO-aware a la FlashAttention, retiled for VMEM/MXU:
q/k/v tiles are (BQ, D)/(BK, D) with D padded to lane width by the caller).

Supports causal masking, sliding windows (Mistral/Gemma2 local layers) and
logit softcapping (Gemma2) — the feature set the assigned archs need.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int, kv_off: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + kv_off
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                        # (BQ,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)             # (BQ,)
    p = jnp.exp(s - m_cur[:, None])             # (BQ, BK)
    l_cur = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) (kv already broadcast to H).

    Sq/Sk must be divisible by bq/bk (callers pad). Queries are
    right-aligned against keys (kv_off = Sk - Sq), so decode (Sq=1 with a
    long cache) masks correctly. ``interpret=None`` auto-detects
    (compiled on TPU, interpreter elsewhere).
    """
    interpret = resolve_interpret(interpret)
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_off=sk - sq)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),  # running max
            pltpu.VMEM((bq, 128), jnp.float32),  # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
