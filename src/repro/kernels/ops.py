"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` everywhere means auto-detect: compiled Mosaic on a TPU
runtime, the Pallas interpreter on every other backend (this container is
CPU-only; the kernels are validated in interpret mode per the kernel
tests). Pass an explicit bool to pin it. Resolution happens once, in the
kernel entry points (``kernels.common.resolve_interpret``); these
wrappers pass ``interpret`` through untouched.

With telemetry enabled, every stencil entry point counts
``kernel.entry{op=...}`` on the registry. The wrapper body runs once
per *Python-level* entry: eagerly that is one count per kernel
dispatch; inside a jit (the engines' cached run loops) it runs only
while tracing — so a growing ``kernel.entry`` under a cached jit is a
retrace detector, the same discipline as ``engine.trace`` (DESIGN.md
Section 7).
"""
from __future__ import annotations

from typing import Optional

from repro import obs
from repro.core.compact import BlockLayout
from repro.core.fractals import NBBFractal
from repro.kernels.common import default_interpret  # noqa: F401  re-export
from repro.workloads.rules import LIFE
from repro.kernels import attention as _attention
from repro.kernels import lambda_map as _lambda
from repro.kernels import nu_map as _nu
from repro.kernels import squeeze_stencil as _stencil


def nu_map_tc(frac: NBBFractal, r: int, ex, ey, *,
              interpret: Optional[bool] = None):
    """Tensor-core (MXU) nu(w): (cx, cy, valid)."""
    return _nu.nu_map_pallas(frac, r, ex, ey, interpret=interpret)


def lambda_map_tc(frac: NBBFractal, r: int, cx, cy, *,
                  interpret: Optional[bool] = None):
    """Tensor-core (MXU) lambda(w): (ex, ey)."""
    return _lambda.lambda_map_pallas(frac, r, cx, cy, interpret=interpret)


def stencil_step_blocks(layout: BlockLayout, state, workload=LIFE, *,
                        interpret: Optional[bool] = None):
    """Fused block-level workload step, v1 (neighbor-block staging)."""
    obs.inc("kernel.entry", op="stencil_step_blocks")
    return _stencil.stencil_step_blocks(layout, state, workload,
                                        interpret=interpret)


def stencil_step_strips(layout: BlockLayout, state, workload=LIFE, *,
                        interpret: Optional[bool] = None):
    """Fused block-level workload step, v2 (strip halos)."""
    obs.inc("kernel.entry", op="stencil_step_strips")
    return _stencil.stencil_step_strips(layout, state, workload,
                                        interpret=interpret)


def stencil_step_fused(layout: BlockLayout, state, workload=LIFE, *,
                       interpret: Optional[bool] = None):
    """Fused block-level workload step, v3 (in-kernel strip reads)."""
    obs.inc("kernel.entry", op="stencil_step_fused")
    return _stencil.stencil_step_fused(layout, state, workload,
                                       interpret=interpret)


def stencil_step_fused_k(layout: BlockLayout, state, workload=LIFE, *,
                         k: int = 2, interpret: Optional[bool] = None):
    """Fused block-level workload step, v4 (temporal fusion): k exact
    steps per launch on a depth-k halo tile held in VMEM. k <= rho."""
    obs.inc("kernel.entry", op="stencil_step_fused_k")
    return _stencil.stencil_step_fused_k(layout, state, workload, k=k,
                                         interpret=interpret)


def stencil_step_mxu(layout: BlockLayout, state, workload=LIFE, *,
                     p: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """Fused block-level workload step, v5 (MXU stencil-as-matmul on
    lane-packed macro-tiles). ``p`` overrides the macro-tile packing
    (blocks per macro-tile; None = lane heuristic — the autotuner
    persists per-config winners)."""
    obs.inc("kernel.entry", op="stencil_step_mxu")
    return _stencil.stencil_step_mxu(layout, state, workload, p=p,
                                     interpret=interpret)


def stencil_step_mxu_k(layout: BlockLayout, state, workload=LIFE, *,
                       k: int = 2, p: Optional[int] = None,
                       interpret: Optional[bool] = None):
    """Fused block-level workload step, v5 temporal fusion: k exact steps
    per MXU macro-tile launch (k <= rho). ``p`` overrides the macro-tile
    packing (None = lane heuristic)."""
    obs.inc("kernel.entry", op="stencil_step_mxu_k")
    return _stencil.stencil_step_mxu_k(layout, state, workload, k=k, p=p,
                                       interpret=interpret)


def stencil_step_mxu_batched(layout: BlockLayout, states, workload=LIFE, *,
                             k: int = 1, p: Optional[int] = None,
                             interpret: Optional[bool] = None):
    """v5 native batch grid: B simulations x k exact steps in one kernel
    dispatch over (B, n_macro_tiles); states (B, C?, n_blocks, rho, rho).
    ``p`` overrides the macro-tile packing (None = lane heuristic)."""
    obs.inc("kernel.entry", op="stencil_step_mxu_batched")
    return _stencil.stencil_step_mxu_batched(layout, states, workload, k=k,
                                             p=p, interpret=interpret)


def stencil3d_step_fused_k(layout, state, workload=None, *, k: int = 2,
                           interpret: Optional[bool] = None):
    """Fused 3D block-level workload step (v4-style temporal fusion):
    k exact steps per launch on a depth-k (rho+2k)^3 window in VMEM.
    ``layout`` is a ``compact3d.BlockLayout3D``; k <= rho."""
    obs.inc("kernel.entry", op="stencil3d_step_fused_k")
    from repro.kernels import squeeze_stencil3d as _s3
    from repro.workloads.rules import LIFE3D
    return _s3.stencil3d_step_fused_k(
        layout, state, LIFE3D if workload is None else workload, k=k,
        interpret=interpret)


def stencil3d_step_mxu_k(layout, state, workload=None, *, k: int = 1,
                         p: Optional[int] = None,
                         interpret: Optional[bool] = None):
    """Fused 3D block-level workload step (v5-style MXU): the 26-cell
    aggregation as banded matmuls per z-slab on lane-packed macro-tiles.
    ``layout`` is a ``compact3d.BlockLayout3D``; k <= rho. ``p``
    overrides the macro-tile packing (None = lane heuristic)."""
    obs.inc("kernel.entry", op="stencil3d_step_mxu_k")
    from repro.kernels import squeeze_stencil3d as _s3
    from repro.workloads.rules import LIFE3D
    return _s3.stencil3d_step_mxu_k(
        layout, state, LIFE3D if workload is None else workload, k=k, p=p,
        interpret=interpret)


def life_step_blocks(layout: BlockLayout, state, *,
                     interpret: Optional[bool] = None):
    """Fused block-level GoL step, v1 (neighbor-block staging)."""
    obs.inc("kernel.entry", op="life_step_blocks")
    return _stencil.life_step_blocks(layout, state, interpret=interpret)


def life_step_strips(layout: BlockLayout, state, *,
                     interpret: Optional[bool] = None):
    """Fused block-level GoL step, v2 (strip halos; lower HBM traffic)."""
    obs.inc("kernel.entry", op="life_step_strips")
    return _stencil.life_step_strips(layout, state, interpret=interpret)


def life_step_fused(layout: BlockLayout, state, *,
                    interpret: Optional[bool] = None):
    """Fused block-level GoL step, v3 (in-kernel strip reads; no halo
    tensor materialised)."""
    obs.inc("kernel.entry", op="life_step_fused")
    return _stencil.life_step_fused(layout, state, interpret=interpret)


def ssd_chunk_scan(x, dt, a, bm, cm, *, chunk: int = 128,
                   interpret: Optional[bool] = None):
    """Mamba-2 SSD scan with the Pallas intra-chunk kernel."""
    from repro.kernels import ssd_chunk as _ssd
    return _ssd.ssd_chunk_scan(x, dt, a, bm, cm, chunk=chunk,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None):
    """Blocked online-softmax attention (causal/window/softcap)."""
    return _attention.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, interpret=interpret)


__all__ = ["nu_map_tc", "lambda_map_tc", "life_step_blocks",
           "life_step_strips", "life_step_fused", "stencil_step_blocks",
           "stencil_step_strips", "stencil_step_fused",
           "stencil_step_fused_k", "stencil_step_mxu",
           "stencil_step_mxu_k", "stencil_step_mxu_batched",
           "stencil3d_step_fused_k", "stencil3d_step_mxu_k",
           "flash_attention", "ssd_chunk_scan", "default_interpret"]
