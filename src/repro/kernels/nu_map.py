"""Pallas TPU kernel for the nu(w) map — the paper's tensor-core encoding
(Section 3.6, Eqs. 15-16) adapted to the MXU.

Per grid step one coordinate tile is processed:
  1. VPU pass: extract the per-level base-s digit pair theta_mu of every
     coordinate and resolve H_nu[theta_mu] *arithmetically* (a k-term
     one-hot sum — TPU-idiomatic, no in-kernel gather), building the code
     matrix ``codes`` (TILE, 128) fp32 (r levels, zero-padded).
  2. MXU pass: one ``dot`` against the constant weight matrix W (128, 128)
     whose first two columns hold Delta^nu_mu * f_{x|y}(mu) — the paper's
     MMA ``A`` operand, here sized to the 128x128 systolic array instead of
     the WMMA 16x16 fragment.

fp32 accumulation is exact for all supported sizes (products < 2**24);
membership (``valid``) falls out of the same digit pass for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fractals import NBBFractal
from repro.core.maps import nu_weight_matrix
from repro.kernels.common import resolve_interpret

RPAD = 128  # contraction dim padded to the MXU systolic width
LANES = 128


def _nu_kernel(coords_ref, w_ref, out_ref, *, frac: NBBFractal, r: int,
               n: int):
    """coords_ref: (2, TILE) int32 [ex; ey]; w_ref: (RPAD, LANES) fp32 weight
    matrix -> out_ref: (3, TILE) int32 [cx; cy; valid]."""
    ex = coords_ref[0, :]
    ey = coords_ref[1, :]
    in_bounds = (ex >= 0) & (ex < n) & (ey >= 0) & (ey < n)
    exc = jnp.clip(ex, 0, n - 1)
    eyc = jnp.clip(ey, 0, n - 1)

    cols = []
    occupied = in_bounds
    for mu in range(1, r + 1):
        scale = frac.s ** (mu - 1)
        tx = (exc // scale) % frac.s
        ty = (eyc // scale) % frac.s
        # arithmetic H_nu: one-hot over the k replica slots (no gather)
        code = jnp.zeros_like(tx)
        occ = jnp.zeros_like(tx, dtype=jnp.bool_)
        for i, (px, py) in enumerate(frac.positions):
            hit = (tx == px) & (ty == py)
            code = code + i * hit.astype(jnp.int32)
            occ = occ | hit
        occupied = occupied & occ
        cols.append(code.astype(jnp.float32))

    codes = jnp.stack(cols, axis=1)  # (TILE, r)
    codes = jnp.pad(codes, ((0, 0), (0, RPAD - r)))  # (TILE, 128)

    res = jax.lax.dot_general(  # the MXU MMA (paper Eq. 15-16)
        codes, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (TILE, 128)

    out_ref[0, :] = res[:, 0].astype(jnp.int32)
    out_ref[1, :] = res[:, 1].astype(jnp.int32)
    out_ref[2, :] = occupied.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("frac", "r", "tile", "interpret"))
def nu_map_pallas(frac: NBBFractal, r: int, ex, ey, *,
                  tile: int = 256, interpret=None):
    """MXU-encoded nu(w) over a batch of expanded coordinates.

    Returns (cx, cy, valid) with the same leading shape as ex/ey.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere); pass an explicit bool to pin it.
    """
    interpret = resolve_interpret(interpret)
    if r > RPAD:
        raise ValueError(f"r={r} exceeds the padded contraction dim {RPAD}")
    shape = ex.shape
    flat_n = 1
    for d in shape:
        flat_n *= d
    npad = max(tile, ((flat_n + tile - 1) // tile) * tile)
    coords = jnp.zeros((2, npad), jnp.int32)
    coords = coords.at[0, :flat_n].set(ex.reshape(-1).astype(jnp.int32))
    coords = coords.at[1, :flat_n].set(ey.reshape(-1).astype(jnp.int32))

    import numpy as np
    w = np.zeros((RPAD, LANES), np.float32)
    w[:r, :2] = nu_weight_matrix(frac, r)

    out = pl.pallas_call(
        functools.partial(_nu_kernel, frac=frac, r=r, n=frac.side(r)),
        grid=(npad // tile,),
        in_specs=[pl.BlockSpec((2, tile), lambda i: (0, i)),
                  pl.BlockSpec((RPAD, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((3, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, npad), jnp.int32),
        interpret=interpret,
    )(coords, jnp.asarray(w))
    cx = out[0, :flat_n].reshape(shape)
    cy = out[1, :flat_n].reshape(shape)
    valid = out[2, :flat_n].reshape(shape).astype(jnp.bool_)
    return cx, cy, valid
