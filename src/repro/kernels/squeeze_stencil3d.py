"""Fused 3D block-level Squeeze stencil kernels — the v4/v5 kernel
family of kernels/squeeze_stencil.py lifted to 3D NBB fractals.

Both entry points are driven by the static block tables of
``compact3d.BlockLayout3D`` (built from the lambda3/nu3 maps) and
parameterized by a single-channel ``StencilWorkload`` over the 26-cell
3D Moore neighborhood:

  * ``stencil3d_step_fused_k`` (v4-style temporal fusion): the depth-k
    halo — six face slabs covering the full window frame — is gathered
    once by XLA over the static neighbor table, then the kernel runs k
    update substeps on a (rho+2k)^3 window held in VMEM before the
    single center write-back. Per-window occupancy is rebuilt in-kernel
    from the shared periodic ``window_mask`` gated by a
    scalar-prefetched 26-direction block-existence table (the 2D
    substep mask discipline, per region).

  * ``stencil3d_step_mxu_k`` (v5-style MXU stencil-as-matmul): the
    26-neighbor aggregation runs as banded matmul contractions *per
    z-slab*: each z-plane of the (3,3,3) weight tensor factors into
    <= 2 rank-1 terms (``workload.weight_factors3``), so slab z's
    aggregate is ``sum_dz sum_t R_t(dz) @ X[z+dz] @ C_t(dz)^T`` — MXU
    contractions on (rho+2k, P*(rho+2k)) slab matrices with P blocks
    lane-packed along x (``BlockLayout3D.macro_tiles``), instead of 26
    VPU shift-adds. Slot borders and the z-shifted window edges
    accumulate truncated-band garbage ring by ring; the center sits at
    distance >= k from every border, so the final extraction is exact
    (the same shrinking-window argument as the 2D v5 kernel).

State is (n_blocks, rho, rho, rho) indexed [b, z, y, x] (single-channel
workloads only, as the 3D engines). ``interpret=None`` auto-detects:
compiled Mosaic on TPU, the Pallas interpreter elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compact3d import BlockLayout3D, halo_regions3
from repro.kernels.common import resolve_interpret
from repro.workloads.base import (MOORE3_DIRS, StencilWorkload,
                                  banded_operators)
from repro.workloads.rules import LIFE3D

#: direction -> MOORE3_DIRS index (the gather/table column order)
DIR3_INDEX = {d: i for i, d in enumerate(MOORE3_DIRS)}


def _gather_halo3_k(layout: BlockLayout3D, s: jnp.ndarray, k: int):
    """Depth-k halo slabs via slab-level XLA gathers over the static
    26-direction neighbor table (k <= rho, so every piece comes from one
    Moore neighbor). Returns six pieces whose union is the full window
    frame:

      zlo/zhi (C, nb, k, w, w)     — full-xy-extent z faces, including
                                     all 12 edge and 8 corner pieces at
                                     that z (w = rho + 2k);
      ylo/yhi (C, nb, rho, k, w)   — center-z y faces incl. x edges;
      xlo/xhi (C, nb, rho, rho, k) — center x faces.

    Ghost ids index an appended zero slab. No zero-weight skipping: a
    k >= 2 substep chain propagates diagonal values inward even under
    orthogonal-only weights (the radius-k L1 dependency cone).
    """
    rho = layout.rho
    nc = s.shape[0]
    table = layout.dev_neighbor_table

    def take(strip, d):  # strip (C, nb, ...), pre-sliced before the gather
        z = jnp.zeros((nc, 1) + strip.shape[2:], s.dtype)
        return jnp.concatenate([strip, z], 1)[:, table[:, DIR3_INDEX[d]]]

    x_src = {-1: slice(rho - k, rho), 0: slice(None), 1: slice(0, k)}

    def zface(dz):  # (C, nb, k, w, w): 9 pieces across (dy, dx)
        rows = []
        for dy in (-1, 0, 1):
            rows.append(jnp.concatenate(
                [take(s[:, :, x_src[dz], x_src[dy], x_src[dx]],
                      (dx, dy, dz)) for dx in (-1, 0, 1)], axis=-1))
        return jnp.concatenate(rows, axis=-2)

    def yface(dy):  # (C, nb, rho, k, w): 3 pieces across dx at dz = 0
        return jnp.concatenate(
            [take(s[:, :, :, x_src[dy], x_src[dx]], (dx, dy, 0))
             for dx in (-1, 0, 1)], axis=-1)

    return (zface(-1), zface(1), yface(-1), yface(1),
            take(s[:, :, :, :, rho - k:], (-1, 0, 0)),
            take(s[:, :, :, :, :k], (1, 0, 0)))


def _assemble_window(c, zlo, zhi, ylo, yhi, xlo, xhi, k):
    """(C, rho^3) center + six face slabs -> (C, w^3) window."""
    rho = c.shape[-1]
    w = rho + 2 * k
    padded = jnp.zeros(c.shape[:-3] + (w, w, w), c.dtype)
    padded = padded.at[..., k:k + rho, k:k + rho, k:k + rho].set(c)
    padded = padded.at[..., :k, :, :].set(zlo)
    padded = padded.at[..., w - k:, :, :].set(zhi)
    padded = padded.at[..., k:k + rho, :k, :].set(ylo)
    padded = padded.at[..., k:k + rho, w - k:, :].set(yhi)
    padded = padded.at[..., k:k + rho, k:k + rho, :k].set(xlo)
    padded = padded.at[..., k:k + rho, k:k + rho, w - k:].set(xhi)
    return padded


# ======================================================================
# v4-style: depth-k window assembled in VMEM, k substeps, one write
# ======================================================================
def _fused3_k_kernel(workload, k, ex_ref, c_ref, zlo_ref, zhi_ref, ylo_ref,
                     yhi_ref, xlo_ref, xhi_ref, wmask_ref, out_ref):
    """One grid step = one block: assemble the (C, w, w, w) window,
    rebuild its occupancy (periodic window mask x prefetched block
    existence per region), then run the workload's k fused substeps."""
    rho = c_ref.shape[-1]
    padded = _assemble_window(
        c_ref[:, 0], zlo_ref[:, 0], zhi_ref[:, 0], ylo_ref[:, 0],
        yhi_ref[:, 0], xlo_ref[:, 0], xhi_ref[:, 0], k)

    i = pl.program_id(0)
    mask = wmask_ref[...].astype(jnp.int32)
    for d, (zs, ys, xs) in enumerate(halo_regions3(rho, k)):
        mask = mask.at[zs, ys, xs].set(mask[zs, ys, xs] * ex_ref[i, d])

    nxt = workload.tile_rule_k(padded[0], mask, k, ndim=3)[None]
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def stencil3d_step_fused_k(layout: BlockLayout3D, state: jnp.ndarray,
                           workload: StencilWorkload = LIFE3D, *,
                           k: int = 2,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Advance ``k`` exact 3D steps in ONE kernel launch (k <= rho).

    state (n_blocks, rho, rho, rho) -> same, k steps later. The depth-k
    halo is gathered once; the kernel runs k substeps on a (rho+2k)^3
    window in VMEM and writes the center back once.
    """
    if k < 1:
        raise ValueError(f"need k >= 1, got k={k}")
    if k > layout.rho:
        raise ValueError(
            f"fused 3D kernel needs k <= rho, got k={k} > rho={layout.rho} "
            "(use Squeeze3DBlockEngine.step_k for deeper halos)")
    layout.materialize()
    _ = layout.dev_existence_table, layout.dev_window_mask(k)
    return _stencil3d_step_fused_k(layout, state, workload, k,
                                   interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "k", "interpret"))
def _stencil3d_step_fused_k(layout: BlockLayout3D, state: jnp.ndarray,
                            workload: StencilWorkload, k: int, *,
                            interpret: bool) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s = state[None]  # single-channel: explicit channel axis internally
    w = rho + 2 * k
    zlo, zhi, ylo, yhi, xlo, xhi = _gather_halo3_k(layout, s, k)
    blk = lambda *shape: pl.BlockSpec(shape, lambda i, ex: (0, i) + (0,) * (len(shape) - 2))  # noqa: E731,E501
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            blk(1, 1, rho, rho, rho),
            blk(1, 1, k, w, w), blk(1, 1, k, w, w),      # z faces
            blk(1, 1, rho, k, w), blk(1, 1, rho, k, w),  # y faces
            blk(1, 1, rho, rho, k), blk(1, 1, rho, rho, k),  # x faces
            pl.BlockSpec((w, w, w), lambda i, ex: (0, 0, 0)),
        ],
        out_specs=blk(1, 1, rho, rho, rho),
    )
    out = pl.pallas_call(
        functools.partial(_fused3_k_kernel, workload, k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, nb, rho, rho, rho),
                                       workload.dtype),
        interpret=interpret,
    )(layout.dev_existence_table, s, zlo, zhi, ylo, yhi, xlo, xhi,
      layout.dev_window_mask(k))
    return out[0]


# ======================================================================
# v5-style: z-slab banded MXU contractions on lane-packed macro-tiles
# ======================================================================
@functools.lru_cache(maxsize=128)
def _mxu3_operators(workload: StencilWorkload, w: int, p: int):
    """Static MXU contraction operands for one (workload, window, pack):
    per rank-1 term of each nonzero z-plane of the weight tensor, a
    banded ``R`` (w, w) row contraction and the block-diagonal (per
    lane-packed slot) transpose ``CT`` (p*w, p*w) of its banded column
    contraction, plus the static tuple of per-term z shifts — slab z's
    aggregate is ``sum_t R_t @ X[z + dz_t] @ CT_t``."""
    rms, cts, dzs = [], [], []
    for dz in (-1, 0, 1):
        terms = workload.weight_factors3[dz + 1]
        if not terms:
            continue
        rm, cm = banded_operators(terms, w, np.float32)
        for t in range(rm.shape[0]):
            ct = np.zeros((p * w, p * w), np.float32)
            for sl in range(p):
                ct[sl * w:(sl + 1) * w, sl * w:(sl + 1) * w] = cm[t].T
            rms.append(rm[t])
            cts.append(ct)
            dzs.append(dz)
    return np.stack(rms), np.stack(cts), tuple(dzs)


def _zshift(x: jnp.ndarray, dz: int) -> jnp.ndarray:
    """out[z] = x[z + dz] over the trailing-3 z axis, zero-padded at the
    window border (border slabs are garbage-by-design: they sit outside
    the shrinking live window of the fused substeps)."""
    if dz == 0:
        return x
    nz = x.shape[-3]
    pad = jnp.zeros(x.shape[:-3] + (1,) + x.shape[-2:], x.dtype)
    if dz > 0:
        return jnp.concatenate([x[..., 1:, :, :], pad], axis=-3)
    return jnp.concatenate([pad, x[..., :nz - 1, :, :]], axis=-3)


def _mxu3_kernel(workload, k, p, dzs, ex_ref, c_ref, zlo_ref, zhi_ref,
                 ylo_ref, yhi_ref, xlo_ref, xhi_ref, wmask_ref, r_ref,
                 ct_ref, out_ref):
    """One grid step = one macro-tile: assemble the (w, w, P*w)
    lane-packed window (P block slots side by side along x), rebuild
    each slot's occupancy from the shared periodic window mask gated by
    its prefetched 26-direction existence row, then run k substeps whose
    26-neighbor aggregation is the per-z-slab banded matmul sum."""
    rho = c_ref.shape[-2]
    w = rho + 2 * k
    c = c_ref[0, 0]                          # (rho, rho, P*rho)
    zlo, zhi = zlo_ref[0, 0], zhi_ref[0, 0]  # (k, w, P*w)
    ylo, yhi = ylo_ref[0, 0], yhi_ref[0, 0]  # (rho, k, P*w)
    xlo, xhi = xlo_ref[0, 0], xhi_ref[0, 0]  # (rho, rho, P*k)
    i = pl.program_id(0)

    cur = jnp.zeros((w, w, p * w), c.dtype)
    mask = jnp.zeros((w, w, p * w), jnp.int32)
    wm = wmask_ref[...].astype(jnp.int32)
    for sl in range(p):
        b0 = sl * w
        win = _assemble_window(
            c[:, :, sl * rho:(sl + 1) * rho],
            zlo[:, :, sl * w:(sl + 1) * w], zhi[:, :, sl * w:(sl + 1) * w],
            ylo[:, :, sl * w:(sl + 1) * w], yhi[:, :, sl * w:(sl + 1) * w],
            xlo[:, :, sl * k:(sl + 1) * k], xhi[:, :, sl * k:(sl + 1) * k],
            k)
        cur = cur.at[:, :, b0:b0 + w].set(win)
        m = wm
        for d, (zs, ys, xs) in enumerate(halo_regions3(rho, k)):
            m = m.at[zs, ys, xs].set(m[zs, ys, xs] * ex_ref[i * p + sl, d])
        mask = mask.at[:, :, b0:b0 + w].set(m)

    rm = r_ref[...]                          # (T, w, w) f32
    ct = ct_ref[...]                         # (T, P*w, P*w) f32
    int_agg = jnp.issubdtype(jnp.dtype(workload.agg_dtype), jnp.integer)
    for _ in range(k):
        x = cur.astype(jnp.float32)
        agg = jnp.zeros((w, w, p * w), jnp.float32)
        for t, dz in enumerate(dzs):
            xs = _zshift(x, dz)              # (w_z, w_y, P*w_x) slabs
            y = jnp.einsum("ij,zjx->zix", rm[t], xs)
            agg = agg + jnp.einsum("zix,xm->zim", y, ct[t])
        # integer CA aggregates: the f32 matmuls reconstruct integer
        # neighbor counts to ~1e-5, so nearest-int rounding is bit-exact
        agg = (jnp.rint(agg).astype(workload.agg_dtype) if int_agg
               else agg.astype(workload.agg_dtype))
        cur = workload.apply(cur, agg, mask).astype(c.dtype)

    out = jnp.zeros((rho, rho, p * rho), out_ref.dtype)
    for sl in range(p):
        out = out.at[:, :, sl * rho:(sl + 1) * rho].set(
            cur[k:k + rho, k:k + rho,
                sl * w + k:sl * w + k + rho].astype(out.dtype))
    out_ref[0, 0] = out


def _pack_macro3(arr: jnp.ndarray, nb: int, p: int, n_macro: int):
    """(L, nb, d, h, c) per-block slabs -> (L, n_macro, d, h, P*c)
    lane-packed macro slabs (zero-filled padding slots past nb)."""
    lead, _, d, h, cols = arr.shape
    pad = jnp.zeros((lead, n_macro * p - nb, d, h, cols), arr.dtype)
    a = jnp.concatenate([arr, pad], axis=1)
    a = a.reshape(lead, n_macro, p, d, h, cols).transpose(0, 1, 3, 4, 2, 5)
    return a.reshape(lead, n_macro, d, h, p * cols)


def stencil3d_step_mxu_k(layout: BlockLayout3D, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE3D, *, k: int = 1,
                         p: Optional[int] = None,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
    """v5-style 3D MXU step: ``k`` exact steps in one macro-tile launch
    whose 26-neighbor aggregation runs as banded matmuls per z-slab
    (k <= rho). state (n_blocks, rho, rho, rho) -> same. ``p`` overrides
    the macro-tile packing P (None = lane heuristic)."""
    if k < 1:
        raise ValueError(f"need k >= 1, got k={k}")
    if k > layout.rho:
        raise ValueError(
            f"mxu 3D kernel needs k <= rho, got k={k} > rho={layout.rho} "
            "(use Squeeze3DBlockEngine.step_k for deeper halos)")
    # resolve the packing override to its concrete P so the jit cache
    # and the layout memos key on one value
    p = layout.macro_tiles(k, p=p)[0]
    layout.materialize()
    _ = layout.dev_existence_padded(k, p=p), layout.dev_window_mask(k)
    _ = _mxu3_operators(workload, layout.rho + 2 * k, p)
    return _stencil3d_step_mxu_k(layout, state, workload, k, p,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "k", "p",
                                    "interpret"))
def _stencil3d_step_mxu_k(layout: BlockLayout3D, state: jnp.ndarray,
                          workload: StencilWorkload, k: int,
                          p: Optional[int] = None, *,
                          interpret: bool) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    w = rho + 2 * k
    p, n_macro, _ = layout.macro_tiles(k, p=p)
    s = state[None]
    pieces = _gather_halo3_k(layout, s, k)

    def pack(arr):
        return _pack_macro3(arr, nb, p, n_macro)

    cm = pack(s)
    zlom, zhim, ylom, yhim, xlom, xhim = (pack(a) for a in pieces)
    rm, ct, dzs = _mxu3_operators(workload, w, p)
    n_terms = rm.shape[0]

    def blk(d, h, cols):
        return pl.BlockSpec((1, 1, d, h, cols),
                            lambda i, ex: (0, i, 0, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_macro,),
        in_specs=[
            blk(rho, rho, p * rho),
            blk(k, w, p * w), blk(k, w, p * w),          # z faces
            blk(rho, k, p * w), blk(rho, k, p * w),      # y faces
            blk(rho, rho, p * k), blk(rho, rho, p * k),  # x faces
            pl.BlockSpec((w, w, w), lambda i, ex: (0, 0, 0)),
            pl.BlockSpec((n_terms, w, w), lambda i, ex: (0, 0, 0)),
            pl.BlockSpec((n_terms, p * w, p * w),
                         lambda i, ex: (0, 0, 0)),
        ],
        out_specs=blk(rho, rho, p * rho),
    )
    out = pl.pallas_call(
        functools.partial(_mxu3_kernel, workload, k, p, dzs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_macro, rho, rho, p * rho),
                                       workload.dtype),
        interpret=interpret,
    )(layout.dev_existence_padded(k, p=p), cm, zlom, zhim, ylom, yhim,
      xlom, xhim, layout.dev_window_mask(k), jnp.asarray(rm),
      jnp.asarray(ct))
    out = out.reshape(n_macro, rho, rho, p, rho).transpose(0, 3, 1, 2, 4)
    return out.reshape(n_macro * p, rho, rho, rho)[:nb]
