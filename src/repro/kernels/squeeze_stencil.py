"""Fused block-level Squeeze stencil kernels (game of life on a compact NBB
fractal), paper Sections 3.5 + 4 adapted to TPU.

Two variants, both driven by the static block-neighbor table built from the
paper's lambda/nu maps (compact.BlockLayout.neighbor_table):

  * ``life_step_blocks``  (v1, paper-shaped): the Pallas grid walks compact
    blocks; the 8 Moore neighbor *blocks* are brought into VMEM through
    scalar-prefetch-dependent BlockSpec index maps (the TPU analogue of the
    paper's per-block shared-memory staging). Read amplification ~9x.

  * ``life_step_strips``  (v2, beyond-paper): the halo strips (2 rows,
    2 cols incl. corners) are pre-gathered by XLA into a (nb, 4, rho+2)
    array; the kernel reads center + strips only, cutting HBM traffic from
    ~9 rho^2 to ~rho^2 + 4 rho per block. See EXPERIMENTS.md §Perf.

Cell state is uint8; arithmetic runs int32 in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compact import BlockLayout


def _life_rule_tile(center: jnp.ndarray, padded: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """B3/S23 on one (rho+2, rho+2)-padded tile; returns uint8 (rho, rho)."""
    rho = center.shape[0]
    counts = jnp.zeros((rho, rho), jnp.int32)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dx == 0 and dy == 0:
                continue
            counts = counts + padded[1 + dy:rho + 1 + dy,
                                     1 + dx:rho + 1 + dx]
    born = counts == 3
    survive = (center > 0) & (counts == 2)
    return ((born | survive) & mask).astype(jnp.uint8)


# ======================================================================
# v1: neighbor blocks via scalar-prefetch index maps
# ======================================================================
def _blocks_kernel(tbl_ref, c_ref, nw, n_, ne, w_, e_, sw, s_, se, mask_ref,
                   out_ref):
    del tbl_ref
    rho = c_ref.shape[1]
    c = c_ref[0].astype(jnp.int32)
    padded = jnp.zeros((rho + 2, rho + 2), jnp.int32)
    padded = padded.at[1:-1, 1:-1].set(c)
    padded = padded.at[0, 0].set(nw[0, -1, -1].astype(jnp.int32))
    padded = padded.at[0, 1:-1].set(n_[0, -1, :].astype(jnp.int32))
    padded = padded.at[0, -1].set(ne[0, -1, 0].astype(jnp.int32))
    padded = padded.at[1:-1, 0].set(w_[0, :, -1].astype(jnp.int32))
    padded = padded.at[1:-1, -1].set(e_[0, :, 0].astype(jnp.int32))
    padded = padded.at[-1, 0].set(sw[0, 0, -1].astype(jnp.int32))
    padded = padded.at[-1, 1:-1].set(s_[0, 0, :].astype(jnp.int32))
    padded = padded.at[-1, -1].set(se[0, 0, 0].astype(jnp.int32))
    out_ref[0] = _life_rule_tile(c, padded, mask_ref[...] > 0)


@functools.partial(jax.jit, static_argnames=("layout", "interpret"))
def life_step_blocks(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: bool = True) -> jnp.ndarray:
    """One GoL step; state (n_blocks, rho, rho) uint8 -> same."""
    rho, nb = layout.rho, layout.n_blocks
    padded_src = jnp.concatenate(
        [state, jnp.zeros((1, rho, rho), state.dtype)], axis=0)
    table = jnp.asarray(layout.neighbor_table)  # (nb, 8), ghost = nb

    def center_idx(i, tbl):
        del tbl
        return (i, 0, 0)

    def nbr_idx(d):
        def idx(i, tbl):
            return (tbl[i, d], 0, 0)
        return idx

    blk = pl.BlockSpec((1, rho, rho), center_idx)
    in_specs = ([blk] + [pl.BlockSpec((1, rho, rho), nbr_idx(d))
                         for d in range(8)]
                + [pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0))])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rho, rho), center_idx),
    )
    return pl.pallas_call(
        _blocks_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, rho, rho), jnp.uint8),
        interpret=interpret,
    )(table, *([padded_src] * 9), jnp.asarray(layout.micro_mask))


# ======================================================================
# v2: pre-gathered halo strips (beyond-paper traffic optimization)
# ======================================================================
def _strips_kernel(c_ref, halo_ref, mask_ref, out_ref):
    rho = c_ref.shape[1]
    c = c_ref[0].astype(jnp.int32)
    halo = halo_ref[0].astype(jnp.int32)  # (4, rho+2)
    padded = jnp.zeros((rho + 2, rho + 2), jnp.int32)
    padded = padded.at[1:-1, 1:-1].set(c)
    padded = padded.at[0, :].set(halo[0])        # top row incl corners
    padded = padded.at[-1, :].set(halo[1])       # bottom row incl corners
    padded = padded.at[1:-1, 0].set(halo[2, :rho])   # west col
    padded = padded.at[1:-1, -1].set(halo[3, :rho])  # east col
    out_ref[0] = _life_rule_tile(c, padded, mask_ref[...] > 0)


def gather_halo_strips(layout: BlockLayout, state: jnp.ndarray) -> jnp.ndarray:
    """(nb, 4, rho+2) halo strips via strip-level XLA gathers.

    Only edge rows/cols of the neighbor blocks are touched (~4 rho per block
    instead of 8 rho^2), which is the v2 traffic win.
    """
    rho, nb = layout.rho, layout.n_blocks
    table = jnp.asarray(layout.neighbor_table)
    z_row = jnp.zeros((1, rho), state.dtype)
    z_cell = jnp.zeros((1,), state.dtype)

    bottom = jnp.concatenate([state[:, -1, :], z_row], 0)   # (nb+1, rho)
    top = jnp.concatenate([state[:, 0, :], z_row], 0)
    east = jnp.concatenate([state[:, :, -1], z_row], 0)
    west = jnp.concatenate([state[:, :, 0], z_row], 0)
    se_c = jnp.concatenate([state[:, -1, -1], z_cell], 0)   # (nb+1,)
    sw_c = jnp.concatenate([state[:, -1, 0], z_cell], 0)
    ne_c = jnp.concatenate([state[:, 0, -1], z_cell], 0)
    nw_c = jnp.concatenate([state[:, 0, 0], z_cell], 0)

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    row_top = jnp.concatenate([
        se_c[table[:, 0]][:, None],          # my NW corner = NW nbr's SE cell
        bottom[table[:, 1]],                 # N nbr's bottom row
        sw_c[table[:, 2]][:, None],          # NE nbr's SW cell
    ], axis=1)                               # (nb, rho+2)
    row_bot = jnp.concatenate([
        ne_c[table[:, 5]][:, None],          # SW nbr's NE cell
        top[table[:, 6]],                    # S nbr's top row
        nw_c[table[:, 7]][:, None],          # SE nbr's NW cell
    ], axis=1)
    col_w = jnp.pad(east[table[:, 3]], ((0, 0), (0, 2)))    # W nbr's east col
    col_e = jnp.pad(west[table[:, 4]], ((0, 0), (0, 2)))    # E nbr's west col
    return jnp.stack([row_top, row_bot, col_w, col_e], axis=1)


@functools.partial(jax.jit, static_argnames=("layout", "interpret"))
def life_step_strips(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: bool = True) -> jnp.ndarray:
    """One GoL step, v2 (strip halos); state (n_blocks, rho, rho) uint8."""
    rho, nb = layout.rho, layout.n_blocks
    halo = gather_halo_strips(layout, state)
    return pl.pallas_call(
        _strips_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rho, rho), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, 4, rho + 2), lambda i: (i, 0, 0)),
                  pl.BlockSpec((rho, rho), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, rho, rho), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, rho, rho), jnp.uint8),
        interpret=interpret,
    )(state, halo, jnp.asarray(layout.micro_mask))


# ======================================================================
# v3: strip reads fused into the kernel (scalar-prefetch index maps) —
# no materialized (nb, 4, rho+2) halo array (EXPERIMENTS.md §Perf)
# ======================================================================
def _fused_kernel(tbl_ref, c_ref, top, bot, west, east,
                  c_nw, c_ne, c_sw, c_se, mask_ref, out_ref):
    del tbl_ref
    rho = c_ref.shape[1]
    c = c_ref[0].astype(jnp.int32)
    padded = jnp.zeros((rho + 2, rho + 2), jnp.int32)
    padded = padded.at[1:-1, 1:-1].set(c)
    # neighbor strips (each ref already indexed at the right block)
    padded = padded.at[0, 1:-1].set(bot[0].astype(jnp.int32))   # N's bottom
    padded = padded.at[-1, 1:-1].set(top[0].astype(jnp.int32))  # S's top
    padded = padded.at[1:-1, 0].set(east[0].astype(jnp.int32))  # W's east
    padded = padded.at[1:-1, -1].set(west[0].astype(jnp.int32))  # E's west
    padded = padded.at[0, 0].set(c_nw[0, 0].astype(jnp.int32))
    padded = padded.at[0, -1].set(c_ne[0, 0].astype(jnp.int32))
    padded = padded.at[-1, 0].set(c_sw[0, 0].astype(jnp.int32))
    padded = padded.at[-1, -1].set(c_se[0, 0].astype(jnp.int32))
    out_ref[0] = _life_rule_tile(c, padded, mask_ref[...] > 0)


@functools.partial(jax.jit, static_argnames=("layout", "interpret"))
def life_step_fused(layout: BlockLayout, state: jnp.ndarray, *,
                    interpret: bool = True) -> jnp.ndarray:
    """One GoL step, v3: per-direction strip/corner arrays are built with
    contiguous XLA slices and the kernel reads the neighbor's strip
    directly through a table-dependent BlockSpec — the halo tensor of v2
    is never materialised (saves ~8(rho+2) HBM bytes/block/step)."""
    rho, nb = layout.rho, layout.n_blocks
    z_row = jnp.zeros((1, rho), state.dtype)
    z1 = jnp.zeros((1, 1), state.dtype)
    top = jnp.concatenate([state[:, 0, :], z_row], 0)       # (nb+1, rho)
    bot = jnp.concatenate([state[:, -1, :], z_row], 0)
    west = jnp.concatenate([state[:, :, 0], z_row], 0)
    east = jnp.concatenate([state[:, :, -1], z_row], 0)
    c_nw = jnp.concatenate([state[:, 0, 0:1], z1], 0)        # (nb+1, 1)
    c_ne = jnp.concatenate([state[:, 0, -1:], z1], 0)
    c_sw = jnp.concatenate([state[:, -1, 0:1], z1], 0)
    c_se = jnp.concatenate([state[:, -1, -1:], z1], 0)

    table = jnp.asarray(layout.neighbor_table)  # ghost == nb

    def at(d):
        def idx(i, tbl):
            return (tbl[i, d], 0)
        return idx

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    row = lambda f: pl.BlockSpec((1, rho), f)       # noqa: E731
    cell = lambda f: pl.BlockSpec((1, 1), f)        # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, rho, rho), lambda i, tbl: (i, 0, 0)),
            row(at(6)),   # S neighbor's top row
            row(at(1)),   # N neighbor's bottom row
            row(at(4)),   # E neighbor's west col
            row(at(3)),   # W neighbor's east col
            cell(at(0)), cell(at(2)), cell(at(5)), cell(at(7)),
            pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rho, rho), lambda i, tbl: (i, 0, 0)),
    )

    # corner args are the DIAGONAL neighbor's opposite corner: e.g. my NW
    # halo cell is the NW neighbor's SE corner, hence c_se @ tbl[:, NW]
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, rho, rho), jnp.uint8),
        interpret=interpret,
    )(table, state, top, bot, west, east,
      c_se, c_sw, c_ne, c_nw, jnp.asarray(layout.micro_mask))
