"""Fused block-level Squeeze stencil kernels on a compact NBB fractal,
paper Sections 3.5 + 4 adapted to TPU.

Three variants, all driven by the static block-neighbor table built from
the paper's lambda/nu maps (compact.BlockLayout.neighbor_table), and all
parameterized by a ``StencilWorkload`` whose ``tile_rule`` supplies the
traced in-tile update (the halo plumbing below is rule-agnostic):

  * ``stencil_step_blocks``  (v1, paper-shaped): the Pallas grid walks
    compact blocks; the 8 Moore neighbor *blocks* are brought into VMEM
    through scalar-prefetch-dependent BlockSpec index maps (the TPU
    analogue of the paper's per-block shared-memory staging). Read
    amplification ~9x.

  * ``stencil_step_strips``  (v2, beyond-paper): the halo strips (2 rows,
    2 cols incl. corners) are pre-gathered by XLA into a (C, nb, 4, rho+2)
    array; the kernel reads center + strips only, cutting HBM traffic from
    ~9 rho^2 to ~rho^2 + 4 rho per block. See EXPERIMENTS.md §Perf.

  * ``stencil_step_fused``   (v3): strip reads fused into the kernel via
    scalar-prefetch index maps — no materialized halo array.

  * ``stencil_step_fused_k`` (v4, temporal fusion): one depth-k halo
    gather, then k update substeps entirely in VMEM before the single
    write-back — per simulated step this divides the dispatch, gather and
    center HBM traffic by ~k at the cost of a (rho+2k)^2 working tile and
    redundant halo-ring compute. The per-block window occupancy needed by
    the substep mask discipline is reconstructed in-kernel from the shared
    periodic ``window_mask`` plus a scalar-prefetched block-existence
    table (see DESIGN.md Section 2).

  * ``stencil_step_mxu[_k]`` (v5, MXU stencil-as-matmul): the Moore
    aggregation is recast as <= 3 pairs of banded matmul contractions
    ``R_i @ X @ C_i^T`` (rank-1 SVD terms of the 3x3 weight matrix,
    ``workload.weight_factors``) so it runs on the MXU instead of 8 VPU
    shift-adds; P compact blocks are lane-packed per program so the
    ~128-lane registers are filled even at rho = 8-9, and
    ``stencil_step_mxu_batched`` adds a native (B, n_macro) batch grid —
    one dispatch for B simulations, sharing the scalar-prefetched
    existence table across the batch (see DESIGN.md Section 2.2).

The v2/v3 halo plumbing skips gathers the workload can never read: the
gathered direction set is derived from ``workload.weight(offset)``
(``halo_needs``), so e.g. HeatDiffusion (orthogonal-only) skips all four
corner gathers.

Public state is (nb, rho, rho) for single-channel workloads and
(C, nb, rho, rho) for multi-channel ones (e.g. Gray-Scott); the kernels
always run with an explicit channel axis internally. The ``life_step_*``
wrappers keep the original game-of-life entry points.

``interpret=None`` on every entry point means auto-detect: compiled
Mosaic on TPU, the Pallas interpreter elsewhere. Tests pass it
explicitly to stay deterministic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compact import BlockLayout, halo_regions
from repro.kernels.common import resolve_interpret
from repro.workloads.base import StencilWorkload, halo_needs
from repro.workloads.rules import LIFE


def _with_channels(workload: StencilWorkload, state: jnp.ndarray):
    """Canonicalize to (C, nb, rho, rho); returns (state_c, had_channels)."""
    if workload.n_channels > 1:
        return state, True
    return state[None], False


def _tile_update(workload: StencilWorkload, c, padded, mask):
    """Run the workload's tile rule on one (C, rho, rho) tile. The rule's
    ``apply`` sees the channel axis only for multi-channel workloads."""
    if workload.n_channels > 1:
        return workload.tile_rule(c, padded, mask)
    return workload.tile_rule(c[0], padded[0], mask)[None]


# ======================================================================
# v1: neighbor blocks via scalar-prefetch index maps
# ======================================================================
def _blocks_kernel(workload, tbl_ref, c_ref, nw, n_, ne, w_, e_, sw, s_, se,
                   mask_ref, out_ref):
    del tbl_ref
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    padded = padded.at[..., 0, 0].set(nw[:, 0, -1, -1])
    padded = padded.at[..., 0, 1:-1].set(n_[:, 0, -1, :])
    padded = padded.at[..., 0, -1].set(ne[:, 0, -1, 0])
    padded = padded.at[..., 1:-1, 0].set(w_[:, 0, :, -1])
    padded = padded.at[..., 1:-1, -1].set(e_[:, 0, :, 0])
    padded = padded.at[..., -1, 0].set(sw[:, 0, 0, -1])
    padded = padded.at[..., -1, 1:-1].set(s_[:, 0, 0, :])
    padded = padded.at[..., -1, -1].set(se[:, 0, 0, 0])
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def stencil_step_blocks(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """One workload step; state (C?, n_blocks, rho, rho) -> same."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_blocks(layout, state, workload,
                                interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_blocks(layout: BlockLayout, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE, *,
                         interpret: bool = True) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    padded_src = jnp.concatenate(
        [s, jnp.zeros((nc, 1, rho, rho), s.dtype)], axis=1)
    table = layout.dev_neighbor_table  # (nb, 8), ghost = nb

    def center_idx(i, tbl):
        del tbl
        return (0, i, 0, 0)

    def nbr_idx(d):
        def idx(i, tbl):
            return (0, tbl[i, d], 0, 0)
        return idx

    blk = pl.BlockSpec((nc, 1, rho, rho), center_idx)
    in_specs = ([blk] + [pl.BlockSpec((nc, 1, rho, rho), nbr_idx(d))
                         for d in range(8)]
                + [pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0))])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nc, 1, rho, rho), center_idx),
    )
    out = pl.pallas_call(
        functools.partial(_blocks_kernel, workload),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(table, *([padded_src] * 9), layout.dev_micro_mask)
    return out if chan else out[0]


# ======================================================================
# v2: pre-gathered halo strips (beyond-paper traffic optimization)
# ======================================================================
def _strips_kernel(workload, c_ref, halo_ref, mask_ref, out_ref):
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    halo = halo_ref[:, 0]                    # (C, 4, rho+2)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    padded = padded.at[..., 0, :].set(halo[:, 0])        # top row + corners
    padded = padded.at[..., -1, :].set(halo[:, 1])       # bottom row + corners
    padded = padded.at[..., 1:-1, 0].set(halo[:, 2, :rho])   # west col
    padded = padded.at[..., 1:-1, -1].set(halo[:, 3, :rho])  # east col
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def _gather_halo_strips(layout: BlockLayout, s: jnp.ndarray,
                        needs=None) -> jnp.ndarray:
    """(C, nb, 4, rho+2) halo strips via strip-level XLA gathers.

    Only edge rows/cols of the neighbor blocks are touched (~4 rho per block
    instead of 8 rho^2), which is the v2 traffic win. ``needs`` (a
    ``workloads.base.halo_needs`` tuple) drops the gathers the workload's
    zero-weight directions can never read — dead pieces become constant
    zeros instead of table gathers.
    """
    rho = layout.rho
    nc, nb = s.shape[0], layout.n_blocks
    need_n, need_s, need_w, need_e, need_nw, need_ne, need_sw, need_se = \
        needs if needs is not None else (True,) * 8
    table = layout.dev_neighbor_table
    z_row = jnp.zeros((nc, 1, rho), s.dtype)
    z_cell = jnp.zeros((nc, 1), s.dtype)
    z_row_nb = jnp.zeros((nc, nb, rho), s.dtype)
    z_cell_nb = jnp.zeros((nc, nb, 1), s.dtype)

    bottom = jnp.concatenate([s[:, :, -1, :], z_row], 1)   # (C, nb+1, rho)
    top = jnp.concatenate([s[:, :, 0, :], z_row], 1)
    east = jnp.concatenate([s[:, :, :, -1], z_row], 1)
    west = jnp.concatenate([s[:, :, :, 0], z_row], 1)
    se_c = jnp.concatenate([s[:, :, -1, -1], z_cell], 1)   # (C, nb+1)
    sw_c = jnp.concatenate([s[:, :, -1, 0], z_cell], 1)
    ne_c = jnp.concatenate([s[:, :, 0, -1], z_cell], 1)
    nw_c = jnp.concatenate([s[:, :, 0, 0], z_cell], 1)

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    row_top = jnp.concatenate([
        # my NW corner = NW nbr's SE cell
        se_c[:, table[:, 0], None] if need_nw else z_cell_nb,
        bottom[:, table[:, 1]] if need_n else z_row_nb,  # N nbr's bottom row
        sw_c[:, table[:, 2], None] if need_ne else z_cell_nb,  # NE's SW cell
    ], axis=2)                               # (C, nb, rho+2)
    row_bot = jnp.concatenate([
        ne_c[:, table[:, 5], None] if need_sw else z_cell_nb,  # SW's NE cell
        top[:, table[:, 6]] if need_s else z_row_nb,     # S nbr's top row
        nw_c[:, table[:, 7], None] if need_se else z_cell_nb,  # SE's NW cell
    ], axis=2)
    col_w = jnp.pad(east[:, table[:, 3]] if need_w else z_row_nb,
                    ((0, 0), (0, 0), (0, 2)))    # W nbr's east col
    col_e = jnp.pad(west[:, table[:, 4]] if need_e else z_row_nb,
                    ((0, 0), (0, 0), (0, 2)))    # E nbr's west col
    return jnp.stack([row_top, row_bot, col_w, col_e], axis=2)


def gather_halo_strips(layout: BlockLayout, state: jnp.ndarray) -> jnp.ndarray:
    """Single-channel legacy entry point: (nb, rho, rho) -> (nb, 4, rho+2)."""
    return _gather_halo_strips(layout, state[None])[0]


def stencil_step_strips(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """One workload step, v2 (strip halos); state (C?, n_blocks, rho, rho)."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_strips(layout, state, workload,
                                interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_strips(layout: BlockLayout, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE, *,
                         interpret: bool = True) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    halo = _gather_halo_strips(layout, s, halo_needs(workload.weights2d))
    out = pl.pallas_call(
        functools.partial(_strips_kernel, workload),
        grid=(nb,),
        in_specs=[pl.BlockSpec((nc, 1, rho, rho), lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((nc, 1, 4, rho + 2), lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((rho, rho), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((nc, 1, rho, rho), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(s, halo, layout.dev_micro_mask)
    return out if chan else out[0]


# ======================================================================
# v3: strip reads fused into the kernel (scalar-prefetch index maps) —
# no materialized (C, nb, 4, rho+2) halo array (EXPERIMENTS.md §Perf)
# ======================================================================
def _fused_kernel(workload, needs, tbl_ref, c_ref, top, bot, west, east,
                  c_nw, c_ne, c_sw, c_se, mask_ref, out_ref):
    del tbl_ref
    need_n, need_s, need_w, need_e, need_nw, need_ne, need_sw, need_se = needs
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    # neighbor strips (each ref already indexed at the right block); pieces
    # the workload's zero-weight directions never read stay zero
    if need_n:
        padded = padded.at[..., 0, 1:-1].set(bot[:, 0])      # N's bottom
    if need_s:
        padded = padded.at[..., -1, 1:-1].set(top[:, 0])     # S's top
    if need_w:
        padded = padded.at[..., 1:-1, 0].set(east[:, 0])     # W's east
    if need_e:
        padded = padded.at[..., 1:-1, -1].set(west[:, 0])    # E's west
    if need_nw:
        padded = padded.at[..., 0, 0].set(c_nw[:, 0, 0])
    if need_ne:
        padded = padded.at[..., 0, -1].set(c_ne[:, 0, 0])
    if need_sw:
        padded = padded.at[..., -1, 0].set(c_sw[:, 0, 0])
    if need_se:
        padded = padded.at[..., -1, -1].set(c_se[:, 0, 0])
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def stencil_step_fused(layout: BlockLayout, state: jnp.ndarray,
                       workload: StencilWorkload = LIFE, *,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """v3 entry point (fused strip reads); see ``_stencil_step_fused``."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_fused(layout, state, workload,
                               interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_fused(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: bool = True) -> jnp.ndarray:
    """One workload step, v3: per-direction strip/corner arrays are built
    with contiguous XLA slices and the kernel reads the neighbor's strip
    directly through a table-dependent BlockSpec — the halo tensor of v2
    is never materialised (saves ~8(rho+2) HBM bytes/block/step). Dead
    directions (zero workload weight) get a constant zero operand with a
    constant index map instead of a table-dependent strip read."""
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    need_n, need_s, need_w, need_e, need_nw, need_ne, need_sw, need_se = \
        needs = halo_needs(workload.weights2d)
    z_row = jnp.zeros((nc, 1, rho), s.dtype)
    z1 = jnp.zeros((nc, 1, 1), s.dtype)
    top = jnp.concatenate([s[:, :, 0, :], z_row], 1)     # (C, nb+1, rho)
    bot = jnp.concatenate([s[:, :, -1, :], z_row], 1)
    west = jnp.concatenate([s[:, :, :, 0], z_row], 1)
    east = jnp.concatenate([s[:, :, :, -1], z_row], 1)
    c_nw = jnp.concatenate([s[:, :, 0, 0:1], z1], 1)     # (C, nb+1, 1)
    c_ne = jnp.concatenate([s[:, :, 0, -1:], z1], 1)
    c_sw = jnp.concatenate([s[:, :, -1, 0:1], z1], 1)
    c_se = jnp.concatenate([s[:, :, -1, -1:], z1], 1)

    table = layout.dev_neighbor_table  # ghost == nb

    def at(d):
        def idx(i, tbl):
            return (0, tbl[i, d], 0)
        return idx

    def const_idx(i, tbl):
        return (0, 0, 0)

    def row_in(arr, d, need):
        """(operand, spec) for an edge-strip input: the neighbor's strip
        through the table, or a single constant zero row when dead."""
        if need:
            return arr, pl.BlockSpec((nc, 1, rho), at(d))
        return z_row, pl.BlockSpec((nc, 1, rho), const_idx)

    def cell_in(arr, d, need):
        if need:
            return arr, pl.BlockSpec((nc, 1, 1), at(d))
        return z1, pl.BlockSpec((nc, 1, 1), const_idx)

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE. Corner args are the
    # DIAGONAL neighbor's opposite corner: e.g. my NW halo cell is the NW
    # neighbor's SE corner, hence c_se @ tbl[:, NW].
    operands_specs = [
        row_in(top, 6, need_s),    # S neighbor's top row
        row_in(bot, 1, need_n),    # N neighbor's bottom row
        row_in(west, 4, need_e),   # E neighbor's west col
        row_in(east, 3, need_w),   # W neighbor's east col
        cell_in(c_se, 0, need_nw), cell_in(c_sw, 2, need_ne),
        cell_in(c_ne, 5, need_sw), cell_in(c_nw, 7, need_se),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=(
            [pl.BlockSpec((nc, 1, rho, rho), lambda i, tbl: (0, i, 0, 0))]
            + [spec for _, spec in operands_specs]
            + [pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0))]),
        out_specs=pl.BlockSpec((nc, 1, rho, rho), lambda i, tbl: (0, i, 0, 0)),
    )

    out = pl.pallas_call(
        functools.partial(_fused_kernel, workload, needs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(table, s, *[arr for arr, _ in operands_specs],
      layout.dev_micro_mask)
    return out if chan else out[0]


# ======================================================================
# v4: temporal fusion — depth-k halo gathered once, k substeps in VMEM
# ======================================================================
#: re-exported from core.compact (the distributed engine shares it)
_halo_regions = halo_regions


def _fused_k_kernel(workload, k, ex_ref, c_ref, top_ref, bot_ref, west_ref,
                    east_ref, mask_ref, out_ref):
    """One grid step = one block: assemble the (C, rho+2k, rho+2k) tile,
    rebuild its occupancy (periodic window mask x prefetched block
    existence), then run the workload's k fused substeps in VMEM."""
    rho = c_ref.shape[-1]
    w = rho + 2 * k
    c = c_ref[:, 0]                          # (C, rho, rho)
    padded = jnp.zeros(c.shape[:-2] + (w, w), c.dtype)
    padded = padded.at[..., k:k + rho, k:k + rho].set(c)
    padded = padded.at[..., :k, :].set(top_ref[:, 0])
    padded = padded.at[..., -k:, :].set(bot_ref[:, 0])
    padded = padded.at[..., k:k + rho, :k].set(west_ref[:, 0])
    padded = padded.at[..., k:k + rho, -k:].set(east_ref[:, 0])

    # the k-substep mask discipline: gate each halo region of the shared
    # periodic occupancy by this block's neighbor existence so ghost cells
    # stay zero at every substep, not just at the final write
    i = pl.program_id(0)
    mask = mask_ref[...].astype(jnp.int32)
    for d, (ys, xs) in enumerate(_halo_regions(rho, k)):
        mask = mask.at[ys, xs].set(mask[ys, xs] * ex_ref[i, d])

    if workload.n_channels > 1:
        nxt = workload.tile_rule_k(padded, mask, k)
    else:
        nxt = workload.tile_rule_k(padded[0], mask, k)[None]
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def _gather_halo_k(layout: BlockLayout, s: jnp.ndarray, k: int):
    """Depth-k halo strips via strip-level XLA gathers over the static
    neighbor table (k <= rho, so every strip comes from one Moore
    neighbor): top/bot (C, nb, k, rho+2k) full-width rows including the
    k x k diagonal corners, west/east (C, nb, rho, k) center columns.
    Ghost ids index an appended zero strip.

    No zero-weight skipping here: a k>=2 substep chain propagates corner
    values inward even under orthogonal-only weights (the dependency cone
    is the radius-k L1 ball), so every strip is live.
    """
    rho = layout.rho
    nc = s.shape[0]
    table = layout.dev_neighbor_table

    def take(strip, d):  # strip (C, nb, h, w), pre-sliced before the gather
        z = jnp.zeros((nc, 1) + strip.shape[2:], s.dtype)
        return jnp.concatenate([strip, z], 1)[:, table[:, d]]

    # MOORE_DIRS order: NW 0, N 1, NE 2, W 3, E 4, SW 5, S 6, SE 7
    top = jnp.concatenate([take(s[:, :, rho - k:, rho - k:], 0),
                           take(s[:, :, rho - k:, :], 1),
                           take(s[:, :, rho - k:, :k], 2)], axis=-1)
    bot = jnp.concatenate([take(s[:, :, :k, rho - k:], 5),
                           take(s[:, :, :k, :], 6),
                           take(s[:, :, :k, :k], 7)], axis=-1)
    west = take(s[:, :, :, rho - k:], 3)
    east = take(s[:, :, :, :k], 4)
    return top, bot, west, east


def stencil_step_fused_k(layout: BlockLayout, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE, *, k: int = 2,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
    """v4: advance ``k`` exact steps in ONE kernel launch.

    The depth-k halo is gathered once; the kernel runs k update substeps
    on a (rho+2k)^2 tile held in VMEM (window shrinking by one ring per
    substep) and writes the center back once — dispatch, table gather and
    center HBM traffic are paid once per k simulated steps. Requires
    k <= rho (deeper halos span multiple block rings; use the engines'
    XLA fallback ``SqueezeBlockEngine.step_k`` beyond that).
    state (C?, n_blocks, rho, rho) -> same, k steps later.
    """
    if k < 1:
        raise ValueError(f"need k >= 1, got k={k}")
    if k > layout.rho:
        raise ValueError(
            f"fused kernel needs k <= rho, got k={k} > rho={layout.rho} "
            "(use SqueezeBlockEngine.step_k for deeper-than-one-block halos)")
    # static geometry built outside the trace — only what v4 reads (the
    # per-block halo_mask of the XLA path is reconstructed in-kernel)
    layout.materialize()
    _ = layout.dev_existence_table, layout.dev_window_mask(k)
    return _stencil_step_fused_k(layout, state, workload, k,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "k", "interpret"))
def _stencil_step_fused_k(layout: BlockLayout, state: jnp.ndarray,
                          workload: StencilWorkload, k: int, *,
                          interpret: bool) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    w = rho + 2 * k
    top, bot, west, east = _gather_halo_k(layout, s, k)
    existence = layout.dev_existence_table               # (nb, 8) int32 0/1
    wmask = layout.dev_window_mask(k)                    # shared, periodic

    blk = lambda *shape: pl.BlockSpec(shape, lambda i, ex: (0, i) + (0,) * (len(shape) - 2))  # noqa: E731,E501
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            blk(nc, 1, rho, rho),
            blk(nc, 1, k, w), blk(nc, 1, k, w),      # top, bot rows
            blk(nc, 1, rho, k), blk(nc, 1, rho, k),  # west, east cols
            pl.BlockSpec((w, w), lambda i, ex: (0, 0)),
        ],
        out_specs=blk(nc, 1, rho, rho),
    )
    out = pl.pallas_call(
        functools.partial(_fused_k_kernel, workload, k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(existence, s, top, bot, west, east, wmask)
    return out if chan else out[0]


# ======================================================================
# v5: MXU stencil-as-matmul — lane-packed macro-tiles + native batch grid
# ======================================================================
@functools.lru_cache(maxsize=128)
def _mxu_operators(workload: StencilWorkload, w: int, p: int):
    """Static MXU contraction operands for one (workload, window, pack):
    ``R`` (T, w, w) banded row contractions and ``CT`` (T, p*w, p*w), the
    block-diagonal (per lane-packed slot) transpose of the banded column
    contractions, so the kernel's whole Moore aggregation is
    ``sum_t (R[t] @ X) @ CT[t]`` — two MXU matmuls per rank-1 term.
    float32 host build; cached per workload (the factor count T <= 3)."""
    from repro.workloads.base import banded_operators
    rm, cm = banded_operators(workload.weight_factors, w, np.float32)
    t = rm.shape[0]
    ct = np.zeros((t, p * w, p * w), np.float32)
    for i in range(t):
        for s in range(p):
            ct[i, s * w:(s + 1) * w, s * w:(s + 1) * w] = cm[i].T
    return rm, ct


def _mxu_kernel(workload, k, p, n_terms, ex_ref, c_ref, top_ref, bot_ref,
                west_ref, east_ref, wmask_ref, r_ref, ct_ref, out_ref):
    """One grid step = one (batch element, macro-tile): assemble the
    (C, w, P*w) lane-packed window (w = rho+2k, P slots of width w),
    rebuild each slot's occupancy from the shared periodic window mask
    gated by its scalar-prefetched neighbor existence (the v4 discipline,
    per slot), then run k substeps whose Moore aggregation is the rank-1
    banded matmul pair per term — MXU contractions instead of 8 VPU
    shifts. Slot borders accumulate truncated-band garbage ring by ring
    (substep j corrupts cells closer than j to a slot edge); the center
    sits at distance >= k, so the final (C, rho, P*rho) extraction is
    exact — the same shrinking-window argument as v4, without shrinking
    the arrays."""
    rho = c_ref.shape[-2]
    w = rho + 2 * k
    nc = c_ref.shape[1]
    c = c_ref[0, :, 0]                       # (C, rho, P*rho)
    top = top_ref[0, :, 0]                   # (C, k, P*w)
    bot = bot_ref[0, :, 0]
    west = west_ref[0, :, 0]                 # (C, rho, P*k)
    east = east_ref[0, :, 0]
    i = pl.program_id(1)

    cur = jnp.zeros((nc, w, p * w), c.dtype)
    mask = jnp.zeros((w, p * w), jnp.int32)
    wm = wmask_ref[...].astype(jnp.int32)
    for s in range(p):
        b0 = s * w
        cur = cur.at[:, k:k + rho, b0 + k:b0 + k + rho].set(
            c[:, :, s * rho:(s + 1) * rho])
        cur = cur.at[:, :k, b0:b0 + w].set(top[:, :, s * w:(s + 1) * w])
        cur = cur.at[:, w - k:, b0:b0 + w].set(bot[:, :, s * w:(s + 1) * w])
        cur = cur.at[:, k:k + rho, b0:b0 + k].set(
            west[:, :, s * k:(s + 1) * k])
        cur = cur.at[:, k:k + rho, b0 + k + rho:b0 + w].set(
            east[:, :, s * k:(s + 1) * k])
        m = wm
        for d, (ys, xs) in enumerate(_halo_regions(rho, k)):
            m = m.at[ys, xs].set(m[ys, xs] * ex_ref[i * p + s, d])
        # P=1 degenerates the slot update to a whole-array write, which
        # jnp lowers to a scatter with an empty index constant that
        # pallas refuses to capture — assign directly instead
        mask = m if p == 1 else mask.at[:, b0:b0 + w].set(m)

    rm = r_ref[...]                          # (T, w, w) f32
    ct = ct_ref[...]                         # (T, P*w, P*w) f32
    int_agg = jnp.issubdtype(jnp.dtype(workload.agg_dtype), jnp.integer)
    for _ in range(k):
        x = cur.astype(jnp.float32)
        aggs = []
        for ci in range(nc):
            a = jnp.zeros((w, p * w), jnp.float32)
            for t in range(n_terms):
                y = jax.lax.dot(rm[t], x[ci],
                                preferred_element_type=jnp.float32)
                a = a + jax.lax.dot(y, ct[t],
                                    preferred_element_type=jnp.float32)
            aggs.append(a)
        agg = jnp.stack(aggs)
        # integer CA aggregates: the f32 matmul reconstructs integer
        # neighbor counts to ~1e-5, so nearest-int rounding is bit-exact
        agg = (jnp.rint(agg).astype(workload.agg_dtype) if int_agg
               else agg.astype(workload.agg_dtype))
        if workload.n_channels > 1:
            nxt = workload.apply(cur, agg, mask)
        else:
            nxt = workload.apply(cur[0], agg[0], mask)[None]
        cur = nxt.astype(c.dtype)

    out = jnp.zeros((nc, rho, p * rho), out_ref.dtype)
    for s in range(p):
        sl = cur[:, k:k + rho, s * w + k:s * w + k + rho].astype(out.dtype)
        # same P=1 whole-array degeneracy as the mask assembly above
        out = sl if p == 1 else out.at[:, :, s * rho:(s + 1) * rho].set(sl)
    out_ref[0, :, 0] = out


def _pack_macro(arr: jnp.ndarray, nb: int, p: int, n_macro: int):
    """(L, nb, h, c) per-block strips -> (L, n_macro, h, P*c) lane-packed
    macro strips (zero-filled padding slots past nb)."""
    lead, _, h, cols = arr.shape
    pad = jnp.zeros((lead, n_macro * p - nb, h, cols), arr.dtype)
    a = jnp.concatenate([arr, pad], axis=1)
    a = a.reshape(lead, n_macro, p, h, cols).transpose(0, 1, 3, 2, 4)
    return a.reshape(lead, n_macro, h, p * cols)


def stencil_step_mxu_batched(layout: BlockLayout, states: jnp.ndarray,
                             workload: StencilWorkload = LIFE, *, k: int = 1,
                             p: Optional[int] = None,
                             interpret: Optional[bool] = None) -> jnp.ndarray:
    """v5, native batch grid: advance B independent simulations ``k`` exact
    steps in ONE kernel dispatch over a (B, n_macro) grid.

    states (B, C?, n_blocks, rho, rho) -> same, k steps later. The halo
    strips are pre-gathered v2-style but emitted macro-tile-contiguous (P
    blocks lane-packed per program, P*(rho+2k) ~ 128 lanes); the
    scalar-prefetched existence table is shared across the whole batch
    instead of being re-staged per simulation by a vmap of pallas_call.
    Requires k <= rho (one block ring, as v4). ``p`` overrides the
    macro-tile packing P (None = the ``macro_tiles`` lane heuristic; the
    autotuner sweeps explicit values).
    """
    if k < 1:
        raise ValueError(f"need k >= 1, got k={k}")
    if k > layout.rho:
        raise ValueError(
            f"mxu kernel needs k <= rho, got k={k} > rho={layout.rho} "
            "(use SqueezeBlockEngine.step_k for deeper-than-one-block halos)")
    # static geometry + operators built outside the trace; the packing
    # override is resolved to its concrete P here so the jit cache and
    # the layout memos key on one value (explicit P equal to the lane
    # heuristic's choice shares the compiled kernel)
    p = layout.macro_tiles(k, p=p)[0]
    layout.materialize()
    _ = layout.dev_existence_padded(k, p=p), layout.dev_window_mask(k)
    _ = _mxu_operators(workload, layout.rho + 2 * k, p)
    return _stencil_step_mxu_batched(layout, states, workload, k, p,
                                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "k", "p",
                                    "interpret"))
def _stencil_step_mxu_batched(layout: BlockLayout, states: jnp.ndarray,
                              workload: StencilWorkload, k: int,
                              p: Optional[int] = None, *,
                              interpret: bool) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    w = rho + 2 * k
    p, n_macro, _ = layout.macro_tiles(k, p=p)
    chan = workload.n_channels > 1
    s = states if chan else states[:, None]  # (B, C, nb, rho, rho)
    b, nc = s.shape[0], s.shape[1]
    # strip gathers are independent per leading axis: fold (B, C) into one
    flat = s.reshape(b * nc, nb, rho, rho)
    top, bot, west, east = _gather_halo_k(layout, flat, k)

    def pack(arr):  # -> (B, C, n_macro, h, P*cols)
        m = _pack_macro(arr, nb, p, n_macro)
        return m.reshape((b, nc) + m.shape[1:])

    cm, topm, botm = pack(flat), pack(top), pack(bot)
    westm, eastm = pack(west), pack(east)
    rm, ct = _mxu_operators(workload, w, p)
    n_terms = rm.shape[0]

    def blk(h, cols):
        return pl.BlockSpec((1, nc, 1, h, cols),
                            lambda bi, i, ex: (bi, 0, i, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_macro),
        in_specs=[
            blk(rho, p * rho),
            blk(k, p * w), blk(k, p * w),      # top, bot macro rows
            blk(rho, p * k), blk(rho, p * k),  # west, east macro cols
            pl.BlockSpec((w, w), lambda bi, i, ex: (0, 0)),
            pl.BlockSpec((n_terms, w, w), lambda bi, i, ex: (0, 0, 0)),
            pl.BlockSpec((n_terms, p * w, p * w),
                         lambda bi, i, ex: (0, 0, 0)),
        ],
        out_specs=blk(rho, p * rho),
    )
    out = pl.pallas_call(
        functools.partial(_mxu_kernel, workload, k, p, n_terms),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nc, n_macro, rho, p * rho),
                                       workload.dtype),
        interpret=interpret,
    )(layout.dev_existence_padded(k, p=p), cm, topm, botm, westm, eastm,
      layout.dev_window_mask(k), jnp.asarray(rm), jnp.asarray(ct))
    out = out.reshape(b, nc, n_macro, rho, p, rho).transpose(0, 1, 2, 4, 3, 5)
    out = out.reshape(b, nc, n_macro * p, rho, rho)[:, :, :nb]
    return out if chan else out[:, 0]


def stencil_step_mxu(layout: BlockLayout, state: jnp.ndarray,
                     workload: StencilWorkload = LIFE, *,
                     p: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """One workload step, v5 (MXU stencil-as-matmul on lane-packed
    macro-tiles); state (C?, n_blocks, rho, rho) -> same. ``p``
    overrides the macro-tile packing (None = lane heuristic)."""
    return stencil_step_mxu_batched(layout, state[None], workload, k=1,
                                    p=p, interpret=interpret)[0]


def stencil_step_mxu_k(layout: BlockLayout, state: jnp.ndarray,
                       workload: StencilWorkload = LIFE, *, k: int = 2,
                       p: Optional[int] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """v5 temporal fusion: k exact steps in one MXU macro-tile launch,
    reusing the v4 mask discipline (k <= rho). ``p`` overrides the
    macro-tile packing (None = lane heuristic)."""
    return stencil_step_mxu_batched(layout, state[None], workload, k=k,
                                    p=p, interpret=interpret)[0]


# ======================================================================
# shard-local entry points — the distributed engine's compute halves.
#
# core/distributed.py exchanges depth-k edge strips (ONE all_gather per k
# steps) and assembles the same halo-piece shapes ``_gather_halo_k``
# produces; these entries run the v4 / v5 kernels on one shard's local
# blocks given those pre-assembled pieces. They are traced inline inside
# shard_map (no jit wrapper here — the enclosing distributed step is the
# compilation unit), and the caller materializes the static geometry
# (dev_window_mask, MXU operators) outside the trace.
# ======================================================================
def stencil_step_fused_k_local(layout: BlockLayout, state: jnp.ndarray,
                               halo, existence: jnp.ndarray,
                               workload: StencilWorkload, *, k: int,
                               interpret: Optional[bool] = None
                               ) -> jnp.ndarray:
    """Shard-local v4: ``k`` fused substeps over local blocks.

    state (C, nbl, rho, rho); ``halo`` = (top, bot, west, east) with
    top/bot (C, nbl, k, rho+2k) and west/east (C, nbl, rho, k);
    ``existence`` (nbl, 8) int32 {0,1} Moore-neighbor existence of the
    local blocks (padding blocks: all zero). Returns (C, nbl, rho, rho).
    """
    rho = layout.rho
    nc, nbl = state.shape[0], state.shape[1]
    w = rho + 2 * k
    top, bot, west, east = halo
    blk = lambda *shape: pl.BlockSpec(shape, lambda i, ex: (0, i) + (0,) * (len(shape) - 2))  # noqa: E731,E501
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbl,),
        in_specs=[
            blk(nc, 1, rho, rho),
            blk(nc, 1, k, w), blk(nc, 1, k, w),      # top, bot rows
            blk(nc, 1, rho, k), blk(nc, 1, rho, k),  # west, east cols
            pl.BlockSpec((w, w), lambda i, ex: (0, 0)),
        ],
        out_specs=blk(nc, 1, rho, rho),
    )
    return pl.pallas_call(
        functools.partial(_fused_k_kernel, workload, k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nbl, rho, rho), workload.dtype),
        interpret=resolve_interpret(interpret),
    )(existence, state, top, bot, west, east, layout.dev_window_mask(k))


def stencil_step_mxu_k_local(layout: BlockLayout, states: jnp.ndarray,
                             halo, existence: jnp.ndarray,
                             workload: StencilWorkload, *, k: int,
                             p: Optional[int] = None,
                             interpret: Optional[bool] = None
                             ) -> jnp.ndarray:
    """Shard-local v5: ``k`` MXU macro-tile substeps of B simulations over
    local blocks, one (B, n_macro_local) grid.

    states (B, C, nbl, rho, rho); ``halo`` pieces carry matching (B, C)
    leading axes; ``existence`` (nbl, 8) as in the v4 local entry. The
    local blocks are lane-packed with ``macro_tiles_for(nbl, k)`` — each
    shard gets its own macro-tile geometry, sharing the kernel body,
    window mask and MXU operators with the single-device v5 path. ``p``
    overrides the per-shard packing (None = lane heuristic).
    """
    rho = layout.rho
    b, nc, nbl = states.shape[0], states.shape[1], states.shape[2]
    w = rho + 2 * k
    p, n_macro, nb_pad = layout.macro_tiles_for(nbl, k, p=p)
    top, bot, west, east = halo

    def pack(arr):  # (B, C, nbl, h, cols) -> (B, C, n_macro, h, P*cols)
        flat = arr.reshape((b * nc,) + arr.shape[2:])
        m = _pack_macro(flat, nbl, p, n_macro)
        return m.reshape((b, nc) + m.shape[1:])

    cm, topm, botm = pack(states), pack(top), pack(bot)
    westm, eastm = pack(west), pack(east)
    rm, ct = _mxu_operators(workload, w, p)
    n_terms = rm.shape[0]
    ex_pad = jnp.concatenate(
        [existence,
         jnp.zeros((nb_pad - nbl, 8), existence.dtype)], axis=0)

    def blk(h, cols):
        return pl.BlockSpec((1, nc, 1, h, cols),
                            lambda bi, i, ex: (bi, 0, i, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_macro),
        in_specs=[
            blk(rho, p * rho),
            blk(k, p * w), blk(k, p * w),      # top, bot macro rows
            blk(rho, p * k), blk(rho, p * k),  # west, east macro cols
            pl.BlockSpec((w, w), lambda bi, i, ex: (0, 0)),
            pl.BlockSpec((n_terms, w, w), lambda bi, i, ex: (0, 0, 0)),
            pl.BlockSpec((n_terms, p * w, p * w),
                         lambda bi, i, ex: (0, 0, 0)),
        ],
        out_specs=blk(rho, p * rho),
    )
    out = pl.pallas_call(
        functools.partial(_mxu_kernel, workload, k, p, n_terms),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nc, n_macro, rho, p * rho),
                                       workload.dtype),
        interpret=resolve_interpret(interpret),
    )(ex_pad, cm, topm, botm, westm, eastm,
      layout.dev_window_mask(k), jnp.asarray(rm), jnp.asarray(ct))
    out = out.reshape(b, nc, n_macro, rho, p, rho).transpose(0, 1, 2, 4, 3, 5)
    return out.reshape(b, nc, n_macro * p, rho, rho)[:, :, :nbl]


# ======================================================================
# 3D kernel family — defined in kernels/squeeze_stencil3d.py (the same
# v4/v5 designs over BlockLayout3D), re-exported here so the stencil
# kernel surface stays importable from one module.
# ======================================================================
from repro.kernels.squeeze_stencil3d import (  # noqa: E402,F401
    stencil3d_step_fused_k, stencil3d_step_mxu_k)


# ======================================================================
# legacy game-of-life entry points (kept for the original call sites)
# ======================================================================
def life_step_blocks(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """One GoL step; state (n_blocks, rho, rho) uint8 -> same."""
    return stencil_step_blocks(layout, state, LIFE, interpret=interpret)


def life_step_strips(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """One GoL step, v2 (strip halos); state (n_blocks, rho, rho) uint8."""
    return stencil_step_strips(layout, state, LIFE, interpret=interpret)


def life_step_fused(layout: BlockLayout, state: jnp.ndarray, *,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """One GoL step, v3 (in-kernel strip reads)."""
    return stencil_step_fused(layout, state, LIFE, interpret=interpret)
