"""Fused block-level Squeeze stencil kernels on a compact NBB fractal,
paper Sections 3.5 + 4 adapted to TPU.

Three variants, all driven by the static block-neighbor table built from
the paper's lambda/nu maps (compact.BlockLayout.neighbor_table), and all
parameterized by a ``StencilWorkload`` whose ``tile_rule`` supplies the
traced in-tile update (the halo plumbing below is rule-agnostic):

  * ``stencil_step_blocks``  (v1, paper-shaped): the Pallas grid walks
    compact blocks; the 8 Moore neighbor *blocks* are brought into VMEM
    through scalar-prefetch-dependent BlockSpec index maps (the TPU
    analogue of the paper's per-block shared-memory staging). Read
    amplification ~9x.

  * ``stencil_step_strips``  (v2, beyond-paper): the halo strips (2 rows,
    2 cols incl. corners) are pre-gathered by XLA into a (C, nb, 4, rho+2)
    array; the kernel reads center + strips only, cutting HBM traffic from
    ~9 rho^2 to ~rho^2 + 4 rho per block. See EXPERIMENTS.md §Perf.

  * ``stencil_step_fused``   (v3): strip reads fused into the kernel via
    scalar-prefetch index maps — no materialized halo array.

Public state is (nb, rho, rho) for single-channel workloads and
(C, nb, rho, rho) for multi-channel ones (e.g. Gray-Scott); the kernels
always run with an explicit channel axis internally. The ``life_step_*``
wrappers keep the original game-of-life entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compact import BlockLayout
from repro.workloads.base import StencilWorkload
from repro.workloads.rules import LIFE


def _with_channels(workload: StencilWorkload, state: jnp.ndarray):
    """Canonicalize to (C, nb, rho, rho); returns (state_c, had_channels)."""
    if workload.n_channels > 1:
        return state, True
    return state[None], False


def _tile_update(workload: StencilWorkload, c, padded, mask):
    """Run the workload's tile rule on one (C, rho, rho) tile. The rule's
    ``apply`` sees the channel axis only for multi-channel workloads."""
    if workload.n_channels > 1:
        return workload.tile_rule(c, padded, mask)
    return workload.tile_rule(c[0], padded[0], mask)[None]


# ======================================================================
# v1: neighbor blocks via scalar-prefetch index maps
# ======================================================================
def _blocks_kernel(workload, tbl_ref, c_ref, nw, n_, ne, w_, e_, sw, s_, se,
                   mask_ref, out_ref):
    del tbl_ref
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    padded = padded.at[..., 0, 0].set(nw[:, 0, -1, -1])
    padded = padded.at[..., 0, 1:-1].set(n_[:, 0, -1, :])
    padded = padded.at[..., 0, -1].set(ne[:, 0, -1, 0])
    padded = padded.at[..., 1:-1, 0].set(w_[:, 0, :, -1])
    padded = padded.at[..., 1:-1, -1].set(e_[:, 0, :, 0])
    padded = padded.at[..., -1, 0].set(sw[:, 0, 0, -1])
    padded = padded.at[..., -1, 1:-1].set(s_[:, 0, 0, :])
    padded = padded.at[..., -1, -1].set(se[:, 0, 0, 0])
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def stencil_step_blocks(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: bool = True) -> jnp.ndarray:
    """One workload step; state (C?, n_blocks, rho, rho) -> same."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_blocks(layout, state, workload, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_blocks(layout: BlockLayout, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE, *,
                         interpret: bool = True) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    padded_src = jnp.concatenate(
        [s, jnp.zeros((nc, 1, rho, rho), s.dtype)], axis=1)
    table = jnp.asarray(layout.neighbor_table)  # (nb, 8), ghost = nb

    def center_idx(i, tbl):
        del tbl
        return (0, i, 0, 0)

    def nbr_idx(d):
        def idx(i, tbl):
            return (0, tbl[i, d], 0, 0)
        return idx

    blk = pl.BlockSpec((nc, 1, rho, rho), center_idx)
    in_specs = ([blk] + [pl.BlockSpec((nc, 1, rho, rho), nbr_idx(d))
                         for d in range(8)]
                + [pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0))])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nc, 1, rho, rho), center_idx),
    )
    out = pl.pallas_call(
        functools.partial(_blocks_kernel, workload),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(table, *([padded_src] * 9), jnp.asarray(layout.micro_mask))
    return out if chan else out[0]


# ======================================================================
# v2: pre-gathered halo strips (beyond-paper traffic optimization)
# ======================================================================
def _strips_kernel(workload, c_ref, halo_ref, mask_ref, out_ref):
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    halo = halo_ref[:, 0]                    # (C, 4, rho+2)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    padded = padded.at[..., 0, :].set(halo[:, 0])        # top row + corners
    padded = padded.at[..., -1, :].set(halo[:, 1])       # bottom row + corners
    padded = padded.at[..., 1:-1, 0].set(halo[:, 2, :rho])   # west col
    padded = padded.at[..., 1:-1, -1].set(halo[:, 3, :rho])  # east col
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def _gather_halo_strips(layout: BlockLayout, s: jnp.ndarray) -> jnp.ndarray:
    """(C, nb, 4, rho+2) halo strips via strip-level XLA gathers.

    Only edge rows/cols of the neighbor blocks are touched (~4 rho per block
    instead of 8 rho^2), which is the v2 traffic win.
    """
    rho = layout.rho
    nc = s.shape[0]
    table = jnp.asarray(layout.neighbor_table)
    z_row = jnp.zeros((nc, 1, rho), s.dtype)
    z_cell = jnp.zeros((nc, 1), s.dtype)

    bottom = jnp.concatenate([s[:, :, -1, :], z_row], 1)   # (C, nb+1, rho)
    top = jnp.concatenate([s[:, :, 0, :], z_row], 1)
    east = jnp.concatenate([s[:, :, :, -1], z_row], 1)
    west = jnp.concatenate([s[:, :, :, 0], z_row], 1)
    se_c = jnp.concatenate([s[:, :, -1, -1], z_cell], 1)   # (C, nb+1)
    sw_c = jnp.concatenate([s[:, :, -1, 0], z_cell], 1)
    ne_c = jnp.concatenate([s[:, :, 0, -1], z_cell], 1)
    nw_c = jnp.concatenate([s[:, :, 0, 0], z_cell], 1)

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    row_top = jnp.concatenate([
        se_c[:, table[:, 0], None],          # my NW corner = NW nbr's SE cell
        bottom[:, table[:, 1]],              # N nbr's bottom row
        sw_c[:, table[:, 2], None],          # NE nbr's SW cell
    ], axis=2)                               # (C, nb, rho+2)
    row_bot = jnp.concatenate([
        ne_c[:, table[:, 5], None],          # SW nbr's NE cell
        top[:, table[:, 6]],                 # S nbr's top row
        nw_c[:, table[:, 7], None],          # SE nbr's NW cell
    ], axis=2)
    col_w = jnp.pad(east[:, table[:, 3]],
                    ((0, 0), (0, 0), (0, 2)))    # W nbr's east col
    col_e = jnp.pad(west[:, table[:, 4]],
                    ((0, 0), (0, 0), (0, 2)))    # E nbr's west col
    return jnp.stack([row_top, row_bot, col_w, col_e], axis=2)


def gather_halo_strips(layout: BlockLayout, state: jnp.ndarray) -> jnp.ndarray:
    """Single-channel legacy entry point: (nb, rho, rho) -> (nb, 4, rho+2)."""
    return _gather_halo_strips(layout, state[None])[0]


def stencil_step_strips(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: bool = True) -> jnp.ndarray:
    """One workload step, v2 (strip halos); state (C?, n_blocks, rho, rho)."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_strips(layout, state, workload, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_strips(layout: BlockLayout, state: jnp.ndarray,
                         workload: StencilWorkload = LIFE, *,
                         interpret: bool = True) -> jnp.ndarray:
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    halo = _gather_halo_strips(layout, s)
    out = pl.pallas_call(
        functools.partial(_strips_kernel, workload),
        grid=(nb,),
        in_specs=[pl.BlockSpec((nc, 1, rho, rho), lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((nc, 1, 4, rho + 2), lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((rho, rho), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((nc, 1, rho, rho), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(s, halo, jnp.asarray(layout.micro_mask))
    return out if chan else out[0]


# ======================================================================
# v3: strip reads fused into the kernel (scalar-prefetch index maps) —
# no materialized (C, nb, 4, rho+2) halo array (EXPERIMENTS.md §Perf)
# ======================================================================
def _fused_kernel(workload, tbl_ref, c_ref, top, bot, west, east,
                  c_nw, c_ne, c_sw, c_se, mask_ref, out_ref):
    del tbl_ref
    rho = c_ref.shape[-1]
    c = c_ref[:, 0]                          # (C, rho, rho)
    padded = jnp.zeros(c.shape[:-2] + (rho + 2, rho + 2), c.dtype)
    padded = padded.at[..., 1:-1, 1:-1].set(c)
    # neighbor strips (each ref already indexed at the right block)
    padded = padded.at[..., 0, 1:-1].set(bot[:, 0])      # N's bottom
    padded = padded.at[..., -1, 1:-1].set(top[:, 0])     # S's top
    padded = padded.at[..., 1:-1, 0].set(east[:, 0])     # W's east
    padded = padded.at[..., 1:-1, -1].set(west[:, 0])    # E's west
    padded = padded.at[..., 0, 0].set(c_nw[:, 0, 0])
    padded = padded.at[..., 0, -1].set(c_ne[:, 0, 0])
    padded = padded.at[..., -1, 0].set(c_sw[:, 0, 0])
    padded = padded.at[..., -1, -1].set(c_se[:, 0, 0])
    nxt = _tile_update(workload, c, padded, mask_ref[...])
    out_ref[:, 0] = nxt.astype(out_ref.dtype)


def stencil_step_fused(layout: BlockLayout, state: jnp.ndarray,
                       workload: StencilWorkload = LIFE, *,
                       interpret: bool = True) -> jnp.ndarray:
    """v3 entry point (fused strip reads); see ``_stencil_step_fused``."""
    layout.materialize()  # static tables must be built outside the trace
    return _stencil_step_fused(layout, state, workload, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("layout", "workload", "interpret"))
def _stencil_step_fused(layout: BlockLayout, state: jnp.ndarray,
                        workload: StencilWorkload = LIFE, *,
                        interpret: bool = True) -> jnp.ndarray:
    """One workload step, v3: per-direction strip/corner arrays are built
    with contiguous XLA slices and the kernel reads the neighbor's strip
    directly through a table-dependent BlockSpec — the halo tensor of v2
    is never materialised (saves ~8(rho+2) HBM bytes/block/step)."""
    rho, nb = layout.rho, layout.n_blocks
    s, chan = _with_channels(workload, state)
    nc = s.shape[0]
    z_row = jnp.zeros((nc, 1, rho), s.dtype)
    z1 = jnp.zeros((nc, 1, 1), s.dtype)
    top = jnp.concatenate([s[:, :, 0, :], z_row], 1)     # (C, nb+1, rho)
    bot = jnp.concatenate([s[:, :, -1, :], z_row], 1)
    west = jnp.concatenate([s[:, :, :, 0], z_row], 1)
    east = jnp.concatenate([s[:, :, :, -1], z_row], 1)
    c_nw = jnp.concatenate([s[:, :, 0, 0:1], z1], 1)     # (C, nb+1, 1)
    c_ne = jnp.concatenate([s[:, :, 0, -1:], z1], 1)
    c_sw = jnp.concatenate([s[:, :, -1, 0:1], z1], 1)
    c_se = jnp.concatenate([s[:, :, -1, -1:], z1], 1)

    table = jnp.asarray(layout.neighbor_table)  # ghost == nb

    def at(d):
        def idx(i, tbl):
            return (0, tbl[i, d], 0)
        return idx

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    row = lambda f: pl.BlockSpec((nc, 1, rho), f)       # noqa: E731
    cell = lambda f: pl.BlockSpec((nc, 1, 1), f)        # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nc, 1, rho, rho), lambda i, tbl: (0, i, 0, 0)),
            row(at(6)),   # S neighbor's top row
            row(at(1)),   # N neighbor's bottom row
            row(at(4)),   # E neighbor's west col
            row(at(3)),   # W neighbor's east col
            cell(at(0)), cell(at(2)), cell(at(5)), cell(at(7)),
            pl.BlockSpec((rho, rho), lambda i, tbl: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nc, 1, rho, rho), lambda i, tbl: (0, i, 0, 0)),
    )

    # corner args are the DIAGONAL neighbor's opposite corner: e.g. my NW
    # halo cell is the NW neighbor's SE corner, hence c_se @ tbl[:, NW]
    out = pl.pallas_call(
        functools.partial(_fused_kernel, workload),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nc, nb, rho, rho), workload.dtype),
        interpret=interpret,
    )(table, s, top, bot, west, east,
      c_se, c_sw, c_ne, c_nw, jnp.asarray(layout.micro_mask))
    return out if chan else out[0]


# ======================================================================
# legacy game-of-life entry points (kept for the original call sites)
# ======================================================================
def life_step_blocks(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: bool = True) -> jnp.ndarray:
    """One GoL step; state (n_blocks, rho, rho) uint8 -> same."""
    return stencil_step_blocks(layout, state, LIFE, interpret=interpret)


def life_step_strips(layout: BlockLayout, state: jnp.ndarray, *,
                     interpret: bool = True) -> jnp.ndarray:
    """One GoL step, v2 (strip halos); state (n_blocks, rho, rho) uint8."""
    return stencil_step_strips(layout, state, LIFE, interpret=interpret)


def life_step_fused(layout: BlockLayout, state: jnp.ndarray, *,
                    interpret: bool = True) -> jnp.ndarray:
    """One GoL step, v3 (in-kernel strip reads)."""
    return stencil_step_fused(layout, state, LIFE, interpret=interpret)
