"""Shared helpers for the Pallas kernel entry points."""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True off-TPU (run the Pallas interpreter), False on TPU (compile the
    Mosaic kernel). The kernels target TPU; every other backend (the CI
    container is CPU-only) gets the interpreter."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` means auto-detect via ``default_interpret``; explicit bools
    pass through unchanged (tests pass ``interpret=True`` so they stay
    deterministic on any backend)."""
    return default_interpret() if interpret is None else bool(interpret)
