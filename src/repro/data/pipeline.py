"""Data pipeline: stateless, step-indexed token batches.

Every batch is a pure function of (seed, step, shard) — no iterator state
to checkpoint, so restart-from-step-N is bit-exact by construction (the
fault-tolerance property the runtime tests rely on). Two sources:

  * ``SyntheticMarkov`` — Zipf-ish unigrams driven through a fixed random
    permutation bigram channel (next = perm[cur] w.p. ``p_signal``); has
    ~ -p log p + ... learnable structure so example training shows a real
    loss drop;
  * ``MemmapCorpus``  — a flat uint16/uint32 token file, random crops.

Batches are (tokens, labels) with labels the next-token shift. A
double-buffered background prefetcher overlaps host batch synthesis with
device compute (straggler mitigation at the input layer).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticMarkov:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_signal: float = 0.8
    #: this host's shard of the global batch
    shard: int = 0
    n_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_shards

    def _perm(self) -> np.ndarray:
        return np.random.default_rng(self.seed ^ 0xC0FFEE).permutation(
            self.vocab).astype(np.int32)

    def batch(self, step: int) -> dict:
        """Batch for global ``step`` (stateless; shard-disjoint)."""
        perm = self._perm()
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        b, s = self.local_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        noise = rng.random((b, s)) >= self.p_signal
        rand_next = rng.integers(0, self.vocab, size=(b, s))
        for t in range(s):
            nxt = perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class MemmapCorpus:
    """Flat binary token file; random crops, stateless per step."""
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> dict:
        data = np.memmap(self.path, dtype=self.dtype, mode="r")
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        b, s = self.local_batch, self.seq_len
        starts = rng.integers(0, len(data) - s - 1, size=b)
        toks = np.stack([data[i:i + s + 1] for i in starts]).astype(np.int32)
        toks = np.minimum(toks, self.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Double-buffered background prefetch of step-indexed batches."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
