"""Baseline fractal engines the paper compares against (Section 4):

  * ``BBEngine``      — the classic expanded bounding-box approach: both the
                        parallel grid and the memory are the full n x n
                        embedding (paper's approach 1).
  * ``LambdaEngine``  — Navarro et al. [7]: compact *grid* (one unit of work
                        per fractal cell, placed by lambda) but still
                        *expanded memory* (paper's approach 2). Solves P1,
                        not P2.

Both simulate Conway's game of life adapted to the fractal: only fractal
cells live or are counted as neighbors (holes and out-of-bounds read 0),
with the standard B3/S23 rule applied on fractal cells only.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import maps
from repro.core.compact import MOORE_DIRS
from repro.core.fractals import NBBFractal

Array = jnp.ndarray


def life_rule(alive: Array, neighbors: Array) -> Array:
    """Conway B3/S23, uint8 in/out."""
    born = neighbors == 3
    survive = (alive > 0) & (neighbors == 2)
    return (born | survive).astype(jnp.uint8)


def _moore_counts(padded: Array) -> Array:
    """Sum of the 8 Moore neighbors from a (+1)-padded 2D array."""
    c = None
    for dx, dy in MOORE_DIRS:
        sl = padded[1 + dy: padded.shape[0] - 1 + dy,
                    1 + dx: padded.shape[1] - 1 + dx]
        c = sl.astype(jnp.int32) if c is None else c + sl
    return c


@dataclasses.dataclass(frozen=True)
class BBEngine:
    """Expanded grid + expanded memory (the classic approach)."""

    frac: NBBFractal
    r: int

    def init_random(self, seed: int) -> Array:
        n = self.frac.side(self.r)
        mask = jnp.asarray(self.frac.mask(self.r))
        bits = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (n, n))
        return (bits & (mask > 0)).astype(jnp.uint8)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        mask = jnp.asarray(self.frac.mask(self.r))
        padded = jnp.pad(state, 1)
        nxt = life_rule(state, _moore_counts(padded))
        return nxt * mask

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        n = self.frac.side(self.r)
        return n * n * dtype_size


@dataclasses.dataclass(frozen=True)
class LambdaEngine:
    """Compact grid (via lambda), expanded memory — Navarro et al. [7].

    Work is enumerated over the k^r compact coordinates; each one lambda-maps
    to its expanded cell, reads its Moore neighborhood from expanded memory,
    and writes the updated cell back to expanded memory.
    """

    frac: NBBFractal
    r: int

    def init_random(self, seed: int) -> Array:
        return BBEngine(self.frac, self.r).init_random(seed)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r = self.frac, self.r
        rows, cols = frac.compact_dims(r)
        cy, cx = jnp.meshgrid(jnp.arange(rows, dtype=jnp.int32),
                              jnp.arange(cols, dtype=jnp.int32), indexing="ij")
        ex, ey = maps.lambda_map(frac, r, cx, cy)
        padded = jnp.pad(state, 1)
        count = jnp.zeros(ex.shape, jnp.int32)
        for dx, dy in MOORE_DIRS:
            count = count + padded[ey + 1 + dy, ex + 1 + dx].astype(jnp.int32)
        alive = state[ey, ex]
        nxt_vals = life_rule(alive, count)
        # scatter back into (a fresh copy of) expanded memory
        nxt = jnp.zeros_like(state)
        return nxt.at[ey, ex].set(nxt_vals)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        n = self.frac.side(self.r)
        return n * n * dtype_size
