"""Baseline fractal engines the paper compares against (Section 4):

  * ``BBEngine``      — the classic expanded bounding-box approach: both the
                        parallel grid and the memory are the full n x n
                        embedding (paper's approach 1).
  * ``LambdaEngine``  — Navarro et al. [7]: compact *grid* (one unit of work
                        per fractal cell, placed by lambda) but still
                        *expanded memory* (paper's approach 2). Solves P1,
                        not P2.

Both are parameterized by a ``StencilWorkload`` (default: the paper's
game-of-life adaptation): only fractal cells carry state or are counted as
neighbors (holes and out-of-bounds read 0 — dead for CA rules, Dirichlet-0
for the PDE rules), and the workload's update rule is applied on fractal
cells only. Multi-channel workloads carry a leading channel axis:
state (C, n, n).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import maps
from repro.core.compact import MOORE_DIRS
from repro.core.fractals import NBBFractal
from repro.workloads.base import (StencilWorkload, check_workload_ndim,
                                  weighted_gather_agg, weighted_moore_agg)
from repro.workloads.rules import LIFE, life_rule  # noqa: F401 (re-export)

Array = jnp.ndarray


def _moore_counts(padded: Array) -> Array:
    """Sum of the 8 Moore neighbors from a (+1)-padded array (trailing two
    axes are spatial; leading channel/block axes broadcast through)."""
    return weighted_moore_agg(padded, (1,) * 8, jnp.int32)


def _pad_spatial(state: Array) -> Array:
    """Zero-pad the trailing two (spatial) axes by 1."""
    pad = [(0, 0)] * (state.ndim - 2) + [(1, 1), (1, 1)]
    return jnp.pad(state, pad)


def _init_masked(workload: StencilWorkload, seed: int, shape,
                 mask: Array) -> Array:
    field = workload.init(jax.random.PRNGKey(seed), shape)
    return field * mask.astype(field.dtype)


@dataclasses.dataclass(frozen=True)
class BBEngine:
    """Expanded grid + expanded memory (the classic approach)."""

    frac: NBBFractal
    r: int
    workload: StencilWorkload = LIFE

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)

    def init_random(self, seed: int) -> Array:
        n = self.frac.side(self.r)
        mask = jnp.asarray(self.frac.mask(self.r))
        return _init_masked(self.workload, seed, (n, n), mask)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        mask = jnp.asarray(self.frac.mask(self.r))
        padded = _pad_spatial(state)
        agg = weighted_moore_agg(padded, wl.weights2d, wl.agg_dtype)
        return wl.apply(state, agg, mask)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        n = self.frac.side(self.r)
        return self.workload.n_channels * n * n * dtype_size


@dataclasses.dataclass(frozen=True)
class LambdaEngine:
    """Compact grid (via lambda), expanded memory — Navarro et al. [7].

    Work is enumerated over the k^r compact coordinates; each one lambda-maps
    to its expanded cell, reads its Moore neighborhood from expanded memory,
    and writes the updated cell back to expanded memory.
    """

    frac: NBBFractal
    r: int
    workload: StencilWorkload = LIFE

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)

    def init_random(self, seed: int) -> Array:
        return BBEngine(self.frac, self.r, self.workload).init_random(seed)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r, wl = self.frac, self.r, self.workload
        rows, cols = frac.compact_dims(r)
        cy, cx = jnp.meshgrid(jnp.arange(rows, dtype=jnp.int32),
                              jnp.arange(cols, dtype=jnp.int32), indexing="ij")
        ex, ey = maps.lambda_map(frac, r, cx, cy)
        padded = _pad_spatial(state)
        agg = weighted_gather_agg(
            MOORE_DIRS, wl.weights2d,
            lambda d: padded[..., ey + 1 + d[1], ex + 1 + d[0]],
            state.shape[:-2] + ex.shape, wl.agg_dtype)
        center = state[..., ey, ex]
        # every enumerated cell is a fractal cell: no mask needed
        nxt_vals = wl.apply(center, agg, None)
        # scatter back into (a fresh copy of) expanded memory
        nxt = jnp.zeros_like(state)
        return nxt.at[..., ey, ex].set(nxt_vals.astype(state.dtype))

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        n = self.frac.side(self.r)
        return self.workload.n_channels * n * n * dtype_size
