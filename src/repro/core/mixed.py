"""Mixed-level NBB fractals — the paper's §5 future work: "build arbitrary
fractal structures by combining different NBB fractals at each scale
level".

A MixedFractal is a bottom-up sequence of per-level generators
``levels = (F_1, ..., F_r)`` (level mu replicates with F_mu's slot set).
All NBB-class properties generalise with mixed radices:

  * side   n   = prod(s_mu), volume V = prod(k_mu);
  * compact domain: level mu's base-k_mu digit goes to axis x for odd mu,
    y for even mu (the paper's alternation), with mixed-radix place values
    Delta_mu = prod of k of earlier SAME-AXIS levels;
  * lambda/nu are the same offset accumulations with per-level (k, s, H).

The uniform case (all levels equal) reduces exactly to maps.py (tested).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.fractals import NBBFractal

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MixedFractal:
    """levels[mu-1] is the generator applied at scale level mu (bottom-up:
    levels[0] is the finest replication)."""

    name: str
    levels: Tuple[NBBFractal, ...]

    @property
    def r(self) -> int:
        return len(self.levels)

    @property
    def side(self) -> int:
        n = 1
        for f in self.levels:
            n *= f.s
        return n

    @property
    def volume(self) -> int:
        v = 1
        for f in self.levels:
            v *= f.k
        return v

    def compact_dims(self) -> Tuple[int, int]:
        rows = cols = 1
        for mu, f in enumerate(self.levels, start=1):
            if mu % 2 == 1:
                cols *= f.k
            else:
                rows *= f.k
        return rows, cols

    @functools.cached_property
    def _scales(self):
        """Per-level expanded place value prod(s_nu, nu<mu) and per-axis
        compact place values."""
        e_scale, x_place, y_place = [], [], []
        es, xp, yp = 1, 1, 1
        for mu, f in enumerate(self.levels, start=1):
            e_scale.append(es)
            es *= f.s
            if mu % 2 == 1:
                x_place.append(xp)
                y_place.append(None)
                xp *= f.k
            else:
                x_place.append(None)
                y_place.append(yp)
                yp *= f.k
        return e_scale, x_place, y_place

    def mask(self) -> np.ndarray:
        m = np.ones((1, 1), np.uint8)
        for f in self.levels:
            m = np.kron(f.replica_grid, m)
        return m

    # ------------------------------------------------------------- the maps
    def lambda_map(self, cx: Array, cy: Array) -> Tuple[Array, Array]:
        e_scale, x_place, y_place = self._scales
        cx = cx.astype(jnp.int32)
        cy = cy.astype(jnp.int32)
        ex = jnp.zeros_like(cx)
        ey = jnp.zeros_like(cy)
        for mu, f in enumerate(self.levels, start=1):
            if mu % 2 == 1:
                beta = (cx // x_place[mu - 1]) % f.k
            else:
                beta = (cy // y_place[mu - 1]) % f.k
            tau = jnp.asarray(f.h_lambda)[beta]
            ex = ex + tau[..., 0] * e_scale[mu - 1]
            ey = ey + tau[..., 1] * e_scale[mu - 1]
        return ex, ey

    def nu_map(self, ex: Array, ey: Array) -> Tuple[Array, Array, Array]:
        """-> (cx, cy, valid)."""
        e_scale, x_place, y_place = self._scales
        n = self.side
        inb = (ex >= 0) & (ex < n) & (ey >= 0) & (ey < n)
        exc = jnp.clip(ex, 0, n - 1).astype(jnp.int32)
        eyc = jnp.clip(ey, 0, n - 1).astype(jnp.int32)
        cx = jnp.zeros(exc.shape, jnp.int32)
        cy = jnp.zeros(eyc.shape, jnp.int32)
        valid = inb
        for mu, f in enumerate(self.levels, start=1):
            tx = (exc // e_scale[mu - 1]) % f.s
            ty = (eyc // e_scale[mu - 1]) % f.s
            code = jnp.asarray(f.h_nu)[ty, tx]
            valid = valid & (code >= 0)
            code = jnp.maximum(code, 0)
            if mu % 2 == 1:
                cx = cx + code * x_place[mu - 1]
            else:
                cy = cy + code * y_place[mu - 1]
        return cx, cy, valid

    def mrf(self) -> float:
        return float(self.side) ** 2 / float(self.volume)
