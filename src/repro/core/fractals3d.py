"""3D extension of Squeeze (the paper's §5 future work): NBB fractals in
three dimensions, with the lambda/nu space maps generalised to a 3-axis
digit interleaving.

A 3D NBB fractal F^{k,s} places k replicas on slots of an s x s x s grid.
Compact packing cycles the axes: level mu contributes its base-k digit to
axis (mu-1) mod 3 (x, y, z in turn), at digit position (mu-1) // 3 — the
direct generalisation of the paper's odd/even x/y alternation. The
compact box is k^ceil(r/3) x k^ceil((r-1)/3) x k^floor(r/3) and holds
exactly V = k^r cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

Coord3 = Tuple[int, int, int]
Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class NBBFractal3D:
    name: str
    s: int
    positions: Tuple[Coord3, ...]  # (x, y, z) slots; order = enumeration

    def __post_init__(self):
        seen = set()
        for pos in self.positions:
            assert len(pos) == 3 and all(0 <= c < self.s for c in pos), pos
            assert pos not in seen, pos
            seen.add(pos)

    @property
    def k(self) -> int:
        return len(self.positions)

    def side(self, r: int) -> int:
        return self.s ** r

    def volume(self, r: int) -> int:
        return self.k ** r

    def compact_dims(self, r: int) -> Tuple[int, int, int]:
        """(nx, ny, nz): axis a holds the digits of levels a+1, a+4, ..."""
        return tuple(self.k ** ((r - a + 2) // 3) for a in range(3))

    def mrf(self, r: int) -> float:
        """Memory reduction vs the s^3r bounding volume."""
        return float(self.s ** (3 * r)) / float(self.k ** r)

    @functools.cached_property
    def h_lambda(self) -> np.ndarray:
        return np.asarray(self.positions, dtype=np.int32)  # (k, 3)

    @functools.cached_property
    def h_nu(self) -> np.ndarray:
        """(s, s, s) indexed [z, y, x] -> replica id, -1 for holes."""
        t = np.full((self.s,) * 3, -1, dtype=np.int32)
        for i, (x, y, z) in enumerate(self.positions):
            t[z, y, x] = i
        return t

    def mask(self, r: int) -> np.ndarray:
        """(n, n, n) uint8 occupancy, [z, y, x], by 3D self-similarity."""
        g = (self.h_nu >= 0).astype(np.uint8)
        m = np.ones((1, 1, 1), np.uint8)
        for _ in range(r):
            m = np.kron(g, m)
        return m


# ---------------------------------------------------------------- the maps
def lambda3_map(frac: NBBFractal3D, r: int, cx: Array, cy: Array, cz: Array
                ) -> Tuple[Array, Array, Array]:
    """Compact (cx, cy, cz) -> expanded (ex, ey, ez)."""
    h = jnp.asarray(frac.h_lambda)
    comp = [cx.astype(jnp.int32), cy.astype(jnp.int32),
            cz.astype(jnp.int32)]
    out = [jnp.zeros_like(comp[0]) for _ in range(3)]
    for mu in range(1, r + 1):
        axis = (mu - 1) % 3
        digit = (mu - 1) // 3
        beta = (comp[axis] // (frac.k ** digit)) % frac.k
        tau = h[beta]  # (..., 3)
        scale = frac.s ** (mu - 1)
        for a in range(3):
            out[a] = out[a] + tau[..., a] * scale
    return tuple(out)


def _nu3_codes(frac: NBBFractal3D, r: int, ex: Array, ey: Array, ez: Array
               ) -> Array:
    hn = jnp.asarray(frac.h_nu)
    e = [ex.astype(jnp.int32), ey.astype(jnp.int32), ez.astype(jnp.int32)]
    codes = []
    for mu in range(1, r + 1):
        scale = frac.s ** (mu - 1)
        tx = (e[0] // scale) % frac.s
        ty = (e[1] // scale) % frac.s
        tz = (e[2] // scale) % frac.s
        codes.append(hn[tz, ty, tx])
    return jnp.stack(codes, axis=-1)


def nu3_map(frac: NBBFractal3D, r: int, ex: Array, ey: Array, ez: Array
            ) -> Tuple[Array, Array, Array]:
    """Expanded -> compact (inverse of lambda3 on fractal cells)."""
    codes = jnp.maximum(_nu3_codes(frac, r, ex, ey, ez), 0)
    out = [jnp.zeros(ex.shape, jnp.int32) for _ in range(3)]
    for mu in range(1, r + 1):
        axis = (mu - 1) % 3
        delta = frac.k ** ((mu - 1) // 3)
        out[axis] = out[axis] + codes[..., mu - 1] * delta
    return tuple(out)


def is_fractal3(frac: NBBFractal3D, r: int, ex: Array, ey: Array, ez: Array
                ) -> Array:
    n = frac.side(r)
    inb = ((ex >= 0) & (ex < n) & (ey >= 0) & (ey < n)
           & (ez >= 0) & (ez < n))
    codes = _nu3_codes(frac, r, jnp.clip(ex, 0, n - 1),
                       jnp.clip(ey, 0, n - 1), jnp.clip(ez, 0, n - 1))
    return inb & jnp.all(codes >= 0, axis=-1)


# ---------------------------------------------------------------- registry
def _cube_except(s: int, holes) -> Tuple[Coord3, ...]:
    hs = set(holes)
    return tuple((x, y, z) for z in range(s) for y in range(s)
                 for x in range(s) if (x, y, z) not in hs)


#: Menger sponge F^{20,3}: 3x3x3 minus the 6 face centers and the center.
MENGER = NBBFractal3D(
    "menger", s=3,
    positions=_cube_except(3, [(1, 1, 1), (0, 1, 1), (2, 1, 1),
                               (1, 0, 1), (1, 2, 1), (1, 1, 0), (1, 1, 2)]))

#: Discrete Sierpinski tetrahedron F^{4,2} (cube-corner embedding).
SIERPINSKI3D = NBBFractal3D(
    "sierpinski3d", s=2,
    positions=((0, 0, 0), (1, 0, 0), (0, 1, 0), (0, 0, 1)))

REGISTRY3D: Dict[str, NBBFractal3D] = {f.name: f
                                       for f in (MENGER, SIERPINSKI3D)}
