"""Elastic fault-tolerant distributed execution.

:class:`ElasticDistributedRunner` wraps a
:class:`~repro.core.distributed.DistributedSqueezeEngine` in the
recovery state machine that converts the sharded engine from
demo-shaped to production posture (DESIGN.md Section 9):

    detect -> retry -> restore -> reshard -> degraded-mode

* **detect** — every fused launch (one halo all-gather + k shard-local
  substeps) runs under a wall-clock timeout (the launch-level analogue
  of the serving layer's hang threshold; cold shapes get the compile
  grace), and every launched state passes a post-launch integrity
  check: cells the occupancy mask says are dead — fractal holes,
  padding blocks — must be zero (the mask discipline guarantees it),
  so a corrupted halo band / edge strip surfaces as
  :class:`~repro.runtime.fault.HaloCorruptionError`;
* **retry** — transient failures (a shard's exception, a detected
  corruption) sleep a deterministically-jittered exponential backoff
  (:func:`~repro.runtime.fault.backoff_delays`, the same schedule the
  restart supervisor uses) and re-launch from the newest intact
  checkpoint, up to ``max_retries`` per failure streak;
* **restore** — checkpoints are *sharded* and *mesh-independent*: the
  unpadded dense compact state, split per shard with one crc32 per
  chunk (``CheckpointManager.save_sharded``), crash-atomic, and
  reassembled by ``restore`` under any mesh. A damaged newest step
  falls back to the previous intact one (``restore_with_fallback``);
  with no checkpoint yet, recovery recomputes from the stashed initial
  state — bit-exact either way for CA workloads;
* **reshard** — an unrecoverable shard loss
  (:class:`~repro.runtime.fault.DeviceLostError`) triggers the elastic
  path: drop the lost device, rebuild the engine on a smaller mesh
  (8 -> 4 devices), which re-derives every per-shard static operand
  (``_shard_operands``: halo masks, ghost-remapped offset tables,
  existence rows, the padded block count — all keyed off the new shard
  count), restore the newest intact checkpoint onto the new sharding
  (``from_dense`` re-pads and re-places), and continue;
* **degraded-mode** — the run finishes on the shrunken mesh
  (``stats.degraded``), still bit-exact: padding blocks are
  permanently dead and the compact state is mesh-independent, so the
  trajectory does not depend on the shard count.

A hang (stalled collective / wedged launch) additionally rebuilds the
engine *in place* on the same mesh — dropping its jitted executables,
the launch-level analogue of the serving layer's
``runner.invalidate`` — before restoring.

Telemetry (``repro.obs``): ``dist.failures{kind=...}``,
``dist.retries``, ``dist.reshards`` counters and a
``dist.recovery_seconds`` histogram (failure-to-healthy wall time, the
number the CI chaos-dist gate bounds); the same numbers are always
available on :attr:`ElasticDistributedRunner.stats` regardless of the
``SQUEEZE_TELEMETRY`` toggle. Chaos hooks
(:meth:`~repro.runtime.fault.FaultInjector.in_launch` /
:meth:`~repro.runtime.fault.FaultInjector.corrupt_halo` /
:meth:`~repro.runtime.fault.FaultInjector.on_checkpoint`) fire the
shard-aware fault matrix; ``benchmarks/chaos_dist_bench.py`` (run by
``tests/test_chaos_dist.py`` and the CI chaos-dist gate) proves every
class recovers bit-exact on the 8-device CPU mesh.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointManager)
from repro.core.compact import BlockLayout
from repro.core.distributed import DistributedSqueezeEngine
from repro.runtime.fault import (DeviceLostError, FaultInjector,
                                 HaloCorruptionError, PreemptionHandler,
                                 SimulatedFailure, Watchdog,
                                 backoff_delays)
from repro.workloads.base import StencilWorkload
from repro.workloads.rules import LIFE


class _LaunchHang(RuntimeError):
    """Internal: a fused launch exceeded its wall-clock bound and was
    abandoned (the stalled-collective failure class)."""


@dataclasses.dataclass
class ElasticStats:
    """Always-on recovery accounting of one runner (the telemetry
    registry mirrors it when ``SQUEEZE_TELEMETRY`` is enabled)."""

    steps_done: int = 0
    launches: int = 0          # successful fused launches
    failures: int = 0          # detected faults (any class)
    retries: int = 0           # backoff-and-restore cycles
    hangs: int = 0             # launches abandoned on timeout
    reshards: int = 0          # elastic mesh shrinks
    restores: int = 0          # checkpoint restores
    checkpoints: int = 0       # sharded checkpoints written
    recoveries: int = 0        # failure streaks that healed
    recovery_seconds: List[float] = dataclasses.field(
        default_factory=list)
    degraded: bool = False     # finished on a shrunken mesh
    preempted: bool = False    # stopped early on SIGTERM

    @property
    def max_recovery_s(self) -> float:
        return max(self.recovery_seconds, default=0.0)


class ElasticDistributedRunner:
    """Supervised distributed stepping: fused launches with timeout +
    retry + sharded-checkpoint restore + elastic reshard (module
    docstring has the state machine).

    Parameters mirror ``make_distributed_engine`` plus the recovery
    knobs. ``devices=None`` takes every local device; ``min_devices``
    floors the elastic reshard (a loss that cannot shrink below it
    re-raises). ``ckpt_every`` (simulated steps) of 0 disables
    checkpointing — recovery then recomputes from the initial state.
    ``launch_timeout_s=None`` disables the hang watchdog (faults still
    retry). ``verify_state=False`` skips the post-launch integrity
    check (and with it halo-corruption detection).
    """

    def __init__(self, layout: BlockLayout,
                 devices: Optional[Sequence] = None, axis: str = "data",
                 workload: StencilWorkload = LIFE, compute: str = "jnp",
                 fusion_k: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 exchange: str = "auto",
                 min_devices: int = 1,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 keep: int = 3,
                 launch_timeout_s: Optional[float] = None,
                 compile_grace_s: float = 60.0, max_retries: int = 3,
                 backoff_base_s: float = 0.02,
                 backoff_cap_s: float = 0.5, backoff_seed: int = 0,
                 verify_state: bool = True,
                 injector: Optional[FaultInjector] = None,
                 preemption: Optional[PreemptionHandler] = None):
        self.layout = layout
        self.devices = list(devices if devices is not None
                            else jax.devices())
        if not self.devices:
            raise ValueError("need at least one device")
        if not (1 <= min_devices <= len(self.devices)):
            raise ValueError(
                f"min_devices must be in [1, {len(self.devices)}], "
                f"got {min_devices}")
        self.axis = axis
        self.workload = workload
        self.compute = compute
        self.fusion_k = fusion_k
        self.interpret = interpret
        self.exchange = exchange
        self.min_devices = min_devices
        self.ckpt_every = int(ckpt_every)
        self.mgr = (CheckpointManager(ckpt_dir, keep=keep)
                    if ckpt_dir else None)
        self.launch_timeout_s = launch_timeout_s
        self.compile_grace_s = compile_grace_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.backoff_seed = backoff_seed
        self.verify_state = verify_state
        self.injector = injector
        self.preemption = preemption
        self.watchdog = Watchdog(name="elastic",
                                 hang_threshold_s=launch_timeout_s)
        self.stats = ElasticStats()
        self._executor: Optional[ThreadPoolExecutor] = None
        self._launch_idx = 0        # dispatch attempts (the chaos clock)
        self._base_dense: Optional[np.ndarray] = None
        self.engine: DistributedSqueezeEngine = None  # _build_engine
        self._build_engine()

    # ----------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None

    def __enter__(self) -> "ElasticDistributedRunner":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    @property
    def n_shards(self) -> int:
        return self.engine.n_shards

    def _build_engine(self) -> None:
        """(Re)build the engine on the current device list. A fresh
        frozen instance re-derives every per-shard static operand and
        jitted step for the current mesh — this is both the
        hang-restart path (same mesh, new executables) and the elastic
        reshard path (smaller mesh, new padding/ghost tables)."""
        mesh = Mesh(np.array(self.devices), (self.axis,))
        self.engine = DistributedSqueezeEngine(
            self.layout, mesh, self.axis, self.workload, self.compute,
            self.fusion_k, self.interpret, self.exchange)
        dead = self.engine.dead_mask()
        self._dead = jax.device_put(
            dead, NamedSharding(mesh, P(self.axis, None, None)))

    # ------------------------------------------------------------- helpers
    def _pool(self) -> ThreadPoolExecutor:
        if self._executor is None:
            # + slack: a hang-abandoned thread keeps its slot busy
            # until its sleep/step returns
            self._executor = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="elastic")
        return self._executor

    def _dense_host(self, state) -> np.ndarray:
        return np.asarray(jax.device_get(self.engine.to_dense(state)))

    def _checkpoint(self, state, done: int, force: bool = False) -> None:
        if self.mgr is None:
            return
        if not force and (self.ckpt_every <= 0
                          or done % self.ckpt_every != 0):
            return
        dense = self._dense_host(state)
        path = self.mgr.save_sharded(
            done, {"state": dense}, n_shards=self.engine.n_shards,
            axis=dense.ndim - 3)
        self.stats.checkpoints += 1
        if self.injector is not None:
            self.injector.on_checkpoint("dist", path, self._launch_idx)

    def _to_boundary(self, done: int) -> int:
        if self.mgr is None or self.ckpt_every <= 0:
            return 1 << 30
        return self.ckpt_every - done % self.ckpt_every

    def _recover(self, count_retry: bool = True):
        """(state, done): the newest intact checkpoint restored onto
        the CURRENT engine/mesh, else the stashed initial state."""
        eng = self.engine
        if count_retry:
            self.stats.retries += 1
            obs.inc("dist.retries")
        if self.mgr is not None and self.mgr.all_steps():
            like = {"state": np.zeros(self._base_dense.shape,
                                      self._base_dense.dtype)}
            try:
                step, tree = self.mgr.restore_with_fallback(like)
                self.stats.restores += 1
                return eng.from_dense(tree["state"]), int(step)
            except (CheckpointCorruptError, FileNotFoundError,
                    KeyError, ValueError):
                pass  # unusable checkpoint family: recompute from t=0
        return eng.from_dense(self._base_dense), 0

    def _reshard(self, err: DeviceLostError) -> bool:
        """Shrink the mesh after an unrecoverable shard loss: drop the
        lost device, halve the device count (floored at
        ``min_devices``), rebuild the engine. False when already at the
        floor (the loss is terminal)."""
        n = len(self.devices)
        n_new = max(self.min_devices, n // 2)
        if n_new >= n:
            return False
        lost = getattr(err, "shard", 0) % n
        survivors = [d for i, d in enumerate(self.devices) if i != lost]
        self.devices = survivors[:n_new]
        self._build_engine()
        self.stats.reshards += 1
        self.stats.degraded = True
        obs.inc("dist.reshards")
        return True

    def _timed_launch(self, state, seg: int, warm: set):
        """One fused launch under the wall-clock bound. The chaos
        ``in_launch`` hook runs inside the timed region (a stalled
        launch really blocks it); on timeout the thread is abandoned
        and ``_LaunchHang`` raised."""
        launch = self._launch_idx
        self._launch_idx += 1

        def work():
            if self.injector is not None:
                self.injector.in_launch(launch)
            out = self.engine.step_k(state, seg)
            return jax.block_until_ready(out)

        if self.launch_timeout_s is None:
            out = work()
        else:
            key = (seg, self.engine.n_shards,
                   tuple(np.shape(state)))
            timeout = (self.launch_timeout_s if key in warm
                       else max(self.launch_timeout_s,
                                self.compile_grace_s))
            self.watchdog.start_step()
            fut = self._pool().submit(work)
            try:
                out = fut.result(timeout=timeout)
            except _FuturesTimeout:
                raise _LaunchHang(
                    f"launch {launch} exceeded {timeout:.3f}s") from None
            self.watchdog.end_step()
            warm.add(key)

        # post-launch chaos (halo/strip corruption) + integrity check
        if self.injector is not None:
            out, poisoned = self.injector.corrupt_halo(
                launch, out, self.engine.nb_local)
            if poisoned:
                out = jax.device_put(
                    out, self.engine.sharding(np.ndim(out)))
        if self.verify_state and bool(
                jnp.any((out * self._dead) != 0)):
            raise HaloCorruptionError(
                f"launch {launch}: dead cells came back nonzero "
                "(corrupted halo band / edge strip)")
        return out

    def _note_failure(self, kind: str) -> None:
        self.stats.failures += 1
        obs.inc("dist.failures", kind=kind)

    # ------------------------------------------------------------- public
    def run(self, steps: int, state=None, seed: int = 0):
        """Advance ``steps`` simulated steps with full recovery,
        returning the final engine-native (sharded) state. ``state``
        may be any rank the engine accepts (single or batched); omitted
        it is seeded via ``init_random(seed)``. If the checkpoint
        directory already holds steps (a preempted run), execution
        RESUMES from the newest intact one."""
        steps = int(steps)
        if state is None:
            state = self.engine.init_random(int(seed))
        self._base_dense = self._dense_host(state)
        done = 0
        attempt = 0            # failures since last success
        delays = None          # backoff schedule of this streak
        t_fail: Optional[float] = None
        warm: set = set()
        if self.mgr is not None and self.mgr.all_steps():
            # resume a preempted/restarted run (not a failure retry)
            state, done = self._recover(count_retry=False)
        with obs.span("elastic.run", compute=self.compute, steps=steps,
                      shards=self.engine.n_shards):
            while done < steps:
                if (self.preemption is not None
                        and self.preemption.requested):
                    self._checkpoint(state, done, force=True)
                    self.stats.preempted = True
                    break
                k = self.engine.effective_fusion_k
                seg = min(k, steps - done, self._to_boundary(done))
                try:
                    out = self._timed_launch(state, seg, warm)
                except DeviceLostError as e:
                    self._note_failure("device_loss")
                    t_fail = t_fail or time.monotonic()
                    if not self._reshard(e):
                        raise  # already at min_devices: terminal
                    warm.clear()
                    state, done = self._recover()
                    continue
                except _LaunchHang:
                    self.watchdog.flag_hang()
                    self.stats.hangs += 1
                    self._note_failure("hang")
                    t_fail = t_fail or time.monotonic()
                    attempt += 1
                    if attempt > self.max_retries:
                        raise
                    # kill + restart: a fresh engine drops the wedged
                    # executables (same mesh), then restore
                    self._build_engine()
                    warm.clear()
                    delays = delays or backoff_delays(
                        self.backoff_base_s, self.backoff_cap_s,
                        seed=self.backoff_seed)
                    time.sleep(next(delays))
                    state, done = self._recover()
                    continue
                except SimulatedFailure as e:
                    kind = ("halo_corrupt"
                            if isinstance(e, HaloCorruptionError)
                            else "exception")
                    self._note_failure(kind)
                    t_fail = t_fail or time.monotonic()
                    attempt += 1
                    if attempt > self.max_retries:
                        raise
                    delays = delays or backoff_delays(
                        self.backoff_base_s, self.backoff_cap_s,
                        seed=self.backoff_seed)
                    time.sleep(next(delays))
                    state, done = self._recover()
                    continue
                # -------------------------------------------- success
                state = out
                done += seg
                self.stats.launches += 1
                self.stats.steps_done = done
                if t_fail is not None:
                    dt = time.monotonic() - t_fail
                    self.stats.recoveries += 1
                    self.stats.recovery_seconds.append(dt)
                    obs.observe("dist.recovery_seconds", dt)
                    t_fail = None
                attempt, delays = 0, None
                self._checkpoint(state, done)
        self.stats.steps_done = done
        return state
