"""NBB (Non-overlapping Bounding-Box) fractal definitions.

A member of the NBB class ``F_n^{k,s}`` is fully described by:
  * ``s``  — linear scaling factor per level (the transition function embeds
             the current fractal in an ``s x s`` grid of slots),
  * ``positions`` — the ``k`` occupied slots, as (x, y) pairs with
             ``0 <= x, y < s``; origin at the upper-left, y growing downward
             (paper Section 3.4 convention).

The order of ``positions`` *is* the replica enumeration: ``H_lambda[i]``
returns the slot of replica ``i`` and ``H_nu[slot]`` returns ``i``.

Level ``r`` facts (paper Eq. 1 and Section 3.1):
  * expanded side        n      = s**r
  * volume (cell count)  V      = k**r
  * compact domain       rows x cols = k**floor(r/2) x k**ceil(r/2)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import numpy as np

Coord = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class NBBFractal:
    """An NBB fractal family, independent of scale level."""

    name: str
    s: int
    positions: Tuple[Coord, ...]  # (x, y) slots, order = replica enumeration

    def __post_init__(self):
        if self.s < 2:
            raise ValueError(f"scaling factor s must be >= 2, got {self.s}")
        seen = set()
        for (x, y) in self.positions:
            if not (0 <= x < self.s and 0 <= y < self.s):
                raise ValueError(
                    f"{self.name}: slot ({x},{y}) outside [0,{self.s})^2")
            if (x, y) in seen:
                raise ValueError(f"{self.name}: duplicate slot ({x},{y})")
            seen.add((x, y))
        if not (1 <= self.k <= self.s * self.s):
            raise ValueError(f"{self.name}: invalid replica count k={self.k}")

    # ------------------------------------------------------------------ basic
    @property
    def k(self) -> int:
        return len(self.positions)

    def side(self, r: int) -> int:
        """Expanded embedding side n = s**r."""
        return self.s ** r

    def volume(self, r: int) -> int:
        """Number of fractal cells V = k**r (paper Eq. 1)."""
        return self.k ** r

    def level_of_side(self, n: int) -> int:
        """r = log_s(n); n must be an exact power of s."""
        r = int(round(np.log(n) / np.log(self.s)))
        if self.s ** r != n:
            raise ValueError(
                f"{self.name}: n={n} is not a power of s={self.s}")
        return r

    def compact_dims(self, r: int) -> Tuple[int, int]:
        """(rows, cols) of the compact rectangle = k^floor(r/2) x k^ceil(r/2).

        Odd levels pack into x (cols), even levels into y (rows) — matching
        lambda's beta_mu digit convention (paper Eq. 5 / Section 3.1).
        """
        return self.k ** (r // 2), self.k ** ((r + 1) // 2)

    def mrf(self, r: int) -> float:
        """Theoretical memory-reduction-factor vs bounding box (paper 3.7)."""
        return float(self.s ** (2 * r)) / float(self.k ** r)

    # ------------------------------------------------------------ replica LUTs
    @functools.cached_property
    def h_lambda(self) -> np.ndarray:
        """(k, 2) int32: replica index -> (tau_x, tau_y) slot (paper Eq. 4)."""
        return np.asarray(self.positions, dtype=np.int32)

    @functools.cached_property
    def h_nu(self) -> np.ndarray:
        """(s, s) int32 indexed [y, x]: slot -> replica index, -1 for holes
        (paper Section 3.4's H_nu lookup)."""
        table = np.full((self.s, self.s), -1, dtype=np.int32)
        for i, (x, y) in enumerate(self.positions):
            table[y, x] = i
        return table

    @functools.cached_property
    def replica_grid(self) -> np.ndarray:
        """(s, s) uint8 occupancy indexed [y, x]."""
        return (self.h_nu >= 0).astype(np.uint8)

    # ------------------------------------------------------------------- masks
    def mask(self, r: int) -> np.ndarray:
        """(n, n) uint8 occupancy of the expanded embedding at level r.

        Built by self-similarity: mask_r = kron(replica_grid, mask_{r-1}).
        """
        m = np.ones((1, 1), dtype=np.uint8)
        for _ in range(r):
            m = np.kron(self.replica_grid, m)
        return m


# -------------------------------------------------------------- registry
def _rowmajor_except(s: int, holes: Tuple[Coord, ...]) -> Tuple[Coord, ...]:
    hole_set = set(holes)
    return tuple((x, y) for y in range(s) for x in range(s)
                 if (x, y) not in hole_set)


#: The paper's Sierpinski triangle F^{3,2}: replicas top (0,0), middle (0,1),
#: right (1,1) — enumeration chosen so H_nu[(x,y)] == x + y (paper Eq. 22).
SIERPINSKI = NBBFractal("sierpinski", s=2, positions=((0, 0), (0, 1), (1, 1)))

#: Sierpinski carpet F^{8,3} (paper Fig. 1): 3x3 minus the center.
CARPET = NBBFractal("carpet", s=3, positions=_rowmajor_except(3, ((1, 1),)))

#: Vicsek F^{5,3} (paper Fig. 5): plus-shape.
VICSEK = NBBFractal(
    "vicsek", s=3, positions=((1, 0), (0, 1), (1, 1), (2, 1), (1, 2)))

#: "Empty bottles" F^{7,3} (paper Fig. 2). The exact slot layout is not given
#: in the text; any 7-of-9 layout is a valid member of the class — we pick a
#: bottle-ish one (3x3 minus the two upper corners).
EMPTY_BOTTLES = NBBFractal(
    "empty_bottles", s=3, positions=_rowmajor_except(3, ((0, 0), (2, 0))))

#: "Chandelier" (paper Fig. 11); layout not specified — 3x3 minus center
#: column's top+middle, hanging-lamp shape.
CHANDELIER = NBBFractal(
    "chandelier", s=3, positions=_rowmajor_except(3, ((1, 0), (0, 1))))

REGISTRY: Dict[str, NBBFractal] = {
    f.name: f for f in (SIERPINSKI, CARPET, VICSEK, EMPTY_BOTTLES, CHANDELIER)
}


def get_fractal(name: str) -> NBBFractal:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown fractal {name!r}; known: {sorted(REGISTRY)}") from None
