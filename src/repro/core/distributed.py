"""Multi-device compact fractal stencil: shard_map + k-fused strip halo
exchange + shard-local fused kernels.

The compact block domain (the *only* thing in memory — the paper's P2 win)
is sharded along its leading block axis over a mesh axis (default "data").
One fused depth-``k`` launch advances ``k`` exact steps with ONE
collective:

  1. each shard packs its local blocks' depth-``k`` edge bands (top/bottom
     ``k`` rows, west/east ``k`` columns — ``BlockLayout.pack_edge_strips``)
     into a (L, nb_local, 4, k, rho) strip array, ~4k/rho of the state;
  2. ONE ``all_gather`` replicates the strips over the mesh axis (the halo
     exchange — strips only, never the state). Per simulated step this is
     1/k collectives and ~4*rho*nb bytes (the per-step scheme re-ships the
     duplicated corners every step);
  3. each shard assembles its local blocks' depth-``k`` halos from the
     replicated strips via the static ``offset_table(k)`` (the paper's
     lambda/nu maps hoisted to block granularity — radius-1 for k <= rho,
     ghosts exact past holes) and runs ``k`` fused substeps locally:
     the v5 MXU macro-tile kernel (``compute='mxu'``), the v4 fused-depth
     kernel (``compute='fused'``), or the XLA window path
     (``compute='jnp'``), all parameterized by the ``StencilWorkload`` and
     all reusing the single-device substep mask discipline (periodic
     window mask gated by per-block neighbor existence).

Because the neighbor table is arbitrary (fractal adjacency is non-local in
compact space), a nearest-neighbor ``ppermute`` ring is insufficient in
general; an all-gather of *strips only* keeps the exchanged volume at
O(nb * k * rho) per k steps versus the O(nb * rho^2) state. For 1000+
nodes the same scheme shards over ("pod", "data") jointly — the gather is
hierarchical (ICI within a pod, DCI across pods) and XLA schedules it that
way from the single logical all_gather.

``run(state, steps)`` tiles steps into floor(steps/k) fused launches plus
ONE remainder launch of depth steps % k, so a run performs exactly
ceil(steps/k) halo all-gathers — asserted by ``exchange_stats()`` in the
tests. ``run(..., donate=True)`` donates the state buffer to XLA
(zero-copy steady-state stepping, as the single-device engines).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.compact import BlockLayout
from repro.workloads.base import StencilWorkload, check_workload_ndim
from repro.workloads.rules import LIFE

Array = jnp.ndarray

#: shard-local compute backends: XLA window path, v4 fused-depth kernel,
#: v5 MXU macro-tile kernel
COMPUTES = ("jnp", "fused", "mxu")


def _pad_blocks(layout: BlockLayout, n_shards: int) -> int:
    """Blocks padded so the leading axis divides the mesh axis size."""
    nb = layout.n_blocks
    return ((nb + n_shards - 1) // n_shards) * n_shards


@dataclasses.dataclass
class ExchangeStats:
    """Halo-exchange accounting of one engine: every fused launch issues
    exactly one strip ``all_gather`` (verified structurally by the tests,
    which count all-gathers in the lowered step HLO)."""

    steps: int = 0            # simulated steps advanced
    collectives: int = 0      # strip all-gathers issued
    bytes_gathered: int = 0   # replicated strip-buffer bytes produced

    @property
    def collectives_per_step(self) -> float:
        return self.collectives / max(self.steps, 1)

    @property
    def bytes_per_step(self) -> float:
        return self.bytes_gathered / max(self.steps, 1)


@dataclasses.dataclass(frozen=True)
class DistributedSqueezeEngine:
    """Block-level Squeeze sharded over one mesh axis, workload-generic
    and fusion-aware.

    State layout: (C?, nb_padded, rho, rho) — or (B, C?, nb_padded, rho,
    rho) batched — sharded over the block axis; padding blocks (ids >=
    layout.n_blocks) are permanently dead: the neighbor table never points
    at them and every compute path gates them out of the occupancy mask.

    ``compute`` picks the shard-local backend ('jnp' | 'fused' | 'mxu');
    ``fusion_k`` the exchange/fusion depth used by ``run`` (None = the
    single-device ``default_fusion_k`` heuristic, always <= rho).
    """

    layout: BlockLayout
    mesh: Mesh
    axis: str = "data"
    workload: StencilWorkload = LIFE
    compute: str = "jnp"
    fusion_k: Optional[int] = None
    interpret: Optional[bool] = None  # kernel computes; None = auto-detect

    def __post_init__(self):
        if self.compute not in COMPUTES:
            raise ValueError(
                f"unknown compute {self.compute!r}; have {COMPUTES}")
        check_workload_ndim(self.workload, 2)
        if self.fusion_k is not None and not (
                1 <= self.fusion_k <= self.layout.rho):
            raise ValueError(
                f"distributed fusion_k must be in [1, rho="
                f"{self.layout.rho}], got {self.fusion_k} (the strip "
                "exchange covers one block ring)")
        self.layout.materialize()
        object.__setattr__(self, "_stats", ExchangeStats())

    # ------------------------------------------------------------ geometry
    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def nb_padded(self) -> int:
        return _pad_blocks(self.layout, self.n_shards)

    @property
    def nb_local(self) -> int:
        return self.nb_padded // self.n_shards

    @property
    def effective_fusion_k(self) -> int:
        if self.fusion_k is not None:
            return self.fusion_k
        from repro.core.stencil import default_fusion_k
        return default_fusion_k(self.layout.rho)

    def state_spec(self, ndim: int) -> P:
        """PartitionSpec sharding the block axis (position ndim-3)."""
        spec = [None] * ndim
        spec[ndim - 3] = self.axis
        return P(*spec)

    def sharding(self, ndim: Optional[int] = None) -> NamedSharding:
        if ndim is None:
            ndim = 3 + (1 if self.workload.n_channels > 1 else 0)
        return NamedSharding(self.mesh, self.state_spec(ndim))

    # ----------------------------------------------------------- accounting
    def strip_bytes(self, k: int, batch: int = 1) -> int:
        """Bytes of the replicated strip buffer produced by one depth-``k``
        halo all-gather (the collective's payload)."""
        itemsize = jnp.dtype(self.workload.dtype).itemsize
        return (batch * self.workload.n_channels * self.nb_padded
                * 4 * k * self.layout.rho * itemsize)

    def exchange_stats(self) -> ExchangeStats:
        """Snapshot of the halo-exchange counters (collectives issued,
        simulated steps advanced, strip bytes gathered)."""
        return dataclasses.replace(self._stats)

    def reset_exchange_stats(self) -> None:
        st = self._stats
        st.steps = st.collectives = st.bytes_gathered = 0

    def _account(self, k: int, launches: int, batch: int) -> None:
        st = self._stats
        strip_bytes = launches * self.strip_bytes(k, batch)
        st.steps += launches * k
        st.collectives += launches
        st.bytes_gathered += strip_bytes
        if obs.enabled():
            # the same accounting, unified onto the telemetry registry
            # (labeled by compute backend) so one obs.report() answers
            # "how many collectives and bytes did this run ship"
            obs.inc("dist.steps", launches * k, compute=self.compute)
            obs.inc("dist.collectives", launches, compute=self.compute)
            obs.inc("dist.bytes_gathered", strip_bytes,
                    compute=self.compute)
            obs.inc("engine.fused_launches", launches,
                    engine=type(self).__name__, variant=self.compute)

    def memory_bytes(self, dtype_size: Optional[int] = None) -> int:
        """Total (all-shard) Squeeze state bytes, padding blocks included
        (the per-shard footprint is this / n_shards)."""
        if dtype_size is None:
            dtype_size = jnp.dtype(self.workload.dtype).itemsize
        return (self.workload.n_channels * self.nb_padded
                * self.layout.rho ** 2 * dtype_size)

    # ------------------------------------------------------------ state I/O
    def _pad_state(self, dense: Array) -> Array:
        pad = self.nb_padded - self.layout.n_blocks
        if pad:
            shape = dense.shape[:-3] + (pad,) + dense.shape[-2:]
            dense = jnp.concatenate(
                [dense, jnp.zeros(shape, dense.dtype)], axis=-3)
        return dense

    def init_random(self, seed: int) -> Array:
        from repro.core.stencil import SqueezeBlockEngine
        dense = SqueezeBlockEngine(self.layout,
                                   self.workload).init_random(seed)
        dense = self._pad_state(dense)
        return jax.device_put(dense, self.sharding(dense.ndim))

    def init_batch(self, seeds) -> Array:
        """Stack independent initial states: (B, C?, nb_padded, rho, rho),
        sharded over the block axis."""
        from repro.core.stencil import SqueezeBlockEngine
        eng = SqueezeBlockEngine(self.layout, self.workload)
        dense = jnp.stack([eng.init_random(int(s)) for s in seeds])
        dense = self._pad_state(dense)
        return jax.device_put(dense, self.sharding(dense.ndim))

    def to_dense(self, state: Array) -> Array:
        """Strip padding blocks (for comparison against single-device)."""
        return state[..., : self.layout.n_blocks, :, :]

    def from_dense(self, dense: Array) -> Array:
        """(B?, C?, n_blocks, rho, rho) unpadded compact state ->
        engine-native padded + sharded state (the inverse of
        :meth:`to_dense`). This is the elastic-restore ingest path: a
        checkpoint saved under ANY mesh stores the mesh-independent
        dense state, and re-enters here padded for THIS mesh's shard
        count and device_put with this engine's sharding."""
        dense = jnp.asarray(dense, jnp.dtype(self.workload.dtype))
        padded = self._pad_state(dense)
        return jax.device_put(padded, self.sharding(padded.ndim))

    def dead_mask(self) -> np.ndarray:
        """(nb_padded, rho, rho) uint8, 1 where a cell must be zero in
        every valid state: fractal holes inside real blocks (the mask
        discipline re-kills them each substep) and every cell of a
        padding block. A nonzero cell under this mask is the signature
        of halo/strip corruption — the elastic runner's post-launch
        integrity check multiplies by it."""
        layout = self.layout
        hole = (1 - layout.micro_mask).astype(np.uint8)
        dead = np.broadcast_to(
            hole, (layout.n_blocks,) + hole.shape)
        pad = self.nb_padded - layout.n_blocks
        if pad:
            dead = np.concatenate(
                [dead, np.ones((pad,) + hole.shape, np.uint8)], axis=0)
        return np.ascontiguousarray(dead)

    def to_expanded(self, state: Array) -> Array:
        """(B?, C?, nb_padded, rho, rho) -> (B?, C?, n, n) expanded."""
        return self.layout.to_expanded(self.to_dense(state))

    # --------------------------------------------------- canonical 5D states
    def _canon(self, state: Array) -> Tuple[Array, bool]:
        """Any public state rank -> ((B, C, nb_padded, rho, rho), batched)."""
        chan = self.workload.n_channels > 1
        base = 4 if chan else 3
        if state.ndim == base:
            return (state[None] if chan else state[None, None]), False
        if state.ndim == base + 1:
            return (state if chan else state[:, None]), True
        raise ValueError(
            f"bad state rank {state.ndim} for workload "
            f"{self.workload.name!r} (expected {base} or {base + 1})")

    def _uncanon(self, s5: Array, batched: bool) -> Array:
        chan = self.workload.n_channels > 1
        if batched:
            return s5 if chan else s5[:, 0]
        return s5[0] if chan else s5[0, 0]

    # ------------------------------------------------------- compiled steps
    @functools.cached_property
    def _cache(self) -> dict:
        """Per-instance memo of device tables and jitted step/run fns."""
        return {}

    def _memo(self, key, build):
        cache = self._cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def _shard_operands(self, k: int) -> Tuple[Array, Array, Array]:
        """Per-shard static operands of a depth-``k`` launch, built ONCE
        and device_put sharded over the block axis (a traced step would
        otherwise re-derive them per launch — ~15 ops of pure overhead on
        the per-step critical path):

          * halo mask (nb_padded, w, w): ``layout.halo_mask(k)`` (periodic
            window occupancy, ghost regions zeroed) with all-zero rows for
            padding blocks — so the substep mask discipline AND the
            padding-stays-dead guarantee are a single multiply;
          * neighbor table (nb_padded, 8): ``offset_table(k)`` (radius-1 ==
            the exact-past-holes Moore table), ghosts pre-remapped to the
            appended zero-strip row, all-ghost rows for padding;
          * existence (nb_padded, 8) int32: scalar-prefetch operand of the
            shard-local kernels' in-kernel mask reconstruction.
        """
        def build():
            layout = self.layout
            pad = self.nb_padded - layout.n_blocks
            w = layout.rho + 2 * k
            mask = np.concatenate(
                [layout.halo_mask(k),
                 np.zeros((pad, w, w), np.uint8)], axis=0)
            table = np.concatenate(
                [layout.offset_table(k),
                 np.full((pad, 8), layout.ghost, np.int32)], axis=0)
            table = np.where(table == layout.ghost,
                             np.int32(self.nb_padded), table)
            existence = np.concatenate(
                [layout.existence_table,
                 np.zeros((pad, 8), np.int32)], axis=0)
            row = NamedSharding(self.mesh, P(self.axis, None))
            cube = NamedSharding(self.mesh, P(self.axis, None, None))
            return (jax.device_put(mask, cube),
                    jax.device_put(table, row),
                    jax.device_put(existence, row))
        return self._memo(("operands", k), build)

    def _materialize(self, k: int) -> None:
        """Build every static host/device table a depth-``k`` traced step
        reads — outside any trace."""
        layout = self.layout
        layout.materialize()
        _ = self._shard_operands(k)
        if self.compute != "jnp":
            _ = layout.dev_window_mask(k)
        if self.compute == "mxu":
            from repro.kernels.squeeze_stencil import _mxu_operators
            p_local = layout.macro_tiles_for(self.nb_local, k)[0]
            _mxu_operators(self.workload, layout.rho + 2 * k, p_local)

    def _local_step_k(self, state_local: Array, mask: Array, table: Array,
                      existence: Array, k: int) -> Array:
        """One fused depth-``k`` launch on this shard: pack strips, ONE
        all_gather, assemble halos, run k substeps locally.

        state_local (B, C, nb_local, rho, rho) -> same, k steps later;
        ``mask``/``table``/``existence`` are this shard's rows of the
        ``_shard_operands`` arrays.
        """
        layout, axis = self.layout, self.axis
        rho, nbl = layout.rho, self.nb_local
        b, nc = state_local.shape[0], state_local.shape[1]

        # 1. pack my edge bands ((B, C) folded: strip plumbing is linear
        # per leading axis)
        flat = state_local.reshape(b * nc, nbl, rho, rho)
        strips_local = layout.pack_edge_strips(flat, k)
        # 2. halo exchange: ONE all_gather of strips only
        strips = jax.lax.all_gather(strips_local, axis, axis=1, tiled=True)
        strips = jnp.concatenate(
            [strips,
             jnp.zeros((strips.shape[0], 1) + strips.shape[2:],
                       strips.dtype)], axis=1)  # ghost zero entry (row nbp)
        # 3. assemble my blocks' depth-k halos + shard-local fused compute
        halo = tuple(
            h.reshape((b, nc) + h.shape[1:])
            for h in layout.halo_from_strips_k(strips, table, k))

        if self.compute == "mxu":
            from repro.kernels.squeeze_stencil import stencil_step_mxu_k_local
            out = stencil_step_mxu_k_local(
                layout, state_local, halo, existence, self.workload, k=k,
                interpret=self.interpret)
        elif self.compute == "fused":
            from repro.kernels.squeeze_stencil import (
                stencil_step_fused_k_local)

            def one(s, top, bot, west, east):
                return stencil_step_fused_k_local(
                    layout, s, (top, bot, west, east), existence,
                    self.workload, k=k, interpret=self.interpret)

            out = jax.vmap(one)(state_local, *halo)
        else:
            return self._jnp_step_k(state_local, halo, mask, k)
        # the kernels gate halo regions in-kernel but keep the periodic
        # center mask — one multiply by the mask's center re-kills padding
        # blocks (their mask rows are all zero)
        center = mask[:, k:k + rho, k:k + rho]
        return out * center.astype(out.dtype)

    def _jnp_step_k(self, states: Array, halo, mask: Array,
                    k: int) -> Array:
        """XLA window path: assemble (B, C, nbl, rho+2k, rho+2k) tiles and
        run the workload's k fused substeps under the precomputed sharded
        halo mask (the same per-block occupancy the single-device XLA
        ``step_k`` reads; padding-block rows are all zero, so the k-substep
        mask discipline and the padding gate are one multiply)."""
        layout, wl = self.layout, self.workload
        rho = layout.rho
        w = rho + 2 * k
        top, bot, west, east = halo
        b, nc, nbl = states.shape[:3]
        padded = jnp.zeros((b, nc, nbl, w, w), states.dtype)
        padded = padded.at[..., k:k + rho, k:k + rho].set(states)
        padded = padded.at[..., :k, :].set(top)
        padded = padded.at[..., w - k:, :].set(bot)
        padded = padded.at[..., k:k + rho, :k].set(west)
        padded = padded.at[..., k:k + rho, w - k:].set(east)

        def one(p):  # (C, nbl, w, w) -> (C, nbl, rho, rho)
            if wl.n_channels > 1:
                return wl.tile_rule_k(p, mask, k)
            return wl.tile_rule_k(p[0], mask, k)[None]

        return jax.vmap(one)(padded).astype(states.dtype)

    def _step5_fn(self, k: int, donate: bool = False):
        """Jitted shard_map'd fused step over canonical 5D states plus the
        sharded static operands (mask, table, existence)."""
        def build():
            self._materialize(k)
            from repro.utils.jax_compat import shard_map
            spec = self.state_spec(5)
            # pallas_call has no shard_map replication rule: the kernel
            # computes must disable the (conservative) rep check
            step = shard_map(
                functools.partial(self._local_step_k, k=k), mesh=self.mesh,
                in_specs=(spec, P(self.axis, None, None),
                          P(self.axis, None), P(self.axis, None)),
                out_specs=spec,
                check_rep=self.compute == "jnp")
            return jax.jit(step, donate_argnums=0) if donate \
                else jax.jit(step)
        return self._memo(("step5", k, donate), build)

    def _call_step(self, k: int, s5: Array, donate: bool = False) -> Array:
        return self._step5_fn(k, donate)(s5, *self._shard_operands(k))

    def _loop_fn(self, k: int, donate: bool):
        """Jitted fori_loop of fused launches; the launch count is a
        *traced* scalar, so changing ``steps`` does not retrace."""
        def build():
            step = self._step5_fn(k)

            def body(s5, n, mask, table, existence):
                return jax.lax.fori_loop(
                    0, n, lambda _, s: step(s, mask, table, existence), s5)

            return jax.jit(body, donate_argnums=0) if donate \
                else jax.jit(body)
        return self._memo(("loop", k, donate), build)

    # ------------------------------------------------------------ public API
    def step(self, state: Array) -> Array:
        """One step (one halo all-gather)."""
        return self.step_k(state, 1)

    def step_k(self, state: Array, k: int) -> Array:
        """``k`` exact steps in one fused launch: ONE halo all-gather of
        depth-``k`` strips, then k shard-local substeps (1 <= k <= rho)."""
        if not (1 <= k <= self.layout.rho):
            raise ValueError(
                f"need 1 <= k <= rho={self.layout.rho}, got k={k}")
        s5, batched = self._canon(state)
        out = self._call_step(k, s5)
        self._account(k, 1, s5.shape[0])
        return self._uncanon(out, batched)

    def step_batched(self, states: Array) -> Array:
        return self.step_k(states, 1)

    def step_k_batched(self, states: Array, k: int) -> Array:
        return self.step_k(states, k)

    @property
    def supports_native_batch(self) -> bool:
        """B simulations advance through one shard_map step whose strip
        exchange is a single batched all-gather (every compute backend;
        'mxu' additionally runs one (B, n_macro_local) kernel grid)."""
        return True

    def run(self, state: Array, steps: int, donate: bool = False) -> Array:
        """``steps`` steps tiled into floor(steps/k) fused depth-k launches
        plus ONE remainder launch of depth steps % k — exactly
        ceil(steps/k) halo all-gathers total. ``donate=True`` donates the
        state buffer to XLA (zero-copy stepping; the caller must not reuse
        ``state`` afterwards)."""
        steps = int(steps)
        if steps < 0:
            raise ValueError(f"steps must be >= 0, got {steps}")
        s5, batched = self._canon(state)
        b = s5.shape[0]
        k = self.effective_fusion_k
        n_fused, rem = divmod(steps, k)
        with obs.span("dist.run", compute=self.compute, steps=steps,
                      k=k, batch=b):
            if n_fused:
                s5 = self._loop_fn(k, donate)(
                    s5, jnp.asarray(n_fused, jnp.int32),
                    *self._shard_operands(k))
                self._account(k, n_fused, b)
            if rem:
                s5 = self._call_step(rem, s5, donate)
                self._account(rem, 1, b)
        if donate:
            obs.inc("engine.donated_runs",
                    engine=type(self).__name__, variant=self.compute)
        return self._uncanon(s5, batched)

    def lowered_step_text(self, state: Array, k: int) -> str:
        """Lowered StableHLO of one fused depth-``k`` launch — the tests
        count its collectives (exactly one all_gather per launch)."""
        s5, _ = self._canon(state)
        return self._step5_fn(k).lower(
            s5, *self._shard_operands(k)).as_text()


def make_distributed_engine(layout: BlockLayout, mesh: Optional[Mesh] = None,
                            axis: str = "data",
                            workload: StencilWorkload = LIFE,
                            compute: str = "jnp",
                            fusion_k: Optional[int] = None,
                            interpret: Optional[bool] = None
                            ) -> DistributedSqueezeEngine:
    """Engine over ``mesh`` (default: all devices on one "data" axis)."""
    if mesh is None:
        mesh = Mesh(jax.devices(), ("data",))
        axis = "data"
    return DistributedSqueezeEngine(layout, mesh, axis, workload, compute,
                                    fusion_k, interpret)
