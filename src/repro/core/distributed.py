"""Multi-device compact fractal stencil: shard_map + k-fused strip halo
exchange + shard-local fused kernels.

The compact block domain (the *only* thing in memory — the paper's P2 win)
is sharded along its leading block axis over a mesh axis (default "data").
One fused depth-``k`` launch advances ``k`` exact steps with ONE halo
exchange of edge *strips* (top/bottom ``k`` rows, west/east ``k`` columns
per block — ``BlockLayout.pack_edge_strips``, ~4k/rho of the state; the
state itself is never exchanged).

Two exchange modes (``exchange=``):

``'p2p'`` (the default resolution of ``'auto'``) — neighbor-only
``jax.lax.ppermute`` overlapped with interior compute. Fractal adjacency
is non-local in *compact* (digit-interleaved) id order, but the lambda/nu
maps give a static block<->space correspondence, and
``BlockLayout.strip_decomposition`` uses it to assign each shard a
contiguous strip of expanded-space block rows (holes handled exactly —
only occupied rows exist). Rows are never split, so every cross-shard
Moore neighbor lives on shard +-1 and the whole exchange is two
``ppermute`` shifts of exactly the strips each neighbor needs
(``send_prev_idx`` / ``send_next_idx`` routing tables). Each launch
splits its local blocks into *interior* (depth-k halo fully shard-local;
computed while the permutes are in flight) and *boundary* (needs a
neighbor strip; computed after) — XLA schedules the interior kernels
against the collective from the data dependence alone. Per-device
exchanged bytes are independent of the shard count (each shard talks to
at most two neighbors regardless of mesh size) — the flat scaling curve
gated by ``benchmarks/distributed_bench.py --scaling``.

``'gather'`` — the fallback: ONE ``all_gather`` replicates every shard's
strips over the mesh axis, then each shard assembles halos from the
replicated buffer. Exchanged bytes grow ~linearly with device count, but
the scheme needs no decomposition, so it covers degenerate meshes where
the strip decomposition is invalid (fewer occupied expanded block rows
than shards). ``exchange='auto'`` resolves to p2p whenever the
decomposition is valid and falls back to gather otherwise;
``exchange='p2p'`` raises on a degenerate mesh.

Both modes assemble halos via the static ``offset_table(k)`` machinery
(radius-1 == the exact-past-holes Moore table for k <= rho) and run the
same shard-local fused substeps: the v5 MXU macro-tile kernel
(``compute='mxu'``), the v4 fused-depth kernel (``compute='fused'``), or
the XLA window path (``compute='jnp'``), all parameterized by the
``StencilWorkload`` and all reusing the single-device substep mask
discipline (periodic window mask gated by per-block neighbor existence).

``run(state, steps)`` tiles steps into floor(steps/k) fused launches plus
ONE remainder launch of depth steps % k, so a run performs exactly
ceil(steps/k) halo exchanges — asserted by ``exchange_stats()`` in the
tests (``bytes_permuted``/``neighbor_sends`` on the p2p path,
``bytes_gathered`` on the gather path). ``run(..., donate=True)``
donates the state buffer to XLA (zero-copy steady-state stepping, as the
single-device engines).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.compact import BlockLayout
from repro.workloads.base import StencilWorkload, check_workload_ndim
from repro.workloads.rules import LIFE

Array = jnp.ndarray

#: shard-local compute backends: XLA window path, v4 fused-depth kernel,
#: v5 MXU macro-tile kernel
COMPUTES = ("jnp", "fused", "mxu")

#: halo-exchange modes: neighbor-only ppermute with interior/boundary
#: overlap, strip all-gather fallback, or pick-per-mesh
EXCHANGES = ("auto", "p2p", "gather")


def _pad_blocks(layout: BlockLayout, n_shards: int) -> int:
    """Blocks padded so the leading axis divides the mesh axis size."""
    nb = layout.n_blocks
    return ((nb + n_shards - 1) // n_shards) * n_shards


@dataclasses.dataclass
class ExchangeStats:
    """Halo-exchange accounting of one engine: every fused launch issues
    exactly one exchange — one strip ``all_gather`` on the gather path,
    one pair of neighbor ``ppermute`` shifts on the p2p path (verified
    structurally by the tests, which count collectives in the lowered
    step HLO)."""

    steps: int = 0            # simulated steps advanced
    collectives: int = 0      # halo exchanges issued (one per launch)
    bytes_gathered: int = 0   # replicated strip-buffer bytes (gather)
    bytes_permuted: int = 0   # neighbor strip bytes on the wire (p2p)
    neighbor_sends: int = 0   # directed shard->shard strip sends (p2p)

    @property
    def exchanged_bytes(self) -> int:
        """Mode-independent exchanged volume (one of the two byte
        counters is always zero)."""
        return self.bytes_gathered + self.bytes_permuted

    @property
    def collectives_per_step(self) -> float:
        return self.collectives / max(self.steps, 1)

    @property
    def bytes_per_step(self) -> float:
        return self.exchanged_bytes / max(self.steps, 1)


@dataclasses.dataclass(frozen=True)
class DistributedSqueezeEngine:
    """Block-level Squeeze sharded over one mesh axis, workload-generic
    and fusion-aware.

    State layout: (C?, nb_padded, rho, rho) — or (B, C?, nb_padded, rho,
    rho) batched — sharded over the block axis. On the gather path the
    native order is compact id order plus a dead tail; on the p2p path it
    is the ``StripDecomposition`` permutation (expanded-row strips, dead
    padding at each shard's tail). Dead blocks are permanently zero: the
    neighbor table never points at them and every compute path gates them
    out of the occupancy mask. ``to_dense``/``from_dense`` convert to the
    mesh- and exchange-independent compact order.

    ``compute`` picks the shard-local backend ('jnp' | 'fused' | 'mxu');
    ``fusion_k`` the exchange/fusion depth used by ``run`` (None = the
    single-device ``default_fusion_k`` heuristic, always <= rho);
    ``exchange`` the halo-exchange mode ('auto' | 'p2p' | 'gather' — see
    the module docstring for the semantics and the fallback rule).
    """

    layout: BlockLayout
    mesh: Mesh
    axis: str = "data"
    workload: StencilWorkload = LIFE
    compute: str = "jnp"
    fusion_k: Optional[int] = None
    interpret: Optional[bool] = None  # kernel computes; None = auto-detect
    exchange: str = "auto"
    #: MXU macro-tile packing override ('mxu' compute only; applied to
    #: each shard's local lane-packing geometry, None = lane heuristic)
    macro_p: Optional[int] = None

    def __post_init__(self):
        if self.compute not in COMPUTES:
            raise ValueError(
                f"unknown compute {self.compute!r}; have {COMPUTES}")
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"unknown exchange {self.exchange!r}; have {EXCHANGES}")
        check_workload_ndim(self.workload, 2)
        if self.fusion_k is not None and not (
                1 <= self.fusion_k <= self.layout.rho):
            raise ValueError(
                f"distributed fusion_k must be in [1, rho="
                f"{self.layout.rho}], got {self.fusion_k} (the strip "
                "exchange covers one block ring)")
        if self.macro_p is not None and self.compute != "mxu":
            raise ValueError(
                "macro_p only applies to the 'mxu' compute, got "
                f"compute={self.compute!r}")
        self.layout.materialize()
        if self.exchange == "p2p" and not self.decomp.valid:
            raise ValueError(
                f"exchange='p2p' needs >= {self.n_shards} occupied "
                "expanded block rows (the strip decomposition is "
                "degenerate on this mesh); use exchange='auto' or "
                "'gather'")
        object.__setattr__(self, "_stats", ExchangeStats())

    # ------------------------------------------------------------ geometry
    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    @functools.cached_property
    def decomp(self):
        """The locality-aware strip decomposition for this mesh size
        (shared across engines via the layout memo; ``.valid`` is False
        on degenerate meshes)."""
        return self.layout.strip_decomposition(self.n_shards)

    @functools.cached_property
    def exchange_mode(self) -> str:
        """The RESOLVED exchange ('p2p' | 'gather'): 'auto' picks p2p
        whenever the strip decomposition is valid."""
        if self.exchange == "gather":
            return "gather"
        if self.exchange == "p2p":
            return "p2p"
        return "p2p" if self.decomp.valid else "gather"

    @property
    def nb_padded(self) -> int:
        if self.exchange_mode == "p2p":
            return self.decomp.nb_padded
        return _pad_blocks(self.layout, self.n_shards)

    @property
    def nb_local(self) -> int:
        return self.nb_padded // self.n_shards

    @property
    def effective_fusion_k(self) -> int:
        if self.fusion_k is not None:
            return self.fusion_k
        from repro.core.stencil import default_fusion_k
        return default_fusion_k(self.layout.rho)

    def state_spec(self, ndim: int) -> P:
        """PartitionSpec sharding the block axis (position ndim-3)."""
        spec = [None] * ndim
        spec[ndim - 3] = self.axis
        return P(*spec)

    def sharding(self, ndim: Optional[int] = None) -> NamedSharding:
        if ndim is None:
            ndim = 3 + (1 if self.workload.n_channels > 1 else 0)
        return NamedSharding(self.mesh, self.state_spec(ndim))

    # ----------------------------------------------------------- accounting
    def strip_bytes(self, k: int, batch: int = 1) -> int:
        """Bytes of the replicated strip buffer produced by one depth-``k``
        halo all-gather (the gather collective's payload)."""
        itemsize = jnp.dtype(self.workload.dtype).itemsize
        return (batch * self.workload.n_channels * self.nb_padded
                * 4 * k * self.layout.rho * itemsize)

    def permute_bytes(self, k: int, batch: int = 1) -> int:
        """Total bytes moved over the wire by one depth-``k`` p2p
        exchange (both ppermute shifts, every adjacent shard pair)."""
        itemsize = jnp.dtype(self.workload.dtype).itemsize
        return self.decomp.wire_bytes_per_exchange(
            k, itemsize, batch * self.workload.n_channels)

    def wire_bytes_per_device(self, k: int, batch: int = 1) -> int:
        """Bytes one shard RECEIVES per depth-``k`` exchange — the
        per-device wire pressure the scaling bench records. Flat in the
        shard count on the p2p path (two neighbors regardless of mesh
        size); grows ~linearly on the gather path (everyone else's
        strips)."""
        itemsize = jnp.dtype(self.workload.dtype).itemsize
        if self.exchange_mode == "p2p":
            return self.decomp.wire_bytes_per_device_per_exchange(
                k, itemsize, batch * self.workload.n_channels)
        return (batch * self.workload.n_channels
                * (self.nb_padded - self.nb_local)
                * 4 * k * self.layout.rho * itemsize)

    def exchange_stats(self) -> ExchangeStats:
        """Snapshot of the halo-exchange counters (exchanges issued,
        simulated steps advanced, bytes gathered/permuted, neighbor
        sends)."""
        return dataclasses.replace(self._stats)

    def reset_exchange_stats(self) -> None:
        st = self._stats
        st.steps = st.collectives = 0
        st.bytes_gathered = st.bytes_permuted = st.neighbor_sends = 0

    def _account(self, k: int, launches: int, batch: int) -> None:
        st = self._stats
        if self.exchange_mode == "p2p":
            gathered = 0
            permuted = launches * self.permute_bytes(k, batch)
            sends = launches * 2 * (self.n_shards - 1)
        else:
            gathered = launches * self.strip_bytes(k, batch)
            permuted = sends = 0
        st.steps += launches * k
        st.collectives += launches
        st.bytes_gathered += gathered
        st.bytes_permuted += permuted
        st.neighbor_sends += sends
        if obs.enabled():
            # the same accounting, unified onto the telemetry registry
            # (labeled by compute backend) so one obs.report() answers
            # "how many exchanges and bytes did this run ship"
            obs.inc("dist.steps", launches * k, compute=self.compute)
            obs.inc("dist.collectives", launches, compute=self.compute)
            obs.inc("dist.bytes_gathered", gathered,
                    compute=self.compute)
            obs.inc("dist.bytes_permuted", permuted,
                    compute=self.compute)
            obs.inc("dist.neighbor_sends", sends, compute=self.compute)
            obs.inc("engine.fused_launches", launches,
                    engine=type(self).__name__, variant=self.compute)

    def memory_bytes(self, dtype_size: Optional[int] = None) -> int:
        """Total (all-shard) Squeeze state bytes, padding blocks included
        (the per-shard footprint is this / n_shards)."""
        if dtype_size is None:
            dtype_size = jnp.dtype(self.workload.dtype).itemsize
        return (self.workload.n_channels * self.nb_padded
                * self.layout.rho ** 2 * dtype_size)

    # ------------------------------------------------------------ state I/O
    @functools.cached_property
    def _native_src(self) -> Optional[np.ndarray]:
        """(nb_padded,) compact block id feeding each native slot, with
        dead slots pointing at the appended zero block — None on the
        gather path, whose native order is compact order + dead tail."""
        if self.exchange_mode != "p2p":
            return None
        d = self.decomp
        return np.where(d.perm >= 0, d.perm,
                        np.int32(self.layout.n_blocks))

    @functools.cached_property
    def _dense_src(self) -> Optional[np.ndarray]:
        """(n_blocks,) native slot of each compact block id (the inverse
        gather of ``_native_src``) — None on the gather path."""
        if self.exchange_mode != "p2p":
            return None
        d = self.decomp
        return (d.shard_of.astype(np.int64) * d.nb_local
                + d.local_of).astype(np.int32)

    def _pad_state(self, dense: Array) -> Array:
        """Compact-order (B?, C?, n_blocks, rho, rho) -> engine-native
        block order (permuted strips on p2p, identity + dead tail on
        gather)."""
        src = self._native_src
        if src is None:
            pad = self.nb_padded - self.layout.n_blocks
            if pad:
                shape = dense.shape[:-3] + (pad,) + dense.shape[-2:]
                dense = jnp.concatenate(
                    [dense, jnp.zeros(shape, dense.dtype)], axis=-3)
            return dense
        zshape = dense.shape[:-3] + (1,) + dense.shape[-2:]
        dense_z = jnp.concatenate(
            [dense, jnp.zeros(zshape, dense.dtype)], axis=-3)
        return dense_z[..., src, :, :]

    def init_random(self, seed: int) -> Array:
        from repro.core.stencil import SqueezeBlockEngine
        dense = SqueezeBlockEngine(self.layout,
                                   self.workload).init_random(seed)
        dense = self._pad_state(dense)
        return jax.device_put(dense, self.sharding(dense.ndim))

    def init_batch(self, seeds) -> Array:
        """Stack independent initial states: (B, C?, nb_padded, rho, rho),
        sharded over the block axis."""
        from repro.core.stencil import SqueezeBlockEngine
        eng = SqueezeBlockEngine(self.layout, self.workload)
        dense = jnp.stack([eng.init_random(int(s)) for s in seeds])
        dense = self._pad_state(dense)
        return jax.device_put(dense, self.sharding(dense.ndim))

    def to_dense(self, state: Array) -> Array:
        """Engine-native -> compact block order (for comparison against
        single-device and for mesh-independent checkpoints)."""
        src = self._dense_src
        if src is None:
            return state[..., : self.layout.n_blocks, :, :]
        return state[..., src, :, :]

    def from_dense(self, dense: Array) -> Array:
        """(B?, C?, n_blocks, rho, rho) unpadded compact state ->
        engine-native padded + sharded state (the inverse of
        :meth:`to_dense`). This is the elastic-restore ingest path: a
        checkpoint saved under ANY mesh/exchange stores the
        mesh-independent dense state, and re-enters here permuted+padded
        for THIS engine's layout and device_put with its sharding."""
        dense = jnp.asarray(dense, jnp.dtype(self.workload.dtype))
        padded = self._pad_state(dense)
        return jax.device_put(padded, self.sharding(padded.ndim))

    def dead_mask(self) -> np.ndarray:
        """(nb_padded, rho, rho) uint8, 1 where a cell must be zero in
        every valid state: fractal holes inside real blocks (the mask
        discipline re-kills them each substep) and every cell of a
        padding block — in ENGINE-NATIVE block order. A nonzero cell
        under this mask is the signature of halo/strip corruption — the
        elastic runner's post-launch integrity check multiplies by it."""
        layout = self.layout
        hole = (1 - layout.micro_mask).astype(np.uint8)
        src = self._native_src
        if src is None:
            dead = np.broadcast_to(
                hole, (layout.n_blocks,) + hole.shape)
            pad = self.nb_padded - layout.n_blocks
            if pad:
                dead = np.concatenate(
                    [dead, np.ones((pad,) + hole.shape, np.uint8)],
                    axis=0)
            return np.ascontiguousarray(dead)
        hole_z = np.concatenate(
            [np.broadcast_to(hole, (layout.n_blocks,) + hole.shape),
             np.ones((1,) + hole.shape, np.uint8)], axis=0)
        return np.ascontiguousarray(hole_z[src])

    def to_expanded(self, state: Array) -> Array:
        """(B?, C?, nb_padded, rho, rho) -> (B?, C?, n, n) expanded."""
        return self.layout.to_expanded(self.to_dense(state))

    # --------------------------------------------------- canonical 5D states
    def _canon(self, state: Array) -> Tuple[Array, bool]:
        """Any public state rank -> ((B, C, nb_padded, rho, rho), batched)."""
        chan = self.workload.n_channels > 1
        base = 4 if chan else 3
        if state.ndim == base:
            return (state[None] if chan else state[None, None]), False
        if state.ndim == base + 1:
            return (state if chan else state[:, None]), True
        raise ValueError(
            f"bad state rank {state.ndim} for workload "
            f"{self.workload.name!r} (expected {base} or {base + 1})")

    def _uncanon(self, s5: Array, batched: bool) -> Array:
        chan = self.workload.n_channels > 1
        if batched:
            return s5 if chan else s5[:, 0]
        return s5[0] if chan else s5[0, 0]

    # ------------------------------------------------------- compiled steps
    @functools.cached_property
    def _cache(self) -> dict:
        """Per-instance memo of device tables and jitted step/run fns."""
        return {}

    def _memo(self, key, build):
        cache = self._cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def _shard_operands(self, k: int) -> Tuple[Array, ...]:
        """Per-shard static operands of a depth-``k`` launch, built ONCE
        and device_put sharded over the block axis (a traced step would
        otherwise re-derive them per launch — pure overhead on the
        per-step critical path). Gather mode: (mask, table, existence).
        P2p mode: those three in native strip order, sentinel-extended
        per shard, plus the interior-view table and the per-shard
        routing rows (send_prev, send_next, boundary).

          * halo mask (nb_padded, w, w): ``layout.halo_mask(k)`` (periodic
            window occupancy, ghost regions zeroed) with all-zero rows for
            dead blocks — so the substep mask discipline AND the
            padding-stays-dead guarantee are a single multiply;
          * neighbor table (nb_padded, 8): ``offset_table(k)`` (radius-1 ==
            the exact-past-holes Moore table), ghosts pre-remapped to the
            appended zero-strip row — gather: global strip ids; p2p: the
            decomposition's combined per-shard strip coordinates;
          * existence (nb_padded, 8) int32: scalar-prefetch operand of the
            shard-local kernels' in-kernel mask reconstruction.
        """
        def build_gather():
            layout = self.layout
            pad = self.nb_padded - layout.n_blocks
            w = layout.rho + 2 * k
            mask = np.concatenate(
                [layout.halo_mask(k),
                 np.zeros((pad, w, w), np.uint8)], axis=0)
            table = np.concatenate(
                [layout.offset_table(k),
                 np.full((pad, 8), layout.ghost, np.int32)], axis=0)
            table = np.where(table == layout.ghost,
                             np.int32(self.nb_padded), table)
            existence = np.concatenate(
                [layout.existence_table,
                 np.zeros((pad, 8), np.int32)], axis=0)
            row = NamedSharding(self.mesh, P(self.axis, None))
            cube = NamedSharding(self.mesh, P(self.axis, None, None))
            return (jax.device_put(mask, cube),
                    jax.device_put(table, row),
                    jax.device_put(existence, row))

        def build_p2p():
            layout, d = self.layout, self.decomp
            src = self._native_src  # dead slots -> appended zero rows
            ns, nbl = self.n_shards, self.nb_local
            w = layout.rho + 2 * k
            mask = np.concatenate(
                [layout.halo_mask(k),
                 np.zeros((1, w, w), np.uint8)], axis=0)[src]
            existence = np.concatenate(
                [layout.existence_table,
                 np.zeros((1, 8), np.int32)], axis=0)[src]
            table = d.table.reshape(self.nb_padded, 8)

            # pre-extend each shard's rows with the ghost/sentinel row
            # (index nbl): all-dead mask/existence, table pointing at
            # the appended zero strip row — hoists three per-launch
            # concatenations off the traced step's critical path
            def extend(rows, sentinel_row):
                per = rows.reshape((ns, nbl) + rows.shape[1:])
                sen = np.broadcast_to(
                    sentinel_row, (ns, 1) + rows.shape[1:])
                out = np.concatenate([per, sen], axis=1)
                return np.ascontiguousarray(
                    out.reshape((ns * (nbl + 1),) + rows.shape[1:]))

            mask_z = extend(mask, np.zeros((w, w), mask.dtype))
            ex_z = extend(existence, np.zeros(8, existence.dtype))
            table_z = extend(table, np.full(8, nbl, table.dtype))

            # interior-view table: every remote reference (combined slot
            # > nbl) remapped to the ghost zero row.  The full-domain
            # overlap pass reads halos through THIS table, so it depends
            # only on shard-local strips — correct for interior blocks
            # (whose rows the remap never touches), provisional for
            # boundary blocks (patched after the permutes land).
            table_int = np.ascontiguousarray(
                np.minimum(table, np.int32(nbl)))

            row = NamedSharding(self.mesh, P(self.axis, None))
            cube = NamedSharding(self.mesh, P(self.axis, None, None))
            return (jax.device_put(mask_z, cube),
                    jax.device_put(table_z, row),
                    jax.device_put(ex_z, row),
                    jax.device_put(table_int, row),
                    jax.device_put(d.send_prev_idx, row),
                    jax.device_put(d.send_next_idx, row),
                    jax.device_put(d.boundary_idx, row))

        build = build_p2p if self.exchange_mode == "p2p" \
            else build_gather
        return self._memo(("operands", self.exchange_mode, k), build)

    def _materialize(self, k: int) -> None:
        """Build every static host/device table a depth-``k`` traced step
        reads — outside any trace."""
        layout = self.layout
        layout.materialize()
        _ = self._shard_operands(k)
        if self.compute != "jnp":
            _ = layout.dev_window_mask(k)
        if self.compute == "mxu":
            from repro.kernels.squeeze_stencil import _mxu_operators
            if self.exchange_mode == "p2p":
                # full-domain overlap pass + boundary patch pass
                sizes = {self.nb_local,
                         self.decomp.boundary_idx.shape[1]}
            else:
                sizes = {self.nb_local}
            for n_sel in sizes:
                p_local = layout.macro_tiles_for(n_sel, k,
                                                 p=self.macro_p)[0]
                _mxu_operators(self.workload, layout.rho + 2 * k, p_local)

    # ---------------------------------------------------- shard-local compute
    def _compute_k(self, states: Array, halo, mask: Array,
                   existence: Array, k: int) -> Array:
        """k fused substeps on one set of blocks (any static count):
        ``states`` (B, C, n_sel, rho, rho), ``halo`` the matching
        depth-k pieces, ``mask``/``existence`` the selected rows of the
        sharded operands. Shared by the gather path (all local blocks at
        once) and the p2p path (full-domain overlap pass + boundary
        patch subset)."""
        layout = self.layout
        rho = layout.rho
        if self.compute == "mxu":
            from repro.kernels.squeeze_stencil import (
                stencil_step_mxu_k_local)
            out = stencil_step_mxu_k_local(
                layout, states, halo, existence, self.workload, k=k,
                p=self.macro_p, interpret=self.interpret)
        elif self.compute == "fused":
            from repro.kernels.squeeze_stencil import (
                stencil_step_fused_k_local)

            def one(s, top, bot, west, east):
                return stencil_step_fused_k_local(
                    layout, s, (top, bot, west, east), existence,
                    self.workload, k=k, interpret=self.interpret)

            out = jax.vmap(one)(states, *halo)
        else:
            return self._jnp_step_k(states, halo, mask, k)
        # the kernels gate halo regions in-kernel but keep the periodic
        # center mask — one multiply by the mask's center re-kills dead
        # blocks (their mask rows are all zero)
        center = mask[:, k:k + rho, k:k + rho]
        return out * center.astype(out.dtype)

    def _local_step_k(self, state_local: Array, mask: Array, table: Array,
                      existence: Array, k: int) -> Array:
        """One fused depth-``k`` gather-mode launch on this shard: pack
        strips, ONE all_gather, assemble halos, run k substeps locally.

        state_local (B, C, nb_local, rho, rho) -> same, k steps later;
        ``mask``/``table``/``existence`` are this shard's rows of the
        ``_shard_operands`` arrays.
        """
        layout, axis = self.layout, self.axis
        rho, nbl = layout.rho, self.nb_local
        b, nc = state_local.shape[0], state_local.shape[1]

        # 1. pack my edge bands ((B, C) folded: strip plumbing is linear
        # per leading axis)
        flat = state_local.reshape(b * nc, nbl, rho, rho)
        strips_local = layout.pack_edge_strips(flat, k)
        # 2. halo exchange: ONE all_gather of strips only
        strips = jax.lax.all_gather(strips_local, axis, axis=1, tiled=True)
        strips = jnp.concatenate(
            [strips,
             jnp.zeros((strips.shape[0], 1) + strips.shape[2:],
                       strips.dtype)], axis=1)  # ghost zero entry (row nbp)
        # 3. assemble my blocks' depth-k halos + shard-local fused compute
        halo = tuple(
            h.reshape((b, nc) + h.shape[1:])
            for h in layout.halo_from_strips_k(strips, table, k))
        return self._compute_k(state_local, halo, mask, existence, k)

    def _local_step_k_p2p(self, state_local: Array, mask: Array,
                          table: Array, existence: Array,
                          table_int: Array, send_prev: Array,
                          send_next: Array, boundary: Array,
                          k: int) -> Array:
        """One fused depth-``k`` p2p launch on this shard: pack strips,
        start the two neighbor ``ppermute`` shifts, run the k substeps
        over the WHOLE local domain from shard-local strips only WHILE
        the permutes are in flight (``table_int`` remaps every remote
        halo reference to the ghost zero row, so the pass has no data
        dependence on the collectives — exact for interior blocks,
        provisional for boundary blocks), then recompute just the
        boundary blocks from the combined local+received strip buffer
        and patch them in (compute-all-then-patch overlap).

        state_local (B, C, nb_local, rho, rho) -> same, k steps later.
        ``mask``/``table``/``existence`` are this shard's rows of the
        native-ordered operands, pre-extended with the ghost/sentinel
        row (index nb_local: all-dead, table pointing at the zero strip
        row); ``table_int`` the (nb_local, 8) interior-view table;
        ``send_prev``/``send_next``/``boundary`` this shard's (1, m)
        routing rows (indices into [0, nb_local])."""
        layout, axis = self.layout, self.axis
        rho, nbl, ns = layout.rho, self.nb_local, self.n_shards
        b, nc = state_local.shape[0], state_local.shape[1]
        sp, sn = send_prev[0], send_next[0]
        bi = boundary[0]

        # 1. pack my edge bands + the shared ghost/sentinel zero row
        flat = state_local.reshape(b * nc, nbl, rho, rho)
        strips = layout.pack_edge_strips(flat, k)
        strips_z = jnp.concatenate(
            [strips,
             jnp.zeros((strips.shape[0], 1) + strips.shape[2:],
                       strips.dtype)], axis=1)
        # 2. halo exchange: two neighbor-only permute shifts carrying
        # ONLY the strips each neighbor needs (dead routing slots ship
        # the zero row)
        fwd = [(i, i + 1) for i in range(ns - 1)]
        bwd = [(i + 1, i) for i in range(ns - 1)]
        recv_prev = jax.lax.ppermute(strips_z[:, sn], axis, fwd)
        recv_next = jax.lax.ppermute(strips_z[:, sp], axis, bwd)

        # 3a. full-domain overlap pass: halos through the interior-view
        # table touch only strips_z, so XLA schedules these kernels
        # concurrently with the in-flight permutes.  Boundary rows come
        # out provisional (their remote neighbors read as dead) and are
        # patched below; interior rows are final.
        halo_full = tuple(
            h.reshape((b, nc) + h.shape[1:])
            for h in layout.halo_from_strips_k(strips_z, table_int, k))
        out = self._compute_k(state_local, halo_full,
                              mask[:nbl], existence[:nbl], k)
        # 3b. boundary fix-up from local + received strips, in the
        # decomposition's combined coordinate convention:
        # [0, nbl) local | nbl ghost | ms_next from prev | ms_prev next
        combined = jnp.concatenate(
            [strips_z, recv_prev, recv_next], axis=1)
        halo_bnd = tuple(
            h.reshape((b, nc) + h.shape[1:])
            for h in layout.halo_from_strips_k(combined, table[bi], k))
        out_bnd = self._compute_k(
            state_local[:, :, jnp.minimum(bi, nbl - 1)], halo_bnd,
            mask[bi], existence[bi], k)
        # sentinel padding entries (index nbl) are out of bounds on the
        # nbl-row axis: the gather above clamps them (their value is
        # irrelevant — the zero mask row kills the output) and the
        # scatter here drops them (default OOB-drop semantics)
        return out.at[:, :, bi].set(out_bnd)

    def _jnp_step_k(self, states: Array, halo, mask: Array,
                    k: int) -> Array:
        """XLA window path: assemble (B, C, n_sel, rho+2k, rho+2k) tiles
        and run the workload's k fused substeps under the precomputed
        sharded halo mask (the same per-block occupancy the single-device
        XLA ``step_k`` reads; dead-block rows are all zero, so the
        k-substep mask discipline and the padding gate are one
        multiply)."""
        layout, wl = self.layout, self.workload
        rho = layout.rho
        w = rho + 2 * k
        top, bot, west, east = halo
        b, nc, nbl = states.shape[:3]
        padded = jnp.zeros((b, nc, nbl, w, w), states.dtype)
        padded = padded.at[..., k:k + rho, k:k + rho].set(states)
        padded = padded.at[..., :k, :].set(top)
        padded = padded.at[..., w - k:, :].set(bot)
        padded = padded.at[..., k:k + rho, :k].set(west)
        padded = padded.at[..., k:k + rho, w - k:].set(east)

        def one(p):  # (C, nbl, w, w) -> (C, nbl, rho, rho)
            if wl.n_channels > 1:
                return wl.tile_rule_k(p, mask, k)
            return wl.tile_rule_k(p[0], mask, k)[None]

        return jax.vmap(one)(padded).astype(states.dtype)

    def _step5_fn(self, k: int, donate: bool = False):
        """Jitted shard_map'd fused step over canonical 5D states plus the
        sharded static operands."""
        def build():
            self._materialize(k)
            from repro.utils.jax_compat import shard_map
            spec = self.state_spec(5)
            row = P(self.axis, None)
            if self.exchange_mode == "p2p":
                local = functools.partial(self._local_step_k_p2p, k=k)
                in_specs = (spec, P(self.axis, None, None),
                            row, row, row, row, row, row)
            else:
                local = functools.partial(self._local_step_k, k=k)
                in_specs = (spec, P(self.axis, None, None), row, row)
            # pallas_call has no shard_map replication rule: the kernel
            # computes must disable the (conservative) rep check
            step = shard_map(
                local, mesh=self.mesh, in_specs=in_specs,
                out_specs=spec, check_rep=self.compute == "jnp")
            return jax.jit(step, donate_argnums=0) if donate \
                else jax.jit(step)
        return self._memo(("step5", k, donate), build)

    def _call_step(self, k: int, s5: Array, donate: bool = False) -> Array:
        return self._step5_fn(k, donate)(s5, *self._shard_operands(k))

    def _loop_fn(self, k: int, donate: bool):
        """Jitted fori_loop of fused launches; the launch count is a
        *traced* scalar, so changing ``steps`` does not retrace."""
        def build():
            step = self._step5_fn(k)

            def body(s5, n, *ops):
                return jax.lax.fori_loop(
                    0, n, lambda _, s: step(s, *ops), s5)

            return jax.jit(body, donate_argnums=0) if donate \
                else jax.jit(body)
        return self._memo(("loop", k, donate), build)

    # ------------------------------------------------------------ public API
    def step(self, state: Array) -> Array:
        """One step (one halo exchange)."""
        return self.step_k(state, 1)

    def step_k(self, state: Array, k: int) -> Array:
        """``k`` exact steps in one fused launch: ONE halo exchange of
        depth-``k`` strips, then k shard-local substeps (1 <= k <= rho)."""
        if not (1 <= k <= self.layout.rho):
            raise ValueError(
                f"need 1 <= k <= rho={self.layout.rho}, got k={k}")
        s5, batched = self._canon(state)
        out = self._call_step(k, s5)
        self._account(k, 1, s5.shape[0])
        return self._uncanon(out, batched)

    def step_batched(self, states: Array) -> Array:
        return self.step_k(states, 1)

    def step_k_batched(self, states: Array, k: int) -> Array:
        return self.step_k(states, k)

    @property
    def supports_native_batch(self) -> bool:
        """B simulations advance through one shard_map step whose strip
        exchange is a single batched collective (every compute backend;
        'mxu' additionally runs one (B, n_macro_local) kernel grid)."""
        return True

    def run(self, state: Array, steps: int, donate: bool = False) -> Array:
        """``steps`` steps tiled into floor(steps/k) fused depth-k launches
        plus ONE remainder launch of depth steps % k — exactly
        ceil(steps/k) halo exchanges total. ``donate=True`` donates the
        state buffer to XLA (zero-copy stepping; the caller must not reuse
        ``state`` afterwards)."""
        steps = int(steps)
        if steps < 0:
            raise ValueError(f"steps must be >= 0, got {steps}")
        s5, batched = self._canon(state)
        b = s5.shape[0]
        k = self.effective_fusion_k
        n_fused, rem = divmod(steps, k)
        with obs.span("dist.run", compute=self.compute, steps=steps,
                      k=k, batch=b):
            if n_fused:
                s5 = self._loop_fn(k, donate)(
                    s5, jnp.asarray(n_fused, jnp.int32),
                    *self._shard_operands(k))
                self._account(k, n_fused, b)
            if rem:
                s5 = self._call_step(rem, s5, donate)
                self._account(rem, 1, b)
        if donate:
            obs.inc("engine.donated_runs",
                    engine=type(self).__name__, variant=self.compute)
        return self._uncanon(s5, batched)

    def lowered_step_text(self, state: Array, k: int) -> str:
        """Lowered StableHLO of one fused depth-``k`` launch — the tests
        count its collectives (one all_gather per gather launch; two
        collective_permutes and ZERO all_gathers per p2p launch)."""
        s5, _ = self._canon(state)
        return self._step5_fn(k).lower(
            s5, *self._shard_operands(k)).as_text()


def make_distributed_engine(layout: BlockLayout, mesh: Optional[Mesh] = None,
                            axis: str = "data",
                            workload: StencilWorkload = LIFE,
                            compute: str = "jnp",
                            fusion_k: Optional[int] = None,
                            interpret: Optional[bool] = None,
                            exchange: str = "auto",
                            macro_p: Optional[int] = None
                            ) -> DistributedSqueezeEngine:
    """Engine over ``mesh`` (default: all devices on one "data" axis)."""
    if mesh is None:
        mesh = Mesh(jax.devices(), ("data",))
        axis = "data"
    return DistributedSqueezeEngine(layout, mesh, axis, workload, compute,
                                    fusion_k, interpret, exchange,
                                    macro_p=macro_p)
