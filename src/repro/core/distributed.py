"""Multi-device compact fractal stencil: shard_map + strip halo exchange.

The compact block domain (the *only* thing in memory — the paper's P2 win)
is sharded along its leading block axis over a mesh axis (default "data").
One step is:

  1. locally slice each block's 4 edge strips + 4 corners into a packed
     (nb_local, 4, rho+2) "source strip" array — ~(4 rho + 4)/rho^2 of the
     state bytes;
  2. ``all_gather`` the strips over the mesh axis (the halo exchange —
     strips only, never the state);
  3. gather each local block's Moore halo from the replicated strips via
     the static neighbor table (built once from the paper's lambda/nu
     maps) and run the fused in-tile life rule.

Because the neighbor table is arbitrary (fractal adjacency is non-local in
compact space), a nearest-neighbor ``ppermute`` ring is insufficient in
general; an all-gather of *strips only* keeps the exchanged volume at
O(nb * rho) versus the O(nb * rho^2) state. For 1000+ nodes the same
scheme shards over ("pod","data") jointly — the gather is hierarchical
(ICI within a pod, DCI across pods) and XLA schedules it that way from the
single logical all_gather.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.baselines import life_rule
from repro.core.compact import BlockLayout

Array = jnp.ndarray


def _pad_blocks(layout: BlockLayout, n_shards: int) -> int:
    """Blocks padded so the leading axis divides the mesh axis size."""
    nb = layout.n_blocks
    return ((nb + n_shards - 1) // n_shards) * n_shards


def _source_strips(state: Array, rho: int) -> Array:
    """Pack each block's edges into (nb, 4, rho+2):
    row 0: top row | row 1: bottom row | row 2: west col | row 3: east col,
    each padded with the block's own corners at positions [rho], [rho+1]."""
    def pack(row_like, c0, c1):
        return jnp.concatenate(
            [row_like, c0[:, None], c1[:, None]], axis=1)
    top = pack(state[:, 0, :], state[:, 0, 0], state[:, 0, -1])
    bot = pack(state[:, -1, :], state[:, -1, 0], state[:, -1, -1])
    west = pack(state[:, :, 0], state[:, 0, 0], state[:, -1, 0])
    east = pack(state[:, :, -1], state[:, 0, -1], state[:, -1, -1])
    return jnp.stack([top, bot, west, east], axis=1)


def _halo_from_strips(layout: BlockLayout, padded_table: Array,
                      strips: Array, local_ids: Array) -> Array:
    """Assemble (nb_local, 4, rho+2) Moore halos from replicated strips.

    padded_table: (nb_padded, 8) neighbor table, ghost rows for padding.
    strips: (nb_padded + 1, 4, rho+2) — last entry is the zero ghost.
    local_ids: (nb_local,) global block ids of this shard's blocks.
    """
    rho = layout.rho
    table = padded_table[local_ids]  # (nbl, 8)
    ghost = strips.shape[0] - 1
    table = jnp.where(table == layout.ghost, ghost, table)

    # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
    # strips rows: 0 top, 1 bottom, 2 west, 3 east; corners at [rho], [rho+1]
    nw_se = strips[table[:, 0], 1, rho + 1]   # NW nbr bottom-right corner
    n_bot = strips[table[:, 1], 1, :rho]      # N nbr bottom row
    ne_sw = strips[table[:, 2], 1, rho]       # NE nbr bottom-left corner
    w_east = strips[table[:, 3], 3, :rho]     # W nbr east col
    e_west = strips[table[:, 4], 2, :rho]     # E nbr west col
    sw_ne = strips[table[:, 5], 0, rho + 1]   # SW nbr top-right corner
    s_top = strips[table[:, 6], 0, :rho]      # S nbr top row
    se_nw = strips[table[:, 7], 0, rho]       # SE nbr top-left corner

    row_top = jnp.concatenate(
        [nw_se[:, None], n_bot, ne_sw[:, None]], axis=1)   # (nbl, rho+2)
    row_bot = jnp.concatenate(
        [sw_ne[:, None], s_top, se_nw[:, None]], axis=1)
    col_w = jnp.pad(w_east, ((0, 0), (0, 2)))
    col_e = jnp.pad(e_west, ((0, 0), (0, 2)))
    return jnp.stack([row_top, row_bot, col_w, col_e], axis=1)


def _tile_step(layout: BlockLayout, state: Array, halo: Array) -> Array:
    """Vectorised in-tile life rule given assembled halos (jnp path)."""
    rho = layout.rho
    nbl = state.shape[0]
    padded = jnp.zeros((nbl, rho + 2, rho + 2), jnp.int32)
    padded = padded.at[:, 1:-1, 1:-1].set(state.astype(jnp.int32))
    padded = padded.at[:, 0, :].set(halo[:, 0].astype(jnp.int32))
    padded = padded.at[:, -1, :].set(halo[:, 1].astype(jnp.int32))
    padded = padded.at[:, 1:-1, 0].set(halo[:, 2, :rho].astype(jnp.int32))
    padded = padded.at[:, 1:-1, -1].set(halo[:, 3, :rho].astype(jnp.int32))
    counts = jnp.zeros((nbl, rho, rho), jnp.int32)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dx == 0 and dy == 0:
                continue
            counts += padded[:, 1 + dy:rho + 1 + dy, 1 + dx:rho + 1 + dx]
    nxt = life_rule(state, counts)
    return nxt * layout.dev_micro_mask[None]


@dataclasses.dataclass(frozen=True)
class DistributedSqueezeEngine:
    """Block-level Squeeze sharded over one mesh axis.

    State layout: (nb_padded, rho, rho) uint8, sharded P(axis, None, None);
    padding blocks (ids >= layout.n_blocks) are permanently dead — the
    neighbor table never points at them.
    """

    layout: BlockLayout
    mesh: Mesh
    axis: str = "data"

    def __post_init__(self):
        self.layout.materialize()

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def nb_padded(self) -> int:
        return _pad_blocks(self.layout, self.n_shards)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis, None, None))

    def init_random(self, seed: int) -> Array:
        from repro.core.stencil import SqueezeBlockEngine
        dense = SqueezeBlockEngine(self.layout).init_random(seed)
        rho = self.layout.rho
        pad = self.nb_padded - self.layout.n_blocks
        dense = jnp.concatenate(
            [dense, jnp.zeros((pad, rho, rho), dense.dtype)], axis=0)
        return jax.device_put(dense, self.sharding())

    def to_dense(self, state: Array) -> Array:
        """Strip padding blocks (for comparison against single-device)."""
        return state[: self.layout.n_blocks]

    @functools.cached_property
    def _step_fn(self):
        import numpy as np
        layout, axis = self.layout, self.axis
        nb_padded = self.nb_padded
        n_shards = self.n_shards
        nbl = nb_padded // n_shards
        rho = layout.rho
        # padding blocks (ids >= n_blocks) get all-ghost rows: their halos
        # are zero, so the life rule can never birth cells into them.
        padded_table = np.concatenate([
            layout.neighbor_table,
            np.full((nb_padded - layout.n_blocks, 8), layout.ghost,
                    np.int32)], axis=0)

        def local_step(state_local: Array) -> Array:
            # which shard am I / which global blocks do I own
            idx = jax.lax.axis_index(axis)
            local_ids = idx * nbl + jnp.arange(nbl, dtype=jnp.int32)
            # 1. pack my edge strips
            strips_local = _source_strips(state_local, rho)
            # 2. halo exchange: all_gather strips only
            strips = jax.lax.all_gather(
                strips_local, axis, axis=0, tiled=True)
            strips = jnp.concatenate(
                [strips, jnp.zeros((1,) + strips.shape[1:], strips.dtype)],
                axis=0)  # ghost
            # 3. assemble halos + fused in-tile rule
            halo = _halo_from_strips(layout, jnp.asarray(padded_table),
                                     strips, local_ids)
            return _tile_step(layout, state_local, halo)

        from repro.utils.jax_compat import shard_map
        step = shard_map(
            local_step, mesh=self.mesh,
            in_specs=P(self.axis, None, None),
            out_specs=P(self.axis, None, None))
        return jax.jit(step)

    def step(self, state: Array) -> Array:
        return self._step_fn(state)

    def run(self, state: Array, steps: int) -> Array:
        @jax.jit
        def body(s):
            return jax.lax.fori_loop(
                0, steps, lambda _, x: self._step_fn(x), s)
        # fori_loop over an already-jitted shard_map keeps one compilation
        return body(state)


def make_distributed_engine(layout: BlockLayout, mesh: Optional[Mesh] = None,
                            axis: str = "data") -> DistributedSqueezeEngine:
    if mesh is None:
        devs = jax.devices()
        mesh = Mesh(devs, ("data",))
        axis = "data"
    return DistributedSqueezeEngine(layout, mesh, axis)
