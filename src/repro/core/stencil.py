"""Squeeze engines: stencil simulation entirely in compact space (paper
Sections 3.2-3.5).

  * ``SqueezeCellEngine``  — the paper-faithful per-cell scheme: one lambda
    per cell, one (fused) nu + membership test per neighbor, gathers from
    the compact state. Memory = k^r cells.
  * ``SqueezeBlockEngine`` — block-level Squeeze (Section 3.5): maps run at
    block granularity; each block is a rho x rho expanded micro-fractal.
    The static block-neighbor table (built once with the maps; see
    DESIGN.md Section 2 for the TPU-native restructure) turns the step
    into halo-gather + dense in-tile stencil.
  * ``SqueezePallasEngine`` — the block engine with its step fused into
    one of the Pallas kernels (kernels/squeeze_stencil.py).

Every engine is parameterized by a ``StencilWorkload`` (default: the
paper's game of life); multi-channel workloads carry a leading channel
axis (cell state (C, rows, cols); block state (C, n_blocks, rho, rho)).
All engines produce states convertible to the same expanded embedding as
the baselines (tests assert step-for-step equivalence).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import maps
from repro.core.baselines import (BBEngine, _moore_counts,  # noqa: F401
                                  life_rule)
from repro.core.compact import (BlockLayout, MOORE_DIRS, compact_meshgrid,
                                compact_to_expanded, expanded_to_compact)
from repro.core.fractals import NBBFractal
from repro.workloads.base import (StencilWorkload, check_workload_ndim,
                                  weighted_gather_agg, weighted_moore_agg)
from repro.workloads.rules import LIFE

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SqueezeCellEngine:
    """Paper-faithful compact-space engine (thread-level Squeeze)."""

    frac: NBBFractal
    r: int
    workload: StencilWorkload = LIFE

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r,
                            self.workload).init_random(seed)
        return expanded_to_compact(self.frac, self.r, expanded)

    def to_expanded(self, state: Array) -> Array:
        return compact_to_expanded(self.frac, self.r, state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r, wl = self.frac, self.r, self.workload
        cx, cy = compact_meshgrid(frac, r)
        # 1 lambda per cell: where am I in (virtual) expanded space?
        ex, ey = maps.lambda_map(frac, r, cx, cy)

        def gather(d):
            # 1 nu (+ membership, fused — same digit pass) per neighbor
            nx, ny, valid = maps.nu_with_membership(
                frac, r, ex + d[0], ey + d[1])
            return jnp.where(valid, state[..., ny, nx],
                             jnp.zeros((), state.dtype))

        agg = weighted_gather_agg(MOORE_DIRS, wl.weights2d, gather,
                                  state.shape[:-2] + ex.shape, wl.agg_dtype)
        # every compact cell is a fractal cell: no mask
        return wl.apply(state, agg, None).astype(state.dtype)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        rows, cols = self.frac.compact_dims(self.r)
        return self.workload.n_channels * rows * cols * dtype_size


@dataclasses.dataclass(frozen=True)
class SqueezeBlockEngine:
    """Block-level Squeeze (paper Section 3.5) with a static neighbor table."""

    layout: BlockLayout
    workload: StencilWorkload = LIFE

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)
        self.layout.materialize()

    @property
    def frac(self) -> NBBFractal:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r,
                            self.workload).init_random(seed)
        return self.layout.from_expanded(expanded)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        pad = self.layout.pad_with_halo
        if wl.n_channels > 1:
            pad = jax.vmap(pad)  # over the leading channel axis
        padded = pad(state)  # (C?, nb, rho+2, rho+2)
        agg = weighted_moore_agg(padded, wl.weights2d, wl.agg_dtype)
        mask = jnp.asarray(self.layout.micro_mask)  # broadcasts over C?, nb
        return wl.apply(state, agg, mask).astype(state.dtype)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.workload.n_channels * self.layout.memory_bytes(dtype_size)


@dataclasses.dataclass(frozen=True)
class SqueezePallasEngine:
    """Block-level Squeeze with the step fused into a Pallas kernel.

    ``variant`` selects the halo strategy of kernels/squeeze_stencil.py:
    'blocks' (v1, paper-shaped), 'strips' (v2, pre-gathered strip halos) or
    'fused' (v3, in-kernel strip reads). State layout and conversions are
    identical to ``SqueezeBlockEngine``.
    """

    layout: BlockLayout
    workload: StencilWorkload = LIFE
    variant: str = "strips"

    def __post_init__(self):
        if self.variant not in ("blocks", "strips", "fused"):
            raise ValueError(f"unknown Pallas variant {self.variant!r}")
        check_workload_ndim(self.workload, 2)
        self.layout.materialize()

    @property
    def frac(self) -> NBBFractal:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        return SqueezeBlockEngine(self.layout,
                                  self.workload).init_random(seed)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    def step(self, state: Array) -> Array:
        from repro.kernels import ops
        fn = {"blocks": ops.stencil_step_blocks,
              "strips": ops.stencil_step_strips,
              "fused": ops.stencil_step_fused}[self.variant]
        return fn(self.layout, state, self.workload)

    def run(self, state: Array, steps: int) -> Array:
        step = self.step
        return jax.lax.fori_loop(0, steps, lambda _, s: step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.workload.n_channels * self.layout.memory_bytes(dtype_size)


def make_engine(kind: str, frac: NBBFractal, r: int, m: int = 0,
                workload: StencilWorkload = LIFE):
    """Engine factory.

    kind: 'bb' | 'lambda' | 'cell' | 'block' | 'pallas-blocks' |
          'pallas-strips' | 'pallas-fused' ('pallas' = 'pallas-strips').
    ``m`` (block level, rho = s**m) only applies to the block/pallas kinds.
    """
    from repro.core.baselines import LambdaEngine
    if kind == "bb":
        return BBEngine(frac, r, workload)
    if kind == "lambda":
        return LambdaEngine(frac, r, workload)
    if kind == "cell":
        return SqueezeCellEngine(frac, r, workload)
    if kind == "block":
        return SqueezeBlockEngine(BlockLayout(frac, r, m), workload)
    if kind == "pallas":
        kind = "pallas-strips"
    if kind.startswith("pallas-"):
        return SqueezePallasEngine(BlockLayout(frac, r, m), workload,
                                   variant=kind[len("pallas-"):])
    raise ValueError(f"unknown engine kind {kind!r}")
