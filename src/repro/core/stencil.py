"""Squeeze engines: stencil simulation entirely in compact space (paper
Sections 3.2-3.5).

  * ``SqueezeCellEngine``  — the paper-faithful per-cell scheme: one lambda
    per cell, one (fused) nu + membership test per neighbor, gathers from
    the compact state. Memory = k^r cells.
  * ``SqueezeBlockEngine`` — block-level Squeeze (Section 3.5): maps run at
    block granularity; each block is a rho x rho expanded micro-fractal.
    The static block-neighbor table (built once with the maps; see
    DESIGN.md Section 2 for the TPU-native restructure) turns the step
    into halo-gather + dense in-tile stencil.
  * ``SqueezePallasEngine`` — the block engine with its step fused into
    one of the Pallas kernels (kernels/squeeze_stencil.py).

Every engine is parameterized by a ``StencilWorkload`` (default: the
paper's game of life); multi-channel workloads carry a leading channel
axis (cell state (C, rows, cols); block state (C, n_blocks, rho, rho)).
All engines produce states convertible to the same expanded embedding as
the baselines (tests assert step-for-step equivalence).

Temporal fusion: the block engines additionally expose ``step_k`` (k
exact steps per launch via depth-k halos; DESIGN.md Section 2) and their
``run(state, steps)`` tiles the step count into ceil(steps/k) fused
launches plus a single-step remainder, with ``k`` chosen by the static
``default_fusion_k`` heuristic unless the engine's ``fusion_k`` field
overrides it. ``run(..., donate=True)`` donates the stepped state buffer
to XLA (zero-copy steady-state stepping).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import maps
from repro.core.baselines import (BBEngine, _moore_counts,  # noqa: F401
                                  life_rule)
from repro.core.compact import (BlockLayout, MOORE_DIRS, compact_meshgrid,
                                compact_to_expanded, expanded_to_compact)
from repro.core.fractals import NBBFractal
from repro.workloads.base import (StencilWorkload, check_workload_ndim,
                                  weighted_gather_agg, weighted_moore_agg)
from repro.workloads.rules import LIFE

Array = jnp.ndarray


def default_fusion_k(rho: int) -> int:
    """Static temporal-fusion depth heuristic for a rho x rho block tile.

    The fused working window is (rho+2k)^2, so deeper fusion trades
    redundant halo-ring compute for ~k-fold amortization of dispatch,
    table gathers and center HBM traffic. Small tiles can't afford a deep
    ring (rho < 2 -> no fusion); big tiles amortize a ring of 3 easily.
    Always <= rho, so the heuristic depth is valid for the Pallas fused-k
    kernel as well as the XLA path. Explicit ``fusion_k`` on the engines
    (or ``k=`` on the runner) overrides this.
    """
    if rho < 2:
        return 1
    return 3 if rho >= 8 else 2


class _CachedRun:
    """Cached-jit run machinery: hosts define ``_run_impl(state, steps)``
    with a *traced* steps scalar, and their ``run`` dispatches through
    ``_dispatch_run`` — one plain and one ``donate_argnums`` compilation
    per engine value, neither retracing when the step count changes.

    The ``engine.trace`` counter increments only while jax traces the
    body (cached dispatches skip it), so the telemetry registry turns
    "changing the step count must not retrace" into an assertable
    invariant (see tests/test_obs.py; counts appear only if telemetry
    was enabled at trace time)."""

    @partial(jax.jit, static_argnums=0)
    def _run(self, state: Array, steps) -> Array:
        obs.inc("engine.trace", engine=type(self).__name__, fn="run")
        return self._run_impl(state, steps)

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _run_donated(self, state: Array, steps) -> Array:
        obs.inc("engine.trace", engine=type(self).__name__,
                fn="run_donated")
        return self._run_impl(state, steps)

    def _dispatch_run(self, state: Array, steps, donate: bool) -> Array:
        fn = self._run_donated if donate else self._run
        return fn(state, jnp.asarray(steps, jnp.int32))


class _FusedStepping(_CachedRun):
    """Temporal-fusion run machinery shared by the block engines.

    Hosts require a ``layout``, a ``fusion_k`` field, ``step(state)`` and
    ``step_k(state, k)``; they override ``_materialize_fused(k)`` to build
    whatever static geometry their k-step body reads (outside any trace).
    """

    @property
    def effective_fusion_k(self) -> int:
        if self.fusion_k is not None:
            return self.fusion_k
        return default_fusion_k(self.layout.rho)

    def _materialize_fused(self, k: int) -> None:
        raise NotImplementedError

    def _run_impl(self, state: Array, steps) -> Array:
        k = self.effective_fusion_k
        if k <= 1:
            return jax.lax.fori_loop(0, steps,
                                     lambda _, s: self.step(s), state)
        state = jax.lax.fori_loop(0, steps // k,
                                  lambda _, s: self.step_k(s, k), state)
        return jax.lax.fori_loop(0, steps % k,
                                 lambda _, s: self.step(s), state)

    def run(self, state: Array, steps, donate: bool = False) -> Array:
        """``steps`` steps, tiled into floor(steps/k) fused k-step launches
        plus a steps%k single-step remainder (``steps`` stays a dynamic
        loop bound: changing it does not retrace). ``donate=True`` donates
        the input state buffer to XLA — zero-copy steady-state stepping;
        the caller must not reuse ``state`` afterwards.

        With telemetry enabled, each call counts its fused launches,
        remainder single steps and donation usage on the registry
        (``engine.fused_launches`` / ``engine.single_steps`` /
        ``engine.donated_runs``, labeled by engine class + Pallas
        variant)."""
        k = self.effective_fusion_k
        if k > 1:                 # the k<=1 path never touches halo tables
            self._materialize_fused(k)
        if obs.enabled():
            n = int(steps)
            lbl = dict(engine=type(self).__name__,
                       variant=getattr(self, "variant", ""))
            obs.inc("engine.runs", **lbl)
            obs.inc("engine.steps", n, **lbl)
            if k > 1:
                obs.inc("engine.fused_launches", n // k, **lbl)
                obs.inc("engine.single_steps", n % k, **lbl)
            else:
                obs.inc("engine.single_steps", n, **lbl)
            if donate:
                obs.inc("engine.donated_runs", **lbl)
        return self._dispatch_run(state, steps, donate)


@dataclasses.dataclass(frozen=True)
class SqueezeCellEngine(_CachedRun):
    """Paper-faithful compact-space engine (thread-level Squeeze)."""

    frac: NBBFractal
    r: int
    workload: StencilWorkload = LIFE

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r,
                            self.workload).init_random(seed)
        return expanded_to_compact(self.frac, self.r, expanded)

    def to_expanded(self, state: Array) -> Array:
        return compact_to_expanded(self.frac, self.r, state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r, wl = self.frac, self.r, self.workload
        cx, cy = compact_meshgrid(frac, r)
        # 1 lambda per cell: where am I in (virtual) expanded space?
        ex, ey = maps.lambda_map(frac, r, cx, cy)

        def gather(d):
            # 1 nu (+ membership, fused — same digit pass) per neighbor
            nx, ny, valid = maps.nu_with_membership(
                frac, r, ex + d[0], ey + d[1])
            return jnp.where(valid, state[..., ny, nx],
                             jnp.zeros((), state.dtype))

        agg = weighted_gather_agg(MOORE_DIRS, wl.weights2d, gather,
                                  state.shape[:-2] + ex.shape, wl.agg_dtype)
        # every compact cell is a fractal cell: no mask
        return wl.apply(state, agg, None).astype(state.dtype)

    def _run_impl(self, state: Array, steps) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def run(self, state: Array, steps, donate: bool = False) -> Array:
        """``steps`` steps in one cached jit whose loop bound is a *traced*
        scalar — changing the step count does not recompile (the old
        bare ``fori_loop`` baked the Python int into the trace, so every
        distinct count paid a full retrace). ``donate=True`` donates the
        input state buffer to XLA (zero-copy steady-state stepping; the
        caller must not reuse ``state`` afterwards) — same signature as
        the block engines' ``run``."""
        return self._dispatch_run(state, steps, donate)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        rows, cols = self.frac.compact_dims(self.r)
        return self.workload.n_channels * rows * cols * dtype_size


@dataclasses.dataclass(frozen=True)
class SqueezeBlockEngine(_FusedStepping):
    """Block-level Squeeze (paper Section 3.5) with a static neighbor table.

    ``fusion_k`` sets the temporal-fusion depth used by ``run`` (None =
    the ``default_fusion_k`` heuristic). The XLA ``step_k`` path supports
    any k >= 1 — depths beyond rho span multiple block rings through the
    depth-k offset tables.
    """

    layout: BlockLayout
    workload: StencilWorkload = LIFE
    fusion_k: Optional[int] = None

    def __post_init__(self):
        check_workload_ndim(self.workload, 2)
        if self.fusion_k is not None and self.fusion_k < 1:
            raise ValueError(f"fusion_k must be >= 1, got {self.fusion_k}")
        self.layout.materialize()

    @property
    def frac(self) -> NBBFractal:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r,
                            self.workload).init_random(seed)
        return self.layout.from_expanded(expanded)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        pad = self.layout.pad_with_halo
        if wl.n_channels > 1:
            pad = jax.vmap(pad)  # over the leading channel axis
        padded = pad(state)  # (C?, nb, rho+2, rho+2)
        agg = weighted_moore_agg(padded, wl.weights2d, wl.agg_dtype)
        mask = self.layout.dev_micro_mask  # broadcasts over C?, nb
        return wl.apply(state, agg, mask).astype(state.dtype)

    # ------------------------------------------------------ temporal fusion
    def _materialize_fused(self, k: int) -> None:
        self.layout.materialize_halo(k)

    def step_k(self, state: Array, k: int) -> Array:
        """Advance ``k`` exact steps in one fused computation: one depth-k
        halo assembly, then k in-register substeps on the shrinking window
        (XLA path; any k >= 1, including k > rho)."""
        self.layout.materialize_halo(k)  # host tables outside the trace
        return self._step_k(state, k)

    @partial(jax.jit, static_argnums=(0, 2))
    def _step_k(self, state: Array, k: int) -> Array:
        wl = self.workload
        pad = partial(self.layout.pad_with_halo_k, k=k)
        if wl.n_channels > 1:
            pad = jax.vmap(pad)  # over the leading channel axis
        padded = pad(state)  # (C?, nb, rho+2k, rho+2k)
        hmask = self.layout.dev_halo_mask(k)  # (nb, rho+2k, rho+2k)
        return wl.tile_rule_k(padded, hmask, k).astype(state.dtype)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.workload.n_channels * self.layout.memory_bytes(dtype_size)


@dataclasses.dataclass(frozen=True)
class SqueezePallasEngine(_FusedStepping):
    """Block-level Squeeze with the step fused into a Pallas kernel.

    ``variant`` selects the halo strategy of kernels/squeeze_stencil.py:
    'blocks' (v1, paper-shaped), 'strips' (v2, pre-gathered strip halos),
    'fused' (v3, in-kernel strip reads) or 'mxu' (v5, stencil-as-matmul on
    lane-packed macro-tiles). State layout and conversions are identical
    to ``SqueezeBlockEngine``. ``run`` steps through the temporal-fusion
    kernel (v4 ``stencil_step_fused_k``, or the v5 k-substep variant for
    'mxu') whenever the effective fusion depth is > 1; ``fusion_k``
    overrides the heuristic but must stay <= rho (the kernels'
    one-block-ring limit).

    The 'mxu' variant additionally supports *native batching*
    (``step_batched`` / ``step_k_batched``): B independent simulations
    advance through ONE kernel dispatch over a (B, n_macro_tiles) grid
    instead of a vmap of per-simulation pallas_calls — the
    ``BatchedRunner`` routes through it when ``supports_native_batch``.
    """

    layout: BlockLayout
    workload: StencilWorkload = LIFE
    variant: str = "strips"
    fusion_k: Optional[int] = None
    #: MXU macro-tile packing override (blocks per macro-tile; 'mxu'
    #: variant only, None = lane heuristic)
    macro_p: Optional[int] = None

    def __post_init__(self):
        if self.variant not in ("blocks", "strips", "fused", "mxu"):
            raise ValueError(f"unknown Pallas variant {self.variant!r}")
        check_workload_ndim(self.workload, 2)
        if self.fusion_k is not None and not (
                1 <= self.fusion_k <= self.layout.rho):
            raise ValueError(
                f"pallas fusion_k must be in [1, rho={self.layout.rho}], "
                f"got {self.fusion_k}")
        if self.macro_p is not None and self.variant != "mxu":
            raise ValueError(
                "macro_p only applies to the 'mxu' variant, got "
                f"variant={self.variant!r}")
        self.layout.materialize()

    @property
    def frac(self) -> NBBFractal:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        return SqueezeBlockEngine(self.layout,
                                  self.workload).init_random(seed)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    def step(self, state: Array) -> Array:
        from repro.kernels import ops
        if self.variant == "mxu":
            return ops.stencil_step_mxu(self.layout, state, self.workload,
                                        p=self.macro_p)
        fn = {"blocks": ops.stencil_step_blocks,
              "strips": ops.stencil_step_strips,
              "fused": ops.stencil_step_fused}[self.variant]
        return fn(self.layout, state, self.workload)

    # ------------------------------------------------------- native batching
    @property
    def supports_native_batch(self) -> bool:
        """True when B simulations step through one (B, n_macro) kernel
        grid rather than a vmap of per-simulation pallas_calls."""
        return self.variant == "mxu"

    def step_batched(self, states: Array) -> Array:
        """One step of B independent simulations in one kernel dispatch;
        states (B, C?, n_blocks, rho, rho) -> same ('mxu' variant only)."""
        return self.step_k_batched(states, 1)

    def step_k_batched(self, states: Array, k: int) -> Array:
        """``k`` exact steps of B independent simulations in one kernel
        dispatch over the (B, n_macro_tiles) grid ('mxu' variant only)."""
        if not self.supports_native_batch:
            raise ValueError(
                f"native batching needs variant='mxu', got {self.variant!r} "
                "(use jax.vmap over step/step_k instead)")
        from repro.kernels import ops
        return ops.stencil_step_mxu_batched(self.layout, states,
                                            self.workload, k=k,
                                            p=self.macro_p)

    # ------------------------------------------------------ temporal fusion
    def _materialize_fused(self, k: int) -> None:
        # only what the fused kernels read — not the XLA path's per-block
        # halo_mask/offset_table (O(n_blocks (rho+2k)^2) host build)
        _ = self.layout.dev_existence_table, self.layout.dev_window_mask(k)
        if self.variant == "mxu":
            # resolve the packing override to its concrete P — the same
            # memo key the kernel wrapper uses
            p = self.layout.macro_tiles(k, p=self.macro_p)[0]
            _ = self.layout.dev_existence_padded(k, p=p)

    def step_k(self, state: Array, k: int) -> Array:
        """Advance ``k`` exact steps in one fused kernel launch (k <= rho):
        the v5 macro-tile kernel for 'mxu', the v4 kernel otherwise."""
        from repro.kernels import ops
        if self.variant == "mxu":
            return ops.stencil_step_mxu_k(self.layout, state, self.workload,
                                          k=k, p=self.macro_p)
        return ops.stencil_step_fused_k(self.layout, state, self.workload,
                                        k=k)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.workload.n_channels * self.layout.memory_bytes(dtype_size)


#: distributed engine kinds -> shard-local compute backend
_DIST_KINDS = {"dist-block": "jnp", "dist-fused": "fused",
               "dist-mxu": "mxu"}

#: sentinel: "normalize against the active default tuning table"
_UNSET_TABLE = object()


def make_engine(kind, frac=None, r: Optional[int] = None, m: int = 0,
                workload: Optional[StencilWorkload] = None,
                fusion_k: Optional[int] = None, mesh=None,
                axis: str = "data", exchange: str = "auto",
                macro_p: Optional[int] = None, table=_UNSET_TABLE):
    """Engine factory. Primary form: ``make_engine(spec)`` with an
    :class:`repro.tuning.spec.EngineSpec` — the canonical configuration
    identity. The spec is ``normalize()``d first (alias rewrite, knob
    zeroing, and tunable-knob resolution: explicit argument > tuning-
    table hit > static heuristic — see DESIGN.md Section 11), so the
    engine's kind/fusion depth/macro-tile packing/exchange mode are the
    resolved values. Registry fractals/workloads and the mesh are
    reconstructed from the spec; pass ``frac=``/``workload=``/``mesh=``
    objects to supply custom ones (they must match the spec's
    identity). ``table=None`` pins normalization to the static
    heuristics (no tuning-table consult).

    Legacy form: ``make_engine(kind, frac, r, m=..., ...)`` with a kind
    string and a fractal object still works — it constructs the spec
    internally and emits a ``DeprecationWarning``.

    kind: 'bb' | 'lambda' | 'cell' | 'block' | 'pallas-blocks' |
          'pallas-strips' | 'pallas-fused' | 'pallas-mxu' |
          'dist-block' | 'dist-fused' | 'dist-mxu' |
          'bb3d' | 'cell3d' | 'block3d' | 'pallas-3d' | 'pallas-3d-mxu'
          ('pallas' = 'pallas-strips', 'pallas-3d' = the fused 3D
          kernel).
    ``m`` (block level, rho = s**m) and ``fusion_k`` (temporal-fusion
    depth for ``run``; None = table-then-heuristic) only apply to the
    block/pallas/dist kinds — the expanded-space and cell engines have
    no block tiles to fuse over. ``macro_p`` overrides the MXU
    macro-tile packing (lane-packed blocks per macro-tile; MXU kinds
    only, None = table-then-lane-heuristic). 'pallas-mxu' is the v5
    stencil-as-matmul kernel: the Moore aggregation runs as rank-1
    banded MXU contractions on lane-packed multi-block macro-tiles with
    a *native* batch grid (``step_batched``) — see DESIGN.md Section
    2.2.

    The 'dist-*' kinds are the multi-device engine of
    ``core/distributed.py``: the compact block domain sharded over
    ``mesh``'s ``axis`` (default: all devices on one "data" axis) with a
    k-fused strip halo exchange (one exchange per k steps; ``exchange``
    picks 'p2p' neighbor-only ppermute with interior/boundary compute
    overlap, the 'gather' all-gather fallback, or 'auto' = p2p whenever
    the strip decomposition is valid) and the named shard-local compute
    backend — 'dist-block' is the XLA window path, 'dist-fused' the v4
    fused-depth kernel, 'dist-mxu' the v5 MXU macro-tile kernel. See
    DESIGN.md Sections 4 and 10.

    The '*3d' kinds take an ``NBBFractal3D`` and a 3D single-channel
    workload (LIFE3D, HEAT3D): 'bb3d'/'cell3d' are the expanded and
    per-cell compact engines, 'block3d' the 3D block engine over
    ``BlockLayout3D`` (XLA path, any fusion depth), 'pallas-3d' the
    fused depth-k 3D kernel and 'pallas-3d-mxu' the z-slab MXU
    stencil-as-matmul kernel (both k <= rho). See DESIGN.md Section 5.

    With telemetry enabled, every build counts ``engine.builds`` and
    sets the ``engine.memory_bytes`` gauge (compact-state footprint at
    the workload dtype), both labeled by the *normalized* kind (so
    'pallas' callers and runner users agree on the label).
    """
    from repro.tuning.spec import EngineSpec
    if isinstance(kind, EngineSpec):
        spec = kind
    else:
        warnings.warn(
            "make_engine(kind, frac, r, ...) with a kind string is "
            "deprecated; build an EngineSpec and call make_engine(spec) "
            "(see DESIGN.md Section 11)",
            DeprecationWarning, stacklevel=2)
        if frac is None or r is None:
            raise TypeError(
                "legacy make_engine(kind, frac, r, ...) needs a fractal "
                "object and r")
        spec = EngineSpec.from_args(kind, frac, r, m, workload, fusion_k,
                                    macro_p, mesh, axis, exchange)
    norm = spec.normalize() if table is _UNSET_TABLE \
        else spec.normalize(table=table)
    frac_obj = frac if frac is not None else norm.build_frac()
    workload_obj = workload if workload is not None \
        else norm.build_workload()
    mesh_obj = mesh if mesh is not None else norm.build_mesh()
    engine = _make_engine(norm, frac_obj, workload_obj, mesh_obj)
    if obs.enabled():
        obs.inc("engine.builds", kind=norm.kind)
        if hasattr(engine, "memory_bytes"):
            try:
                itemsize = jnp.dtype(workload_obj.dtype).itemsize
                obs.set_gauge("engine.memory_bytes",
                              engine.memory_bytes(dtype_size=itemsize),
                              kind=norm.kind)
            except TypeError:  # engines with a fixed internal dtype
                obs.set_gauge("engine.memory_bytes",
                              engine.memory_bytes(), kind=norm.kind)
    return engine


def _make_engine(spec, frac, workload, mesh):
    """Dispatch a *normalized* EngineSpec plus the resolved fractal/
    workload/mesh objects to the engine classes."""
    from repro.core.baselines import LambdaEngine
    kind, r, m = spec.kind, spec.r, spec.m
    fusion_k, macro_p = spec.fusion_k, spec.macro_p
    if kind in ("bb3d", "cell3d", "block3d") or kind.startswith("pallas-3d"):
        from repro.core import stencil3d as s3
        from repro.core.compact3d import BlockLayout3D
        if kind == "bb3d":
            return s3.BB3DEngine(frac, r, workload)
        if kind == "cell3d":
            return s3.Squeeze3DEngine(frac, r, workload)
        if kind == "block3d":
            return s3.Squeeze3DBlockEngine(BlockLayout3D(frac, r, m),
                                           workload, fusion_k=fusion_k)
        variant = kind[len("pallas-3d"):].lstrip("-") or "fused"
        return s3.Squeeze3DPallasEngine(BlockLayout3D(frac, r, m),
                                        workload, variant=variant,
                                        fusion_k=fusion_k,
                                        macro_p=macro_p)
    if kind == "bb":
        return BBEngine(frac, r, workload)
    if kind == "lambda":
        return LambdaEngine(frac, r, workload)
    if kind == "cell":
        return SqueezeCellEngine(frac, r, workload)
    if kind == "block":
        return SqueezeBlockEngine(BlockLayout(frac, r, m), workload,
                                  fusion_k=fusion_k)
    if kind in _DIST_KINDS:
        from repro.core.distributed import make_distributed_engine
        return make_distributed_engine(
            BlockLayout(frac, r, m), mesh=mesh, axis=spec.axis,
            workload=workload, compute=_DIST_KINDS[kind],
            fusion_k=fusion_k, exchange=spec.exchange,
            macro_p=macro_p)
    if kind.startswith("pallas-"):
        return SqueezePallasEngine(BlockLayout(frac, r, m), workload,
                                   variant=kind[len("pallas-"):],
                                   fusion_k=fusion_k, macro_p=macro_p)
    raise ValueError(f"unknown engine kind {kind!r}")
