"""Squeeze engines: stencil simulation entirely in compact space (paper
Sections 3.2-3.5).

  * ``SqueezeCellEngine``  — the paper-faithful per-cell scheme: one lambda
    per cell, one (fused) nu + membership test per neighbor, gathers from
    the compact state. Memory = k^r cells.
  * ``SqueezeBlockEngine`` — block-level Squeeze (Section 3.5): maps run at
    block granularity; each block is a rho x rho expanded micro-fractal.
    The static block-neighbor table (built once with the maps; see
    DESIGN.md Section 2 for the TPU-native restructure) turns the step
    into halo-gather + dense in-tile stencil.

Both produce states convertible to the same expanded embedding as the
baselines (tests assert step-for-step equivalence).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import maps
from repro.core.baselines import BBEngine, life_rule, _moore_counts
from repro.core.compact import (BlockLayout, MOORE_DIRS, compact_meshgrid,
                                compact_to_expanded, expanded_to_compact)
from repro.core.fractals import NBBFractal

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SqueezeCellEngine:
    """Paper-faithful compact-space engine (thread-level Squeeze)."""

    frac: NBBFractal
    r: int

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r).init_random(seed)
        return expanded_to_compact(self.frac, self.r, expanded)

    def to_expanded(self, state: Array) -> Array:
        return compact_to_expanded(self.frac, self.r, state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r = self.frac, self.r
        cx, cy = compact_meshgrid(frac, r)
        # 1 lambda per cell: where am I in (virtual) expanded space?
        ex, ey = maps.lambda_map(frac, r, cx, cy)
        count = jnp.zeros(state.shape, jnp.int32)
        for dx, dy in MOORE_DIRS:
            # 1 nu (+ membership, fused — same digit pass) per neighbor
            nx, ny, valid = maps.nu_with_membership(frac, r, ex + dx, ey + dy)
            val = state[ny, nx].astype(jnp.int32)
            count = count + jnp.where(valid, val, 0)
        return life_rule(state, count)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        rows, cols = self.frac.compact_dims(self.r)
        return rows * cols * dtype_size


@dataclasses.dataclass(frozen=True)
class SqueezeBlockEngine:
    """Block-level Squeeze (paper Section 3.5) with a static neighbor table."""

    layout: BlockLayout

    def __post_init__(self):
        self.layout.materialize()

    @property
    def frac(self) -> NBBFractal:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        expanded = BBEngine(self.frac, self.r).init_random(seed)
        return self.layout.from_expanded(expanded)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        padded = self.layout.pad_with_halo(state)  # (nb, rho+2, rho+2)
        counts = jax.vmap(_moore_counts)(padded)
        nxt = life_rule(state, counts)
        mask = jnp.asarray(self.layout.micro_mask)[None]
        return nxt * mask

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.layout.memory_bytes(dtype_size)


def make_engine(kind: str, frac: NBBFractal, r: int, m: int = 0):
    """Engine factory: kind in {'bb', 'lambda', 'cell', 'block'}."""
    from repro.core.baselines import LambdaEngine
    if kind == "bb":
        return BBEngine(frac, r)
    if kind == "lambda":
        return LambdaEngine(frac, r)
    if kind == "cell":
        return SqueezeCellEngine(frac, r)
    if kind == "block":
        return SqueezeBlockEngine(BlockLayout(frac, r, m))
    raise ValueError(f"unknown engine kind {kind!r}")
