"""The two Squeeze space maps: lambda(w) (compact -> expanded) and nu(w)
(expanded -> compact), paper Sections 3.3 and 3.4.

Conventions (paper Section 3.4): origin (0,0) at the upper-left of both the
expanded domain D^2 (side n = s**r) and the compact domain D_c^2
(k^floor(r/2) rows x k^ceil(r/2) cols); x grows right, y grows down.

Digit structure. A compact coordinate interleaves base-k digits across levels:
odd levels mu = 1,3,5,... are the base-k digits of x (digit (mu-1)//2), even
levels mu = 2,4,... the digits of y. An expanded coordinate's level-mu replica
slot is its base-s digit mu-1 per axis (paper Eq. 6; the printed denominator
``s^mu`` is a typo for ``s^(mu-1)``, otherwise theta would always be 0).

NOTE on the paper's Eqs. 8-10: as printed, f_x selects *even* levels, which
contradicts Eq. 5's beta_mu (odd levels read w_x) and Section 3.1 ("at mu=1 the
compact space is scaled up in x"). We implement the self-consistent version —
odd levels accumulate into x, even into y — which is the unique choice making
nu the inverse of lambda; the property tests enforce ``nu . lambda = id``.

Three implementations per map:
  * ``*_scalar``  — pure-python ints, the executable spec (hypothesis oracle);
  * ``lambda_map`` / ``nu_map`` — vectorised jnp (per-level unrolled loop);
  * ``*_matmul``  — the tensor-core/MXU encoding (paper Section 3.6, Eqs.
    15-16): replica codes matrix @ per-level weight matrix, fp32 accumulate.
    Exact while every product < 2**24 (holds for all supported n <= 2**20).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.fractals import NBBFractal

Array = jnp.ndarray


# ======================================================================
# scalar references (executable spec)
# ======================================================================
def lambda_map_scalar(frac: NBBFractal, r: int, cx: int, cy: int
                      ) -> Tuple[int, int]:
    """Paper Eqs. 2-5: compact (cx, cy) -> expanded (ex, ey)."""
    ex = ey = 0
    for mu in range(1, r + 1):
        w = cx if (mu % 2 == 1) else cy
        beta = (w // frac.k ** ((mu - 1) // 2)) % frac.k
        tx, ty = frac.positions[beta]
        ex += tx * frac.s ** (mu - 1)
        ey += ty * frac.s ** (mu - 1)
    return ex, ey


def nu_map_scalar(frac: NBBFractal, r: int, ex: int, ey: int
                  ) -> Tuple[int, int]:
    """Paper Eqs. 6-13: expanded (ex, ey) -> compact (cx, cy).

    Only meaningful when (ex, ey) is a fractal cell (see is_fractal_scalar);
    for holes the H_nu lookup is -1 and the result is unspecified (clamped
    to code 0 here, matching the vectorised path).
    """
    cx = cy = 0
    for mu in range(1, r + 1):
        tx = (ex // frac.s ** (mu - 1)) % frac.s
        ty = (ey // frac.s ** (mu - 1)) % frac.s
        code = int(frac.h_nu[ty, tx])
        code = max(code, 0)
        delta = frac.k ** ((mu - 1) // 2)
        if mu % 2 == 1:
            cx += code * delta
        else:
            cy += code * delta
    return cx, cy


def is_fractal_scalar(frac: NBBFractal, r: int, ex: int, ey: int) -> bool:
    if not (0 <= ex < frac.s ** r and 0 <= ey < frac.s ** r):
        return False
    for mu in range(1, r + 1):
        tx = (ex // frac.s ** (mu - 1)) % frac.s
        ty = (ey // frac.s ** (mu - 1)) % frac.s
        if frac.h_nu[ty, tx] < 0:
            return False
    return True


# ======================================================================
# vectorised jnp maps
# ======================================================================
def lambda_map(frac: NBBFractal, r: int, cx: Array, cy: Array
               ) -> Tuple[Array, Array]:
    """Vectorised lambda(w). cx/cy: int32 arrays of any (matching) shape."""
    h = jnp.asarray(frac.h_lambda)  # (k, 2)
    cx = cx.astype(jnp.int32)
    cy = cy.astype(jnp.int32)
    ex = jnp.zeros_like(cx)
    ey = jnp.zeros_like(cy)
    for mu in range(1, r + 1):
        w = cx if (mu % 2 == 1) else cy
        beta = (w // (frac.k ** ((mu - 1) // 2))) % frac.k
        tau = h[beta]  # (..., 2)
        scale = frac.s ** (mu - 1)
        ex = ex + tau[..., 0] * scale
        ey = ey + tau[..., 1] * scale
    return ex, ey


def _nu_codes(frac: NBBFractal, r: int, ex: Array, ey: Array) -> Array:
    """Per-level replica codes H_nu[theta_mu], shape (..., r) int32.

    Holes produce -1 (useful for membership tests); nu_map clamps to 0.
    """
    hn = jnp.asarray(frac.h_nu)  # (s, s) indexed [y, x]
    ex = ex.astype(jnp.int32)
    ey = ey.astype(jnp.int32)
    codes = []
    for mu in range(1, r + 1):
        scale = frac.s ** (mu - 1)
        tx = (ex // scale) % frac.s
        ty = (ey // scale) % frac.s
        codes.append(hn[ty, tx])
    return jnp.stack(codes, axis=-1)


def nu_map(frac: NBBFractal, r: int, ex: Array, ey: Array
           ) -> Tuple[Array, Array]:
    """Vectorised nu(w). ex/ey: int32 arrays of any (matching) shape."""
    codes = jnp.maximum(_nu_codes(frac, r, ex, ey), 0)  # (..., r)
    wx, wy = nu_weights(frac, r)
    cx = jnp.sum(codes * wx.astype(jnp.int32), axis=-1)
    cy = jnp.sum(codes * wy.astype(jnp.int32), axis=-1)
    return cx.astype(jnp.int32), cy.astype(jnp.int32)


def is_fractal(frac: NBBFractal, r: int, ex: Array, ey: Array) -> Array:
    """Vectorised fractal-membership test for expanded coordinates."""
    n = frac.s ** r
    in_bounds = (ex >= 0) & (ex < n) & (ey >= 0) & (ey < n)
    exc = jnp.clip(ex, 0, n - 1)
    eyc = jnp.clip(ey, 0, n - 1)
    codes = _nu_codes(frac, r, exc, eyc)
    return in_bounds & jnp.all(codes >= 0, axis=-1)


def nu_with_membership(frac: NBBFractal, r: int, ex: Array, ey: Array
                       ) -> Tuple[Array, Array, Array]:
    """Fused nu(w) + membership: one digit pass serves both (the stencil
    inner loop needs both per neighbor, so computing codes twice would
    double the map cost). Returns (cx, cy, valid)."""
    n = frac.s ** r
    in_bounds = (ex >= 0) & (ex < n) & (ey >= 0) & (ey < n)
    exc = jnp.clip(ex, 0, n - 1)
    eyc = jnp.clip(ey, 0, n - 1)
    codes = _nu_codes(frac, r, exc, eyc)  # (..., r)
    valid = in_bounds & jnp.all(codes >= 0, axis=-1)
    codes = jnp.maximum(codes, 0)
    wx, wy = nu_weights(frac, r)
    cx = jnp.sum(codes * wx.astype(jnp.int32), axis=-1)
    cy = jnp.sum(codes * wy.astype(jnp.int32), axis=-1)
    return cx.astype(jnp.int32), cy.astype(jnp.int32), valid


# ======================================================================
# matmul (tensor-core / MXU) encodings — paper Section 3.6
# ======================================================================
def nu_weights(frac: NBBFractal, r: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-level nu weights (Delta^nu_mu * f(mu)), as two (r,) fp32 vectors.

    Row mu-1 holds k^floor((mu-1)/2), routed to x for odd mu, y for even mu
    (the self-consistent form of paper Eqs. 7-10; see module docstring).
    """
    wx = np.zeros((r,), np.float32)
    wy = np.zeros((r,), np.float32)
    for mu in range(1, r + 1):
        delta = float(frac.k ** ((mu - 1) // 2))
        if mu % 2 == 1:
            wx[mu - 1] = delta
        else:
            wy[mu - 1] = delta
    return wx, wy


def nu_weight_matrix(frac: NBBFractal, r: int) -> np.ndarray:
    """(r, 2) fp32 — the ``A`` operand of the paper's MMA encoding (Eq. 15),
    transposed to the (codes @ W) orientation used on the MXU."""
    wx, wy = nu_weights(frac, r)
    return np.stack([wx, wy], axis=1)


def nu_map_matmul(frac: NBBFractal, r: int, ex: Array, ey: Array
                  ) -> Tuple[Array, Array]:
    """nu(w) as one fp32 matmul: codes (N, r) @ W (r, 2) -> (N, 2).

    This is the paper's tensor-core formulation (Eqs. 15-16) adapted to the
    MXU: one dot maps a whole batch of coordinates. Exact for n <= 2**20.
    """
    codes = jnp.maximum(_nu_codes(frac, r, ex, ey), 0).astype(jnp.float32)
    w = jnp.asarray(nu_weight_matrix(frac, r))  # (r, 2)
    out = codes @ w  # MXU dot, fp32 accumulate
    return (out[..., 0].astype(jnp.int32), out[..., 1].astype(jnp.int32))


def lambda_weight_matrix(frac: NBBFractal, r: int) -> np.ndarray:
    """(2r, 2) fp32 block-diagonal weights for the single-dot lambda form:
    [tau_x codes | tau_y codes] (N, 2r) @ W -> (ex, ey)."""
    w = np.zeros((2 * r, 2), np.float32)
    for mu in range(1, r + 1):
        w[mu - 1, 0] = float(frac.s ** (mu - 1))
        w[r + mu - 1, 1] = float(frac.s ** (mu - 1))
    return w


def lambda_codes(frac: NBBFractal, r: int, cx: Array, cy: Array) -> Array:
    """(..., 2r) fp32: per-level tau_x then tau_y slot offsets of beta_mu."""
    h = jnp.asarray(frac.h_lambda)
    cx = cx.astype(jnp.int32)
    cy = cy.astype(jnp.int32)
    tx, ty = [], []
    for mu in range(1, r + 1):
        w = cx if (mu % 2 == 1) else cy
        beta = (w // (frac.k ** ((mu - 1) // 2))) % frac.k
        tau = h[beta]
        tx.append(tau[..., 0])
        ty.append(tau[..., 1])
    return jnp.stack(tx + ty, axis=-1).astype(jnp.float32)


def lambda_map_matmul(frac: NBBFractal, r: int, cx: Array, cy: Array
                      ) -> Tuple[Array, Array]:
    """lambda(w) as one fp32 matmul (the [7]-style tensor-core encoding)."""
    codes = lambda_codes(frac, r, cx, cy)  # (..., 2r)
    w = jnp.asarray(lambda_weight_matrix(frac, r))  # (2r, 2)
    out = codes @ w
    return (out[..., 0].astype(jnp.int32), out[..., 1].astype(jnp.int32))
