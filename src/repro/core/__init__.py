"""Squeeze core: NBB fractals, the lambda/nu space maps, and the compact
stencil engines (the paper's primary contribution)."""
from repro.core.fractals import (CARPET, CHANDELIER, EMPTY_BOTTLES, REGISTRY,
                                 SIERPINSKI, VICSEK, NBBFractal, get_fractal)
from repro.core.maps import (is_fractal, lambda_map, lambda_map_matmul,
                             nu_map, nu_map_matmul, nu_with_membership)
from repro.core.compact import (BlockLayout, MOORE_DIRS, compact_to_expanded,
                                expanded_to_compact)
from repro.core.compact3d import BlockLayout3D
from repro.core.fractals3d import MENGER, SIERPINSKI3D, NBBFractal3D
from repro.core.stencil import (SqueezeBlockEngine, SqueezeCellEngine,
                                SqueezePallasEngine, make_engine)
from repro.core.baselines import BBEngine, LambdaEngine, life_rule

__all__ = [
    "CARPET", "CHANDELIER", "EMPTY_BOTTLES", "REGISTRY", "SIERPINSKI",
    "VICSEK", "NBBFractal", "get_fractal", "is_fractal", "lambda_map",
    "lambda_map_matmul", "nu_map", "nu_map_matmul", "nu_with_membership",
    "BlockLayout", "MOORE_DIRS", "compact_to_expanded", "expanded_to_compact",
    "BlockLayout3D", "MENGER", "SIERPINSKI3D", "NBBFractal3D",
    "SqueezeBlockEngine", "SqueezeCellEngine", "SqueezePallasEngine",
    "make_engine", "BBEngine", "LambdaEngine", "life_rule",
]
