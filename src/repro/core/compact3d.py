"""Block-level Squeeze in three dimensions: the 2D ``BlockLayout``
machinery (core/compact.py) ported to 3D NBB fractals over the
lambda3/nu3 maps — the geometry half of completing the paper's §5
"extend to 3D" claim at full performance.

With ``rho = s**m`` the 3D fractal is handled as a level-``r_b`` fractal
of blocks (``r_b = r - m``); each block stores a rho^3 *expanded*
micro-fractal cube (identical occupancy ``micro_mask`` in every block,
by self-similarity). Block state is ``(n_blocks, rho, rho, rho)``
indexed ``[b, z, y, x]`` with block id ``(bz * ny + by) * nx + bx`` over
the compact block box ``(nx, ny, nz) = compact_dims(r_b)``. Cross-block
neighbor access goes through static tables built with one lambda3 per
block and one nu3 per (block, offset) — the paper's maps hoisted to
block granularity, exactly as in 2D (DESIGN.md Sections 2 and 5).

Depth-``k`` halo geometry (offset tables exact past holes, periodic
window masks, per-block halo masks, ``pad_with_halo_k``) mirrors the 2D
layout method-for-method so the fused engines and kernels can share one
substep discipline across dimensions.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractals3d as f3
#: 26-direction 3D Moore neighborhood, raster-ordered — defined in the
#: dependency-free workloads layer, re-exported here for the engines.
from repro.workloads.base import MOORE3_DIRS  # noqa: F401

Array = jnp.ndarray


def halo_regions3(rho: int, k: int):
    """The 26 (zs, ys, xs) window slices of the depth-k halo frame, in
    MOORE3_DIRS order. Shared by the fused 3D kernels to gate the
    periodic window mask by per-block neighbor existence."""
    w = rho + 2 * k
    sl = {-1: slice(0, k), 0: slice(k, k + rho), 1: slice(k + rho, w)}
    return tuple((sl[dz], sl[dy], sl[dx]) for (dx, dy, dz) in MOORE3_DIRS)


@dataclasses.dataclass(frozen=True)
class BlockLayout3D:
    """Static geometry of a 3D block-level Squeeze decomposition."""

    frac: f3.NBBFractal3D
    r: int
    m: int  # rho = s**m

    def __post_init__(self):
        if not (0 <= self.m <= self.r):
            raise ValueError(f"need 0 <= m <= r, got m={self.m}, r={self.r}")

    def materialize(self) -> "BlockLayout3D":
        """Build all static geometry eagerly (same contract as the 2D
        layout: engines call this at construction, outside any trace)."""
        _ = self.micro_mask, self.block_coords
        _ = self.block_origin_expanded, self.neighbor_table
        _ = self.dev_micro_mask, self.dev_block_origin_expanded
        _ = self.dev_neighbor_table
        return self

    def materialize_halo(self, k: int) -> "BlockLayout3D":
        """Build the depth-``k`` halo geometry eagerly (fused-k entry
        points call this outside any trace)."""
        self.materialize()
        _ = self.existence_table, self.dev_existence_table
        _ = self.offset_table(k), self.window_mask(k), self.halo_mask(k)
        _ = self.dev_offset_table(k), self.dev_window_mask(k)
        _ = self.dev_halo_mask(k)
        return self

    @property
    def rho(self) -> int:
        return self.frac.s ** self.m

    @property
    def r_b(self) -> int:
        return self.r - self.m

    @property
    def block_dims(self) -> Tuple[int, int, int]:
        """(nx, ny, nz) of the compact block box."""
        return self.frac.compact_dims(self.r_b)

    @property
    def n_blocks(self) -> int:
        return self.frac.volume(self.r_b)

    @property
    def ghost(self) -> int:
        """Sentinel block id used for out-of-fractal neighbors."""
        return self.n_blocks

    @functools.cached_property
    def micro_mask(self) -> np.ndarray:
        """(rho, rho, rho) uint8 occupancy of the level-m micro-fractal,
        indexed [z, y, x]."""
        return self.frac.mask(self.m)

    @functools.cached_property
    def block_coords(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Flat (n_blocks,) compact block coordinates (bx, by, bz),
        id-ordered (z-major raster)."""
        nx, ny, nz = self.block_dims
        bz, by, bx = np.meshgrid(np.arange(nz, dtype=np.int32),
                                 np.arange(ny, dtype=np.int32),
                                 np.arange(nx, dtype=np.int32),
                                 indexing="ij")
        return bx.reshape(-1), by.reshape(-1), bz.reshape(-1)

    @functools.cached_property
    def block_origin_expanded(self) -> np.ndarray:
        """(n_blocks, 3) int32 cell-level expanded origin (x, y, z)."""
        bx, by, bz = self.block_coords
        ex, ey, ez = f3.lambda3_map(self.frac, self.r_b, jnp.asarray(bx),
                                    jnp.asarray(by), jnp.asarray(bz))
        return np.stack([np.asarray(ex), np.asarray(ey),
                         np.asarray(ez)], axis=1) * self.rho

    def _map_offsets_to_table(self, offsets) -> np.ndarray:
        """(n_blocks, len(offsets)) int32 compact block id per offset:
        one lambda3 per block, one nu3 per (block, offset);
        out-of-fractal blocks get the ``ghost`` sentinel."""
        frac, r_b = self.frac, self.r_b
        bx, by, bz = (jnp.asarray(a) for a in self.block_coords)
        ex, ey, ez = f3.lambda3_map(frac, r_b, bx, by, bz)
        nx, ny, _ = self.block_dims
        side = frac.side(r_b) - 1
        table = np.empty((self.n_blocks, len(offsets)), dtype=np.int32)
        for d, (dx, dy, dz) in enumerate(offsets):
            qx, qy, qz = ex + dx, ey + dy, ez + dz
            valid = f3.is_fractal3(frac, r_b, qx, qy, qz)
            cx, cy, cz = f3.nu3_map(frac, r_b,
                                    jnp.clip(qx, 0, side),
                                    jnp.clip(qy, 0, side),
                                    jnp.clip(qz, 0, side))
            ids = jnp.where(valid, (cz * ny + cy) * nx + cx, self.ghost)
            table[:, d] = np.asarray(ids, dtype=np.int32)
        return table

    @functools.cached_property
    def neighbor_table(self) -> np.ndarray:
        """(n_blocks, 26) int32 compact block id per Moore direction."""
        return self._map_offsets_to_table(MOORE3_DIRS)

    @functools.cached_property
    def existence_table(self) -> np.ndarray:
        """(n_blocks, 26) int32 {0,1}: 1 where the Moore neighbor block
        is a real fractal block (scalar-prefetch operand of the fused 3D
        kernels, gating the periodic window mask per substep)."""
        return (self.neighbor_table != self.ghost).astype(np.int32)

    # --------------------------------------------- device-side cached tables
    @staticmethod
    def _to_device(host: np.ndarray) -> Array:
        with jax.ensure_compile_time_eval():
            return jax.device_put(host)

    @functools.cached_property
    def dev_neighbor_table(self) -> Array:
        """Device-side ``neighbor_table`` (one shared upload)."""
        return self._to_device(self.neighbor_table)

    @functools.cached_property
    def dev_micro_mask(self) -> Array:
        """Device-side ``micro_mask`` (one shared upload)."""
        return self._to_device(self.micro_mask)

    @functools.cached_property
    def dev_existence_table(self) -> Array:
        """Device-side ``existence_table`` (one shared upload)."""
        return self._to_device(self.existence_table)

    @functools.cached_property
    def dev_block_origin_expanded(self) -> Array:
        """Device-side ``block_origin_expanded`` (one shared upload)."""
        return self._to_device(self.block_origin_expanded)

    def dev_offset_table(self, k: int) -> Array:
        """Device-side ``offset_table(k)`` (one upload per depth)."""
        return self._memo(("dev_offset_table", self.halo_block_radius(k)),
                          lambda: self._to_device(self.offset_table(k)))

    def dev_window_mask(self, k: int) -> Array:
        """Device-side int32 ``window_mask(k)`` (upload per depth)."""
        return self._memo(
            ("dev_window_mask", k),
            lambda: self._to_device(self.window_mask(k).astype(np.int32)))

    def dev_halo_mask(self, k: int) -> Array:
        """Device-side ``halo_mask(k)`` (one upload per depth)."""
        return self._memo(("dev_halo_mask", k),
                          lambda: self._to_device(self.halo_mask(k)))

    # ------------------------------------------------------- depth-k halos
    def halo_block_radius(self, k: int) -> int:
        """Neighborhood radius in *blocks* covering a depth-``k`` cell
        halo (1 while k <= rho)."""
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        return math.ceil(k / self.rho)

    def halo_offsets(self, k: int) -> Tuple[Tuple[int, int, int], ...]:
        """Block offsets (bdx, bdy, bdz) whose cubes intersect the
        depth-``k`` halo window, raster-ordered; equals ``MOORE3_DIRS``
        when k <= rho."""
        kb = self.halo_block_radius(k)
        return tuple((dx, dy, dz)
                     for dz in range(-kb, kb + 1)
                     for dy in range(-kb, kb + 1)
                     for dx in range(-kb, kb + 1)
                     if (dx, dy, dz) != (0, 0, 0))

    @functools.cached_property
    def _halo_cache(self) -> dict:
        """Per-instance memo for the depth-k tables/masks (not an
        lru_cache on the methods — that would pin every layout and its
        (n_blocks, (rho+2k)^3) masks process-wide; see the 2D layout)."""
        return {}

    def _memo(self, key, build):
        cache = self._halo_cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def offset_table(self, k: int) -> np.ndarray:
        """(n_blocks, len(halo_offsets(k))) int32 compact block id per
        offset, ghost sentinel for out-of-fractal blocks. Every entry is
        one lambda3 + one nu3 directly against the maps — never a
        composition of unit-step tables, so ghosts stay exact past holes
        at every depth (the 2D offset_table argument, in 3D)."""
        return self._memo(("offset_table", self.halo_block_radius(k)),
                          lambda: self._build_offset_table(k))

    def _build_offset_table(self, k: int) -> np.ndarray:
        if self.halo_block_radius(k) == 1:
            return self.neighbor_table  # identical construction + ordering
        return self._map_offsets_to_table(self.halo_offsets(k))

    def _halo_region(self, k: int, bdx: int, bdy: int, bdz: int):
        """Static window/source slices for one block offset: the overlap
        of the neighbor cube at (bdx, bdy, bdz) with the (rho+2k)^3 halo
        window. Returns ((z0, z1, y0, y1, x0, x1) in the window,
        the matching source bounds in the neighbor cube)."""
        rho = self.rho
        w = rho + 2 * k

        def axis(bd):
            o = k + bd * rho
            lo, hi = max(o, 0), min(o + rho, w)
            return lo, hi, lo - o, hi - o

        xz = [axis(bd) for bd in (bdz, bdy, bdx)]
        dst = tuple(v for lo, hi, _, _ in xz for v in (lo, hi))
        src = tuple(v for _, _, lo, hi in xz for v in (lo, hi))
        return dst, src

    def window_mask(self, k: int) -> np.ndarray:
        """(rho+2k,)^3 uint8: periodic extension of ``micro_mask`` over
        the depth-``k`` window (every *existing* neighbor block carries
        exactly ``micro_mask``, by self-similarity)."""
        def build():
            idx = np.arange(-k, self.rho + k) % self.rho
            return self.micro_mask[np.ix_(idx, idx, idx)]
        return self._memo(("window_mask", k), build)

    def halo_mask(self, k: int) -> np.ndarray:
        """(n_blocks, rho+2k, rho+2k, rho+2k) uint8 occupancy of each
        block's depth-``k`` window: the periodic ``window_mask`` with the
        regions of out-of-fractal (ghost) neighbors zeroed per block —
        the k-substep mask discipline's operand, as in 2D."""
        return self._memo(("halo_mask", k), lambda: self._build_halo_mask(k))

    def _build_halo_mask(self, k: int) -> np.ndarray:
        w = self.rho + 2 * k
        table = self.offset_table(k)
        full = np.broadcast_to(self.window_mask(k),
                               (self.n_blocks, w, w, w)).copy()
        for oi, (bdx, bdy, bdz) in enumerate(self.halo_offsets(k)):
            (z0, z1, y0, y1, x0, x1), _ = \
                self._halo_region(k, bdx, bdy, bdz)
            full[table[:, oi] == self.ghost, z0:z1, y0:y1, x0:x1] = 0
        return full

    # -------------------------------------------- macro-tile strip geometry
    def macro_tiles(self, k: int, lanes: int = 128,
                    p: Optional[int] = None) -> Tuple[int, int, int]:
        """Lane-packing geometry of the 3D MXU kernel: ``(P, n_macro,
        nb_pad)`` with ``P`` blocks packed side by side along the minor
        (x/lane) axis of one macro-tile so ``P * (rho+2k)`` fills the
        vector registers — the same math as the 2D ``macro_tiles``,
        applied to z-slab matrices of shape (rho+2k, P*(rho+2k)).
        ``p`` overrides the lane heuristic (autotuner sweep; clamped to
        [1, n_blocks], no rebalance)."""
        return self.macro_tiles_for(self.n_blocks, k, lanes, p)

    def macro_tiles_for(self, nb: int, k: int, lanes: int = 128,
                        p: Optional[int] = None) -> Tuple[int, int, int]:
        """``macro_tiles`` for an arbitrary block count ``nb``."""
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        if p is not None:
            if p < 1:
                raise ValueError(f"macro-tile packing must be >= 1, "
                                 f"got {p}")
            p = min(p, nb)
            n_macro = -(-nb // p)
            return p, n_macro, n_macro * p
        w = self.rho + 2 * k
        p = max(1, min(lanes // w, nb))
        n_macro = -(-nb // p)
        p = -(-nb // n_macro)  # rebalance: same tile count, fewer dead slots
        return p, n_macro, n_macro * p

    def existence_padded(self, k: int,
                         p: Optional[int] = None) -> np.ndarray:
        """(nb_pad, 26) int32 ``existence_table`` zero-padded to the
        macro slot count (padding slots stay ghost-gated to zero).
        ``p`` is the macro-tile packing override."""
        def build():
            _, _, nb_pad = self.macro_tiles(k, p=p)
            pad = np.zeros((nb_pad - self.n_blocks, 26), np.int32)
            return np.concatenate([self.existence_table, pad], axis=0)
        return self._memo(("existence_padded", k, p), build)

    def dev_existence_padded(self, k: int,
                             p: Optional[int] = None) -> Array:
        """Device-side ``existence_padded(k)`` (upload per depth and
        packing)."""
        return self._memo(
            ("dev_existence_padded", k, p),
            lambda: self._to_device(self.existence_padded(k, p)))

    # ------------------------------------------------------------ conversions
    def to_expanded(self, state_b: Array) -> Array:
        """Block state (C?, n_blocks, rho, rho, rho) -> (C?, n, n, n)
        expanded embedding (leading channel axes pass through)."""
        n = self.frac.side(self.r)
        org = self.dev_block_origin_expanded  # (n_blocks, 3)
        rho = self.rho
        iz, iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho),
                                  jnp.arange(rho), indexing="ij")
        ax = org[:, 0, None, None, None] + ix[None]
        ay = org[:, 1, None, None, None] + iy[None]
        az = org[:, 2, None, None, None] + iz[None]
        out = jnp.zeros(state_b.shape[:-4] + (n, n, n), dtype=state_b.dtype)
        return out.at[..., az, ay, ax].set(state_b)

    def from_expanded(self, state_e: Array) -> Array:
        """(C?, n, n, n) expanded embedding -> block state (C?, n_blocks,
        rho, rho, rho)."""
        org = self.dev_block_origin_expanded
        rho = self.rho
        iz, iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho),
                                  jnp.arange(rho), indexing="ij")
        ax = org[:, 0, None, None, None] + ix[None]
        ay = org[:, 1, None, None, None] + iy[None]
        az = org[:, 2, None, None, None] + iz[None]
        mask = self.dev_micro_mask
        return state_e[..., az, ay, ax] * mask.astype(state_e.dtype)

    def pad_with_halo_k(self, state_b: Array, k: int) -> Array:
        """Assemble (n_blocks, (rho+2k)^3) windows with depth-``k``
        halos: for each block offset only the overlap slab of the
        neighbor cube is sliced *before* the gather (HBM traffic stays
        ~surface * k, not offsets * rho^3); ghost ids index an appended
        zero slab, keeping out-of-fractal reads zero at every depth."""
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        rho, nb = self.rho, self.n_blocks
        w = rho + 2 * k
        table = self.dev_offset_table(k)
        out = jnp.zeros((nb, w, w, w), state_b.dtype)
        out = out.at[:, k:k + rho, k:k + rho, k:k + rho].set(state_b)
        for oi, (bdx, bdy, bdz) in enumerate(self.halo_offsets(k)):
            (z0, z1, y0, y1, x0, x1), (sz0, sz1, sy0, sy1, sx0, sx1) = \
                self._halo_region(k, bdx, bdy, bdz)
            strip = state_b[:, sz0:sz1, sy0:sy1, sx0:sx1]
            strip = jnp.concatenate(
                [strip, jnp.zeros((1,) + strip.shape[1:], state_b.dtype)],
                axis=0)
            out = out.at[:, z0:z1, y0:y1, x0:x1].set(
                jnp.take(strip, table[:, oi], axis=0))
        return out

    def memory_bytes(self, dtype_size: int = 1) -> int:
        """Squeeze 3D block-level state bytes."""
        return self.n_blocks * self.rho ** 3 * dtype_size
