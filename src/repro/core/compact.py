"""Compact-domain layout helpers and block-level Squeeze (paper Section 3.5).

Cell-level: the compact state is a dense ``(rows, cols)`` array,
``rows = k^floor(r/2)``, ``cols = k^ceil(r/2)``; entry ``[cy, cx]`` is the
fractal cell whose compact coordinate is ``(cx, cy)``.

Block-level: with ``rho = s**m`` the fractal is handled as a level-``r_b``
fractal of blocks (``r_b = r - m``); each block stores a rho x rho *expanded*
micro-fractal tile (identical occupancy ``micro_mask`` in every block, by
self-similarity). Block state is ``(n_blocks, rho, rho)`` with block id
``by * cols_b + bx``. Cross-block neighbor access goes through a static
block-neighbor table built with one lambda + 8 nu evaluations per block —
the paper's maps hoisted to block granularity (see DESIGN.md Section 2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import maps
from repro.core.fractals import NBBFractal
#: Moore neighborhood directions (dx, dy), y growing downward — defined in
#: the dependency-free workloads layer, re-exported here for the engines.
from repro.workloads.base import MOORE_DIRS  # noqa: F401

Array = jnp.ndarray


def compact_meshgrid(frac: NBBFractal, r: int) -> Tuple[Array, Array]:
    """(cx, cy) int32 arrays of shape (rows, cols) covering D_c^2."""
    rows, cols = frac.compact_dims(r)
    cy, cx = jnp.meshgrid(jnp.arange(rows, dtype=jnp.int32),
                          jnp.arange(cols, dtype=jnp.int32), indexing="ij")
    return cx, cy


def compact_to_expanded(frac: NBBFractal, r: int, state_c: Array) -> Array:
    """Scatter a compact state into its (n, n) expanded embedding (holes 0).

    Trailing two axes are spatial; leading (channel) axes pass through.
    """
    n = frac.side(r)
    cx, cy = compact_meshgrid(frac, r)
    ex, ey = maps.lambda_map(frac, r, cx, cy)
    out = jnp.zeros(state_c.shape[:-2] + (n, n), dtype=state_c.dtype)
    return out.at[..., ey, ex].set(state_c)


def expanded_to_compact(frac: NBBFractal, r: int, state_e: Array) -> Array:
    """Gather an expanded state into compact form (reads only fractal cells)."""
    cx, cy = compact_meshgrid(frac, r)
    ex, ey = maps.lambda_map(frac, r, cx, cy)
    return state_e[..., ey, ex]


# ======================================================================
# block-level Squeeze
# ======================================================================
@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Static geometry of a block-level Squeeze decomposition."""

    frac: NBBFractal
    r: int
    m: int  # rho = s**m

    def __post_init__(self):
        if not (0 <= self.m <= self.r):
            raise ValueError(f"need 0 <= m <= r, got m={self.m}, r={self.r}")

    def materialize(self) -> "BlockLayout":
        """Build all static geometry eagerly. Engines call this at
        construction (outside jit): a lazy first touch inside a traced
        step() would try to np.asarray() tracers. Kept out of
        __post_init__ so analytic uses (memory_bytes etc.) stay O(1)."""
        _ = self.micro_mask, self.block_coords
        _ = self.block_origin_expanded, self.neighbor_table
        return self

    @property
    def rho(self) -> int:
        return self.frac.s ** self.m

    @property
    def r_b(self) -> int:
        return self.r - self.m

    @property
    def block_dims(self) -> Tuple[int, int]:
        """(rows_b, cols_b) of the compact block domain."""
        return self.frac.compact_dims(self.r_b)

    @property
    def n_blocks(self) -> int:
        return self.frac.volume(self.r_b)

    @property
    def ghost(self) -> int:
        """Sentinel block id used for out-of-fractal neighbors."""
        return self.n_blocks

    @functools.cached_property
    def micro_mask(self) -> np.ndarray:
        """(rho, rho) uint8 occupancy of the level-m micro-fractal, [y, x]."""
        return self.frac.mask(self.m)

    @functools.cached_property
    def block_coords(self) -> Tuple[np.ndarray, np.ndarray]:
        """Flat (n_blocks,) compact block coordinates (bx, by), id-ordered."""
        rows_b, cols_b = self.block_dims
        by, bx = np.meshgrid(np.arange(rows_b, dtype=np.int32),
                             np.arange(cols_b, dtype=np.int32), indexing="ij")
        return bx.reshape(-1), by.reshape(-1)

    @functools.cached_property
    def block_origin_expanded(self) -> np.ndarray:
        """(n_blocks, 2) int32 cell-level expanded origin (x, y) per block."""
        bx, by = self.block_coords
        ex, ey = maps.lambda_map(self.frac, self.r_b,
                                 jnp.asarray(bx), jnp.asarray(by))
        return np.stack([np.asarray(ex), np.asarray(ey)], axis=1) * self.rho

    @functools.cached_property
    def neighbor_table(self) -> np.ndarray:
        """(n_blocks, 8) int32 compact block id per Moore direction.

        Built with the paper's maps at block granularity: one lambda per
        block, one nu per (block, direction); out-of-fractal neighbors get
        the ``ghost`` sentinel (a zero block is appended before gathers).
        """
        frac, r_b = self.frac, self.r_b
        bx, by = (jnp.asarray(a) for a in self.block_coords)
        ex, ey = maps.lambda_map(frac, r_b, bx, by)
        _, cols_b = self.block_dims
        table = np.empty((self.n_blocks, 8), dtype=np.int32)
        for d, (dx, dy) in enumerate(MOORE_DIRS):
            nx, ny = ex + dx, ey + dy
            valid = maps.is_fractal(frac, r_b, nx, ny)
            cx, cy = maps.nu_map(frac, r_b,
                                 jnp.clip(nx, 0, frac.side(r_b) - 1),
                                 jnp.clip(ny, 0, frac.side(r_b) - 1))
            ids = jnp.where(valid, cy * cols_b + cx, self.ghost)
            table[:, d] = np.asarray(ids, dtype=np.int32)
        return table

    # ------------------------------------------------------------ conversions
    def to_expanded(self, state_b: Array) -> Array:
        """Block state (C?, n_blocks, rho, rho) -> (C?, n, n) expanded
        embedding (leading channel axes pass through)."""
        n = self.frac.side(self.r)
        org = jnp.asarray(self.block_origin_expanded)  # (n_blocks, 2)
        rho = self.rho
        iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho), indexing="ij")
        # absolute cell coords per (block, i, j)
        ax = org[:, 0, None, None] + ix[None]
        ay = org[:, 1, None, None] + iy[None]
        out = jnp.zeros(state_b.shape[:-3] + (n, n), dtype=state_b.dtype)
        return out.at[..., ay, ax].set(state_b)

    def from_expanded(self, state_e: Array) -> Array:
        """(C?, n, n) expanded embedding -> block state (C?, n_blocks,
        rho, rho)."""
        org = jnp.asarray(self.block_origin_expanded)
        rho = self.rho
        iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho), indexing="ij")
        ax = org[:, 0, None, None] + ix[None]
        ay = org[:, 1, None, None] + iy[None]
        mask = jnp.asarray(self.micro_mask)
        return state_e[..., ay, ax] * mask.astype(state_e.dtype)

    def pad_with_halo(self, state_b: Array) -> Array:
        """Assemble (n_blocks, rho+2, rho+2) tiles with Moore halos.

        Gathers only the needed strips (edge rows/cols, corner cells) from
        each neighbor block via the static table; ghost neighbors read as 0.
        """
        rho = self.rho
        nb = self.n_blocks
        # one zero ghost block appended: sentinel gathers read zeros.
        padded_src = jnp.concatenate(
            [state_b, jnp.zeros((1, rho, rho), state_b.dtype)], axis=0)
        table = jnp.asarray(self.neighbor_table)  # (nb, 8)

        out = jnp.zeros((nb, rho + 2, rho + 2), state_b.dtype)
        out = out.at[:, 1:-1, 1:-1].set(state_b)

        def nbr(d):  # (nb, rho, rho) neighbor-block contents for direction d
            return jnp.take(padded_src, table[:, d], axis=0)

        # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
        nw, n_, ne, w_, e_, sw, s_, se = (nbr(d) for d in range(8))
        out = out.at[:, 0, 0].set(nw[:, -1, -1])
        out = out.at[:, 0, 1:-1].set(n_[:, -1, :])
        out = out.at[:, 0, -1].set(ne[:, -1, 0])
        out = out.at[:, 1:-1, 0].set(w_[:, :, -1])
        out = out.at[:, 1:-1, -1].set(e_[:, :, 0])
        out = out.at[:, -1, 0].set(sw[:, 0, -1])
        out = out.at[:, -1, 1:-1].set(s_[:, 0, :])
        out = out.at[:, -1, -1].set(se[:, 0, 0])
        return out

    def memory_bytes(self, dtype_size: int = 1) -> int:
        """Squeeze block-level state bytes (paper Table 2's nu column)."""
        return self.n_blocks * self.rho * self.rho * dtype_size
