"""Compact-domain layout helpers and block-level Squeeze (paper Section 3.5).

Cell-level: the compact state is a dense ``(rows, cols)`` array,
``rows = k^floor(r/2)``, ``cols = k^ceil(r/2)``; entry ``[cy, cx]`` is the
fractal cell whose compact coordinate is ``(cx, cy)``.

Block-level: with ``rho = s**m`` the fractal is handled as a level-``r_b``
fractal of blocks (``r_b = r - m``); each block stores a rho x rho *expanded*
micro-fractal tile (identical occupancy ``micro_mask`` in every block, by
self-similarity). Block state is ``(n_blocks, rho, rho)`` with block id
``by * cols_b + bx``. Cross-block neighbor access goes through a static
block-neighbor table built with one lambda + 8 nu evaluations per block —
the paper's maps hoisted to block granularity (see DESIGN.md Section 2).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maps
from repro.core.fractals import NBBFractal
#: Moore neighborhood directions (dx, dy), y growing downward — defined in
#: the dependency-free workloads layer, re-exported here for the engines.
from repro.workloads.base import MOORE_DIRS  # noqa: F401

Array = jnp.ndarray


def halo_regions(rho: int, k: int):
    """The 8 (ys, xs) window slices of the depth-k halo frame, in
    MOORE_DIRS order (NW, N, NE, W, E, SW, S, SE). Shared by the fused
    kernels and the distributed engine to gate the periodic window mask
    by per-block neighbor existence."""
    w = rho + 2 * k
    lo, mid, hi = slice(0, k), slice(k, k + rho), slice(k + rho, w)
    return ((lo, lo), (lo, mid), (lo, hi), (mid, lo), (mid, hi),
            (hi, lo), (hi, mid), (hi, hi))


def compact_meshgrid(frac: NBBFractal, r: int) -> Tuple[Array, Array]:
    """(cx, cy) int32 arrays of shape (rows, cols) covering D_c^2."""
    rows, cols = frac.compact_dims(r)
    cy, cx = jnp.meshgrid(jnp.arange(rows, dtype=jnp.int32),
                          jnp.arange(cols, dtype=jnp.int32), indexing="ij")
    return cx, cy


def compact_to_expanded(frac: NBBFractal, r: int, state_c: Array) -> Array:
    """Scatter a compact state into its (n, n) expanded embedding (holes 0).

    Trailing two axes are spatial; leading (channel) axes pass through.
    """
    n = frac.side(r)
    cx, cy = compact_meshgrid(frac, r)
    ex, ey = maps.lambda_map(frac, r, cx, cy)
    out = jnp.zeros(state_c.shape[:-2] + (n, n), dtype=state_c.dtype)
    return out.at[..., ey, ex].set(state_c)


def expanded_to_compact(frac: NBBFractal, r: int, state_e: Array) -> Array:
    """Gather an expanded state into compact form (fractal cells only)."""
    cx, cy = compact_meshgrid(frac, r)
    ex, ey = maps.lambda_map(frac, r, cx, cy)
    return state_e[..., ey, ex]


# ======================================================================
# block-level Squeeze
# ======================================================================
@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Static geometry of a block-level Squeeze decomposition."""

    frac: NBBFractal
    r: int
    m: int  # rho = s**m

    def __post_init__(self):
        if not (0 <= self.m <= self.r):
            raise ValueError(f"need 0 <= m <= r, got m={self.m}, r={self.r}")

    def materialize(self) -> "BlockLayout":
        """Build all static geometry eagerly. Engines call this at
        construction (outside jit): a lazy first touch inside a traced
        step() would try to np.asarray() tracers. Kept out of
        __post_init__ so analytic uses (memory_bytes etc.) stay O(1)."""
        _ = self.micro_mask, self.block_coords
        _ = self.block_origin_expanded, self.neighbor_table
        _ = self.dev_micro_mask, self.dev_block_origin_expanded
        _ = self.dev_neighbor_table
        return self

    def materialize_halo(self, k: int) -> "BlockLayout":
        """Build the depth-``k`` halo geometry eagerly (same contract as
        ``materialize``: fused-k entry points call this outside any trace)."""
        self.materialize()
        _ = self.existence_table, self.dev_existence_table
        _ = self.offset_table(k), self.window_mask(k), self.halo_mask(k)
        _ = self.dev_offset_table(k), self.dev_window_mask(k)
        _ = self.dev_halo_mask(k)
        return self

    @property
    def rho(self) -> int:
        return self.frac.s ** self.m

    @property
    def r_b(self) -> int:
        return self.r - self.m

    @property
    def block_dims(self) -> Tuple[int, int]:
        """(rows_b, cols_b) of the compact block domain."""
        return self.frac.compact_dims(self.r_b)

    @property
    def n_blocks(self) -> int:
        return self.frac.volume(self.r_b)

    @property
    def ghost(self) -> int:
        """Sentinel block id used for out-of-fractal neighbors."""
        return self.n_blocks

    @functools.cached_property
    def micro_mask(self) -> np.ndarray:
        """(rho, rho) uint8 occupancy of the level-m micro-fractal, [y, x]."""
        return self.frac.mask(self.m)

    @functools.cached_property
    def block_coords(self) -> Tuple[np.ndarray, np.ndarray]:
        """Flat (n_blocks,) compact block coordinates (bx, by), id-ordered."""
        rows_b, cols_b = self.block_dims
        by, bx = np.meshgrid(np.arange(rows_b, dtype=np.int32),
                             np.arange(cols_b, dtype=np.int32), indexing="ij")
        return bx.reshape(-1), by.reshape(-1)

    @functools.cached_property
    def block_origin_expanded(self) -> np.ndarray:
        """(n_blocks, 2) int32 cell-level expanded origin (x, y) per block."""
        bx, by = self.block_coords
        ex, ey = maps.lambda_map(self.frac, self.r_b,
                                 jnp.asarray(bx), jnp.asarray(by))
        return np.stack([np.asarray(ex), np.asarray(ey)], axis=1) * self.rho

    def _map_offsets_to_table(self, offsets) -> np.ndarray:
        """(n_blocks, len(offsets)) int32 compact block id per block offset,
        built with the paper's maps at block granularity: one lambda per
        block, one nu per (block, offset); out-of-fractal blocks get the
        ``ghost`` sentinel (a zero block is appended before gathers)."""
        frac, r_b = self.frac, self.r_b
        bx, by = (jnp.asarray(a) for a in self.block_coords)
        ex, ey = maps.lambda_map(frac, r_b, bx, by)
        _, cols_b = self.block_dims
        table = np.empty((self.n_blocks, len(offsets)), dtype=np.int32)
        for d, (dx, dy) in enumerate(offsets):
            nx, ny = ex + dx, ey + dy
            valid = maps.is_fractal(frac, r_b, nx, ny)
            cx, cy = maps.nu_map(frac, r_b,
                                 jnp.clip(nx, 0, frac.side(r_b) - 1),
                                 jnp.clip(ny, 0, frac.side(r_b) - 1))
            ids = jnp.where(valid, cy * cols_b + cx, self.ghost)
            table[:, d] = np.asarray(ids, dtype=np.int32)
        return table

    @functools.cached_property
    def neighbor_table(self) -> np.ndarray:
        """(n_blocks, 8) int32 compact block id per Moore direction."""
        return self._map_offsets_to_table(MOORE_DIRS)

    @functools.cached_property
    def existence_table(self) -> np.ndarray:
        """(n_blocks, 8) int32 {0,1}: 1 where the Moore neighbor block is a
        real fractal block, 0 where ``neighbor_table`` holds the ghost
        sentinel. Scalar-prefetch operand of the fused-k kernel (gates the
        periodic window mask so ghost halo regions stay zero across
        substeps)."""
        return (self.neighbor_table != self.ghost).astype(np.int32)

    # --------------------------------------------- device-side cached tables
    # One upload per layout, shared by every kernel variant and every trace:
    # jnp.asarray inside each jitted entry point would re-stage the host
    # table per entry point per trace. Cached in __dict__, so dataclass
    # hashing/equality (fields only) are untouched, and the buffers die
    # with the layout (the runner's LRU can still evict). Builds run under
    # ensure_compile_time_eval so a lazy first touch inside an outer jit
    # trace still caches a *concrete* device array, never a tracer.
    @staticmethod
    def _to_device(host: np.ndarray) -> Array:
        with jax.ensure_compile_time_eval():
            return jax.device_put(host)

    @functools.cached_property
    def dev_neighbor_table(self) -> Array:
        """Device-side ``neighbor_table`` (one shared upload)."""
        return self._to_device(self.neighbor_table)

    @functools.cached_property
    def dev_micro_mask(self) -> Array:
        """Device-side ``micro_mask`` (one shared upload)."""
        return self._to_device(self.micro_mask)

    @functools.cached_property
    def dev_existence_table(self) -> Array:
        """Device-side ``existence_table`` (one shared upload)."""
        return self._to_device(self.existence_table)

    @functools.cached_property
    def dev_block_origin_expanded(self) -> Array:
        """Device-side ``block_origin_expanded`` (one shared upload)."""
        return self._to_device(self.block_origin_expanded)

    def dev_offset_table(self, k: int) -> Array:
        """Device-side ``offset_table(k)`` (one shared upload per depth)."""
        return self._memo(("dev_offset_table", self.halo_block_radius(k)),
                          lambda: self._to_device(self.offset_table(k)))

    def dev_window_mask(self, k: int) -> Array:
        """Device-side int32 ``window_mask(k)`` (shared upload per depth)."""
        return self._memo(
            ("dev_window_mask", k),
            lambda: self._to_device(self.window_mask(k).astype(np.int32)))

    def dev_halo_mask(self, k: int) -> Array:
        """Device-side ``halo_mask(k)`` (one shared upload per depth)."""
        return self._memo(("dev_halo_mask", k),
                          lambda: self._to_device(self.halo_mask(k)))

    # ------------------------------------------------------- depth-k halos
    def halo_block_radius(self, k: int) -> int:
        """Neighborhood radius in *blocks* covering a depth-``k`` cell halo
        (1 while k <= rho; grows for deeper fusion than one block ring)."""
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        return math.ceil(k / self.rho)

    def halo_offsets(self, k: int) -> Tuple[Tuple[int, int], ...]:
        """Block offsets (bdx, bdy) whose tiles intersect the depth-``k``
        halo window, raster-ordered; equals ``MOORE_DIRS`` when k <= rho."""
        kb = self.halo_block_radius(k)
        return tuple((dx, dy)
                     for dy in range(-kb, kb + 1)
                     for dx in range(-kb, kb + 1)
                     if (dx, dy) != (0, 0))

    @functools.cached_property
    def _halo_cache(self) -> dict:
        """Per-instance memo for the depth-k tables/masks. Deliberately not
        ``functools.lru_cache`` on the methods: that would key on ``self``
        in a class-level cache and pin every layout (and its (n_blocks,
        rho+2k, rho+2k) halo masks) process-wide forever — defeating the
        runner's LRU eviction. This dict dies with the layout."""
        return {}

    def _memo(self, key, build):
        cache = self._halo_cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def offset_table(self, k: int) -> np.ndarray:
        """(n_blocks, len(halo_offsets(k))) int32 compact block id per
        offset, ghost sentinel for out-of-fractal blocks.

        The generalization of ``neighbor_table`` to arbitrary block
        distance: each entry is one lambda + one nu evaluation *per offset*
        directly against the maps, never a composition of unit-step tables
        — composing through a ghost would mis-drop real blocks that sit
        beyond a hole, so every depth is resolved exactly (out-of-fractal
        reads stay zero at every depth, nothing else does).
        """
        return self._memo(("offset_table", self.halo_block_radius(k)),
                          lambda: self._build_offset_table(k))

    def _build_offset_table(self, k: int) -> np.ndarray:
        if self.halo_block_radius(k) == 1:
            return self.neighbor_table  # identical construction + ordering
        return self._map_offsets_to_table(self.halo_offsets(k))

    def _halo_region(self, k: int, bdx: int, bdy: int):
        """Static window/source slices for one block offset: the overlap of
        the neighbor tile at (bdx, bdy) with the (rho+2k)^2 halo window.
        Returns ((dy0, dy1, dx0, dx1) in the window,
                 (sy0, sy1, sx0, sx1) in the neighbor tile)."""
        rho = self.rho
        w = rho + 2 * k
        x0, y0 = k + bdx * rho, k + bdy * rho
        dx0, dx1 = max(x0, 0), min(x0 + rho, w)
        dy0, dy1 = max(y0, 0), min(y0 + rho, w)
        return (dy0, dy1, dx0, dx1), (dy0 - y0, dy1 - y0, dx0 - x0, dx1 - x0)

    def window_mask(self, k: int) -> np.ndarray:
        """(rho+2k, rho+2k) uint8: periodic extension of ``micro_mask`` over
        the depth-``k`` window. By self-similarity every *existing* neighbor
        block carries exactly ``micro_mask``, so this is the halo occupancy
        before ghost gating."""
        def build():
            idx = np.arange(-k, self.rho + k) % self.rho
            return self.micro_mask[np.ix_(idx, idx)]
        return self._memo(("window_mask", k), build)

    def halo_mask(self, k: int) -> np.ndarray:
        """(n_blocks, rho+2k, rho+2k) uint8 occupancy of each block's
        depth-``k`` window: the periodic ``window_mask`` with the regions of
        out-of-fractal (ghost) neighbor blocks zeroed per block. Fused-k
        substeps multiply by a shrinking crop of this mask so hole *and*
        ghost cells stay zero at every substep (the k-substep mask
        discipline; see DESIGN.md Section 2)."""
        return self._memo(("halo_mask", k), lambda: self._build_halo_mask(k))

    def _build_halo_mask(self, k: int) -> np.ndarray:
        w = self.rho + 2 * k
        table = self.offset_table(k)
        full = np.broadcast_to(self.window_mask(k),
                               (self.n_blocks, w, w)).copy()
        for oi, (bdx, bdy) in enumerate(self.halo_offsets(k)):
            (dy0, dy1, dx0, dx1), _ = self._halo_region(k, bdx, bdy)
            full[table[:, oi] == self.ghost, dy0:dy1, dx0:dx1] = 0
        return full

    # -------------------------------------------- macro-tile strip geometry
    def macro_tiles(self, k: int, lanes: int = 128,
                    p: Optional[int] = None) -> Tuple[int, int, int]:
        """Lane-packing geometry of the v5 MXU kernel: ``(P, n_macro,
        nb_pad)`` where ``P`` compact blocks (each a depth-``k`` padded
        ``(rho+2k)``-wide slot) are packed side by side along the minor
        (lane) axis of one macro-tile, chosen so ``P * (rho+2k)`` fills
        the ``lanes``-wide vector registers, and ``n_macro = ceil(n_blocks
        / P)`` macro-tiles cover the compact block domain. After the
        ceiling split, ``P`` is rebalanced down to ``ceil(n_blocks /
        n_macro)`` so padding slots (dead lanes) are minimized. ``nb_pad =
        n_macro * P >= n_blocks``; slots past ``n_blocks`` are zero-filled
        ghosts whose outputs are sliced off.

        ``p`` overrides the lane heuristic with an explicit packing (the
        autotuner sweeps it; clamped to [1, n_blocks], no rebalance — the
        caller asked for exactly this packing)."""
        return self.macro_tiles_for(self.n_blocks, k, lanes, p)

    def macro_tiles_for(self, nb: int, k: int, lanes: int = 128,
                        p: Optional[int] = None) -> Tuple[int, int, int]:
        """``macro_tiles`` for an arbitrary block count ``nb`` — the
        distributed engine packs each shard's *local* blocks (nb_padded /
        n_shards of them) into their own macro-tiles, so the lane-packing
        geometry must be computable per shard, not only for the full
        compact domain. ``p`` overrides the lane heuristic (see
        ``macro_tiles``)."""
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        if p is not None:
            if p < 1:
                raise ValueError(f"macro-tile packing must be >= 1, "
                                 f"got {p}")
            p = min(p, nb)
            n_macro = -(-nb // p)
            return p, n_macro, n_macro * p
        w = self.rho + 2 * k
        p = max(1, min(lanes // w, nb))
        n_macro = -(-nb // p)
        p = -(-nb // n_macro)  # rebalance: same tile count, fewer dead slots
        return p, n_macro, n_macro * p

    # ------------------------------------------- depth-k exchange strips
    # The distributed halo exchange (core/distributed.py) ships *edge
    # bands*, never whole blocks: per block the top/bottom k rows and the
    # west/east k columns (transposed so all four stack to (4, k, rho)).
    # Corner k x k pieces are sub-slices of the top/bottom bands, so the
    # bands alone reconstruct a full depth-k Moore halo. Valid for
    # k <= rho (one block ring — the same bound as the fused kernels);
    # the consuming table is ``offset_table(k)``, whose radius-1 case is
    # exactly ``neighbor_table`` (ghosts exact past holes at every depth).
    def pack_edge_strips(self, state: Array, k: int) -> Array:
        """(L, nb, rho, rho) -> (L, nb, 4, k, rho) edge bands:
        row 0 = top k rows, row 1 = bottom k rows, row 2 = west k cols
        (transposed), row 3 = east k cols (transposed)."""
        rho = self.rho
        if not (1 <= k <= rho):
            raise ValueError(f"need 1 <= k <= rho={rho}, got k={k}")
        top = state[:, :, :k, :]
        bot = state[:, :, rho - k:, :]
        west = state[:, :, :, :k].swapaxes(-1, -2)
        east = state[:, :, :, rho - k:].swapaxes(-1, -2)
        return jnp.stack([top, bot, west, east], axis=2)

    def halo_from_strips_k(self, strips: Array, table: Array, k: int):
        """Assemble depth-``k`` halo pieces from packed edge strips.

        ``strips``: (L, ns, 4, k, rho) — ``pack_edge_strips`` output over
        any superset of blocks (ns >= nb; the distributed engine appends a
        zero ghost entry at ns-1). ``table``: (nb_sel, 8) row indices into
        ``strips`` per Moore direction, ghosts already remapped to the
        zero entry. Returns ``(top, bot, west, east)`` shaped exactly like
        the fused kernels' ``_gather_halo_k`` output — top/bot
        (L, nb_sel, k, rho+2k) full-width rows including the diagonal
        k x k corners, west/east (L, nb_sel, rho, k) center columns — so
        every depth-k consumer (XLA window assembly, v4/v5 kernels) is
        shared between the single-device and distributed paths."""
        rho = self.rho

        def band(d, row):  # (L, nb_sel, k, rho)
            return strips[:, table[:, d], row]

        # MOORE_DIRS order: NW 0, N 1, NE 2, W 3, E 4, SW 5, S 6, SE 7
        top = jnp.concatenate(
            [band(0, 1)[..., rho - k:], band(1, 1),
             band(2, 1)[..., :k]], axis=-1)
        bot = jnp.concatenate(
            [band(5, 0)[..., rho - k:], band(6, 0),
             band(7, 0)[..., :k]], axis=-1)
        west = band(3, 3).swapaxes(-1, -2)   # W neighbor's east cols
        east = band(4, 2).swapaxes(-1, -2)   # E neighbor's west cols
        return top, bot, west, east

    def existence_padded(self, k: int,
                         p: Optional[int] = None) -> np.ndarray:
        """(nb_pad, 8) int32 ``existence_table`` zero-padded to the macro
        slot count: padding slots have no real neighbors, so their halo
        regions stay ghost-gated to zero in the v5 kernel. ``p`` is the
        macro-tile packing override (None = lane heuristic)."""
        def build():
            _, _, nb_pad = self.macro_tiles(k, p=p)
            pad = np.zeros((nb_pad - self.n_blocks, 8), np.int32)
            return np.concatenate([self.existence_table, pad], axis=0)
        return self._memo(("existence_padded", k, p), build)

    def dev_existence_padded(self, k: int,
                             p: Optional[int] = None) -> Array:
        """Device-side ``existence_padded(k)`` (shared upload per depth
        and packing)."""
        return self._memo(
            ("dev_existence_padded", k, p),
            lambda: self._to_device(self.existence_padded(k, p)))

    # ------------------------------------------ locality-aware sharding
    def strip_decomposition(self, n_shards: int) -> "StripDecomposition":
        """Locality-aware block->shard assignment for the neighbor-only
        point-to-point halo exchange (one shared build per shard count;
        see :class:`StripDecomposition`)."""
        return self._memo(("strip_decomposition", n_shards),
                          lambda: StripDecomposition(self, n_shards))

    # ------------------------------------------------------------ conversions
    def to_expanded(self, state_b: Array) -> Array:
        """Block state (C?, n_blocks, rho, rho) -> (C?, n, n) expanded
        embedding (leading channel axes pass through)."""
        n = self.frac.side(self.r)
        org = self.dev_block_origin_expanded  # (n_blocks, 2)
        rho = self.rho
        iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho), indexing="ij")
        # absolute cell coords per (block, i, j)
        ax = org[:, 0, None, None] + ix[None]
        ay = org[:, 1, None, None] + iy[None]
        out = jnp.zeros(state_b.shape[:-3] + (n, n), dtype=state_b.dtype)
        return out.at[..., ay, ax].set(state_b)

    def from_expanded(self, state_e: Array) -> Array:
        """(C?, n, n) expanded embedding -> block state (C?, n_blocks,
        rho, rho)."""
        org = self.dev_block_origin_expanded
        rho = self.rho
        iy, ix = jnp.meshgrid(jnp.arange(rho), jnp.arange(rho), indexing="ij")
        ax = org[:, 0, None, None] + ix[None]
        ay = org[:, 1, None, None] + iy[None]
        mask = self.dev_micro_mask
        return state_e[..., ay, ax] * mask.astype(state_e.dtype)

    def pad_with_halo(self, state_b: Array) -> Array:
        """Assemble (n_blocks, rho+2, rho+2) tiles with Moore halos.

        Gathers only the needed strips (edge rows/cols, corner cells) from
        each neighbor block via the static table; ghost neighbors read as 0.
        """
        rho = self.rho
        nb = self.n_blocks
        # one zero ghost block appended: sentinel gathers read zeros.
        padded_src = jnp.concatenate(
            [state_b, jnp.zeros((1, rho, rho), state_b.dtype)], axis=0)
        table = self.dev_neighbor_table  # (nb, 8)

        out = jnp.zeros((nb, rho + 2, rho + 2), state_b.dtype)
        out = out.at[:, 1:-1, 1:-1].set(state_b)

        def nbr(d):  # (nb, rho, rho) neighbor-block contents for direction d
            return jnp.take(padded_src, table[:, d], axis=0)

        # MOORE_DIRS order: NW, N, NE, W, E, SW, S, SE
        nw, n_, ne, w_, e_, sw, s_, se = (nbr(d) for d in range(8))
        out = out.at[:, 0, 0].set(nw[:, -1, -1])
        out = out.at[:, 0, 1:-1].set(n_[:, -1, :])
        out = out.at[:, 0, -1].set(ne[:, -1, 0])
        out = out.at[:, 1:-1, 0].set(w_[:, :, -1])
        out = out.at[:, 1:-1, -1].set(e_[:, :, 0])
        out = out.at[:, -1, 0].set(sw[:, 0, -1])
        out = out.at[:, -1, 1:-1].set(s_[:, 0, :])
        out = out.at[:, -1, -1].set(se[:, 0, 0])
        return out

    def pad_with_halo_k(self, state_b: Array, k: int) -> Array:
        """Assemble (n_blocks, rho+2k, rho+2k) tiles with depth-``k`` halos.

        The depth-1 generalization of ``pad_with_halo``: for each block
        offset in ``halo_offsets(k)`` only the overlap strip of the neighbor
        tile with the window is sliced *before* the gather (so HBM traffic
        stays ~perimeter * k, not offsets * rho^2); ghost ids index the
        appended zero strip, which keeps out-of-fractal reads zero at every
        depth.
        """
        if k < 1:
            raise ValueError(f"halo depth must be >= 1, got {k}")
        rho, nb = self.rho, self.n_blocks
        w = rho + 2 * k
        table = self.dev_offset_table(k)
        out = jnp.zeros((nb, w, w), state_b.dtype)
        out = out.at[:, k:k + rho, k:k + rho].set(state_b)
        for oi, (bdx, bdy) in enumerate(self.halo_offsets(k)):
            (dy0, dy1, dx0, dx1), (sy0, sy1, sx0, sx1) = \
                self._halo_region(k, bdx, bdy)
            strip = state_b[:, sy0:sy1, sx0:sx1]
            strip = jnp.concatenate(
                [strip, jnp.zeros((1,) + strip.shape[1:], state_b.dtype)],
                axis=0)
            out = out.at[:, dy0:dy1, dx0:dx1].set(
                jnp.take(strip, table[:, oi], axis=0))
        return out

    def memory_bytes(self, dtype_size: int = 1) -> int:
        """Squeeze block-level state bytes (paper Table 2's nu column)."""
        return self.n_blocks * self.rho * self.rho * dtype_size


def _balanced_contiguous_partition(counts: np.ndarray,
                                   n_groups: int) -> list:
    """Split ``counts`` into ``n_groups`` CONTIGUOUS NONEMPTY groups
    minimizing the maximum group sum (binary search on the capacity +
    greedy feasibility; len(counts) >= n_groups required). Returns the
    half-open index ranges [(a0, b0), ...]."""
    n = len(counts)
    if n < n_groups:
        raise ValueError(f"cannot split {n} rows into {n_groups} "
                         "nonempty groups")

    def bounds_for(cap):
        """Greedy fill under ``cap``, always leaving enough rows for the
        remaining groups to stay nonempty; None when infeasible."""
        out, start, acc = [], 0, 0
        g = n_groups
        for i, c in enumerate(counts):
            must_cut = (n - i) == (g - 1)  # later groups need the rest
            if i > start and (acc + c > cap or must_cut):
                out.append((start, i))
                start, acc, g = i, 0, g - 1
            acc += c
            if acc > cap and i > start:
                return None
        out.append((start, n))
        return out if len(out) == n_groups and acc <= cap else None

    lo, hi = int(counts.max()), int(counts.sum())
    while lo < hi:
        mid = (lo + hi) // 2
        if bounds_for(mid) is None:
            lo = mid + 1
        else:
            hi = mid
    bounds = bounds_for(lo)
    assert bounds is not None
    return bounds


@dataclasses.dataclass(frozen=True)
class StripDecomposition:
    """Locality-aware block->shard assignment: expanded-space row strips.

    Sharding the compact block domain in compact (digit-interleaved)
    id order scatters spatially adjacent blocks across shards, which is
    why the all-gather exchange was needed. This decomposition instead
    orders blocks by their EXPANDED-space block row (``ey`` of
    ``block_origin_expanded`` — one lambda evaluation per block, holes
    handled exactly because only occupied rows exist) and assigns each
    shard a contiguous strip of whole rows, balanced by block count
    (``_balanced_contiguous_partition``). Rows are never split, so a
    block's Moore neighbors (expanded rows ``ey`` +- 1) always live on
    the SAME shard or one of its two strip neighbors — the static
    guarantee that makes a neighbor-only ``ppermute`` exchange exact.

    ``valid`` is False when the mesh degenerates (fewer occupied rows
    than shards: some shard would own no row and the +-1-shard guarantee
    breaks) — the distributed engine then falls back to the all-gather
    exchange.

    Native (engine) state layout: shard ``s`` owns native rows
    ``[s*nb_local, (s+1)*nb_local)``; within a shard, real blocks come
    first (row-major expanded order), then dead padding slots up to
    ``nb_local`` (the max strip load). ``perm[i]`` is the compact block
    id of native row ``i`` (-1 for dead slots).

    Routing tables (all static, built once per (layout, n_shards)):

    * ``send_prev_idx`` / ``send_next_idx`` — (n_shards, ms_prev/next)
      local indices of the blocks whose edge bands the prev/next strip
      neighbor actually needs (padded with the ``nb_local`` zero-strip
      sentinel; clamped to >= 1 slot so the ppermute operands are never
      zero-sized);
    * ``table`` — (n_shards, nb_local, 8) per-shard Moore halo table in
      COMBINED strip coordinates: [0, nbl) local strips, nbl the zero
      ghost row, [nbl+1, nbl+1+ms_next) strips received from the prev
      neighbor (its send_next buffer), then ms_prev slots received
      from the next neighbor (its send_prev buffer);
    * ``interior_idx`` / ``boundary_idx`` — (n_shards, max_interior/
      boundary) local indices partitioning each shard's slots into
      blocks whose depth-k halo is fully shard-local (interior: compute
      overlaps the in-flight exchange) and blocks that must wait for a
      neighbor strip (boundary), padded with the same sentinel.
    """

    layout: BlockLayout
    n_shards: int

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"need n_shards >= 1, got {self.n_shards}")
        self._build()

    def _set(self, **kw):
        for name, val in kw.items():
            object.__setattr__(self, name, val)

    # ------------------------------------------------------------- build
    def _build(self) -> None:
        layout = self.layout
        nb, rho = layout.n_blocks, layout.rho
        org = layout.block_origin_expanded
        ex, ey = org[:, 0] // rho, org[:, 1] // rho
        order = np.lexsort((ex, ey)).astype(np.int32)
        rows, counts = np.unique(ey, return_counts=True)
        if len(rows) < self.n_shards:
            self._set(valid=False, nb_local=0, nb_padded=0, perm=None,
                      shard_of=None, local_of=None)
            return
        bounds = _balanced_contiguous_partition(counts, self.n_shards)
        row_shard = {int(rows[i]): s
                     for s, (a, b) in enumerate(bounds)
                     for i in range(a, b)}
        shard_of = np.array([row_shard[int(y)] for y in ey], np.int32)
        nbl = int(max(counts[a:b].sum() for a, b in bounds))
        perm = np.full(self.n_shards * nbl, -1, np.int32)
        local_of = np.empty(nb, np.int32)
        fill = np.zeros(self.n_shards, np.int32)
        for g in order:  # row-major within each strip
            s = shard_of[g]
            local_of[g] = fill[s]
            perm[s * nbl + fill[s]] = g
            fill[s] += 1
        self._set(valid=True, nb_local=nbl,
                  nb_padded=self.n_shards * nbl, perm=perm,
                  shard_of=shard_of, local_of=local_of)
        self._build_routing()

    def _build_routing(self) -> None:
        layout, nbl, ns = self.layout, self.nb_local, self.n_shards
        table_g = layout.neighbor_table  # (nb, 8) compact block ids
        ghost = layout.ghost
        shard_of, local_of = self.shard_of, self.local_of
        send_prev = [[] for _ in range(ns)]  # local idx needed by s-1
        send_next = [[] for _ in range(ns)]  # local idx needed by s+1
        for g in range(layout.n_blocks):
            s = int(shard_of[g])
            for ng in table_g[g]:
                if ng == ghost:
                    continue
                d = int(shard_of[ng]) - s
                if abs(d) > 1:  # the row-strip invariant
                    raise AssertionError(
                        f"strip decomposition broke: blocks {g}->{ng} "
                        f"span shards {s}->{shard_of[ng]}")
                # ng's strip travels from its shard s+d back to s, i.e.
                # shard s+1 sends to its PREV neighbor and vice versa
                tgt = send_prev if d == 1 else send_next if d == -1 \
                    else None
                if tgt is not None and local_of[ng] not in tgt[s + d]:
                    tgt[s + d].append(int(local_of[ng]))
        for lst in (*send_prev, *send_next):
            lst.sort()
        # >= 1 slot: the ppermute operands must never be zero-sized
        ms_prev = max(1, max(len(x) for x in send_prev))
        ms_next = max(1, max(len(x) for x in send_next))

        def pad(lists, width):
            out = np.full((ns, width), nbl, np.int32)  # zero-strip row
            for s, lst in enumerate(lists):
                out[s, :len(lst)] = lst
            return out

        slot_prev = [{li: j for j, li in enumerate(lst)}
                     for lst in send_prev]
        slot_next = [{li: j for j, li in enumerate(lst)}
                     for lst in send_next]

        # per-shard halo table in combined strip coordinates
        table = np.full((ns, nbl, 8), nbl, np.int32)
        remote = np.zeros((ns, nbl), bool)
        for g in range(layout.n_blocks):
            s, li = int(shard_of[g]), int(local_of[g])
            for d in range(8):
                ng = table_g[g, d]
                if ng == ghost:
                    continue  # stays the zero ghost row
                so, lo = int(shard_of[ng]), int(local_of[ng])
                if so == s:
                    table[s, li, d] = lo
                elif so == s - 1:
                    # recv-from-prev slab = prev shard's send_next
                    # buffer (width ms_next)
                    table[s, li, d] = nbl + 1 + slot_next[so][lo]
                    remote[s, li] = True
                else:
                    # recv-from-next slab = next shard's send_prev
                    # buffer (width ms_prev)
                    table[s, li, d] = (nbl + 1 + ms_next
                                       + slot_prev[so][lo])
                    remote[s, li] = True

        # interior/boundary partition of every local slot (dead padding
        # slots are interior: they compute to zero without any strip)
        interior = [np.flatnonzero(~remote[s]) for s in range(ns)]
        boundary = [np.flatnonzero(remote[s]) for s in range(ns)]
        mi = max(1, max(len(x) for x in interior))
        mb = max(1, max(len(x) for x in boundary))
        self._set(
            ms_prev=ms_prev, ms_next=ms_next,
            send_prev_idx=pad(send_prev, ms_prev),
            send_next_idx=pad(send_next, ms_next),
            table=table,
            interior_idx=pad(interior, mi),
            boundary_idx=pad(boundary, mb),
            n_interior=np.array([len(x) for x in interior], np.int32),
            n_boundary=np.array([len(x) for x in boundary], np.int32),
            real_sends=sum(1 for s in range(ns - 1)
                           if len(send_next[s])) +
            sum(1 for s in range(1, ns) if len(send_prev[s])),
        )

    # ------------------------------------------------------- exchange ops
    def pack_edge_strips_for(self, strips_z: Array, neighbor: str,
                             shard: int = 0) -> Array:
        """Gather the send buffer for one strip neighbor out of this
        shard's zero-row-appended local strips (``strips_z``:
        (L, nb_local+1, 4, k, rho)). ``neighbor``: 'prev' | 'next'.
        Inside shard_map the per-shard routing row arrives as a sharded
        operand; this host-facing form (used by the tests' exchange
        simulation) selects it by ``shard``."""
        idx = (self.send_prev_idx if neighbor == "prev"
               else self.send_next_idx)[shard]
        return strips_z[:, idx]

    def halo_from_neighbor_strips_k(self, combined: Array, table: Array,
                                    k: int):
        """Assemble depth-``k`` halo pieces from the COMBINED per-shard
        strip array (local strips + zero row + received neighbor slabs,
        in the ``table`` coordinate convention) — the neighbor-routed
        counterpart of :meth:`BlockLayout.halo_from_strips_k`, sharing
        its band layout with every depth-k consumer."""
        return self.layout.halo_from_strips_k(combined, table, k)

    # ------------------------------------------------------- accounting
    def slot_bytes(self, k: int, itemsize: int) -> int:
        """Bytes of one strip slot (all four depth-``k`` edge bands of
        one block): 4 * k * rho cells."""
        return 4 * k * self.layout.rho * itemsize

    def wire_bytes_per_exchange(self, k: int, itemsize: int,
                                batch: int = 1) -> int:
        """Total bytes moved over the interconnect by one depth-``k``
        p2p exchange: both ppermutes ship their (clamped) buffers
        between every adjacent shard pair."""
        slots = (self.ms_prev + self.ms_next) * (self.n_shards - 1)
        return batch * slots * self.slot_bytes(k, itemsize)

    def wire_bytes_per_device_per_exchange(self, k: int, itemsize: int,
                                           batch: int = 1) -> int:
        """Bytes RECEIVED by one (interior) shard per exchange — the
        per-device wire pressure, independent of the shard count (the
        flat curve the scaling gate asserts)."""
        return (batch * (self.ms_prev + self.ms_next)
                * self.slot_bytes(k, itemsize))
