"""3D compact stencil engines: the paper's case study lifted to 3D NBB
fractals (Menger sponge etc.) using the lambda3/nu3 maps — completing the
§5 "extend to 3D" future work into a runnable simulator.

Parameterized by a single-channel ``StencilWorkload`` over the 26-cell
Moore neighborhood; the default is 3D life B6/S5-7 (``LIFE3D``), and
``HEAT3D`` runs the Jacobi heat workload on the 6 orthogonal neighbors.
Holes and out-of-bounds never contribute, exactly like the 2D adaptation
in §4.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import fractals3d as f3
from repro.workloads.base import (StencilWorkload, check_workload_ndim,
                                  weighted_gather_agg)
from repro.workloads.rules import LIFE3D

Array = jnp.ndarray

MOORE3: Tuple[Tuple[int, int, int], ...] = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0))


def life3_rule(alive: Array, neighbors: Array) -> Array:
    """3D life B6/S5-7 (kept as a function for the original call sites)."""
    born = (neighbors == 6)
    survive = (alive > 0) & (neighbors >= 5) & (neighbors <= 7)
    return (born | survive).astype(jnp.uint8)


def _check_workload(workload: StencilWorkload):
    if workload.n_channels != 1:
        raise ValueError("3D engines support single-channel workloads only")
    check_workload_ndim(workload, 3)


def _weights3(workload: StencilWorkload):
    return tuple(workload.weight(d) for d in MOORE3)


@dataclasses.dataclass(frozen=True)
class BB3DEngine:
    """Expanded bounding-volume baseline: O(n^3) memory."""

    frac: f3.NBBFractal3D
    r: int
    workload: StencilWorkload = LIFE3D

    def __post_init__(self):
        _check_workload(self.workload)

    def init_random(self, seed: int) -> Array:
        n = self.frac.side(self.r)
        mask = jnp.asarray(self.frac.mask(self.r))
        field = self.workload.init(jax.random.PRNGKey(seed), (n, n, n))
        return field * mask.astype(field.dtype)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        mask = jnp.asarray(self.frac.mask(self.r))
        padded = jnp.pad(state, 1)
        n = state.shape[0]
        agg = weighted_gather_agg(
            MOORE3, _weights3(wl),
            lambda d: padded[1 + d[2]:n + 1 + d[2], 1 + d[1]:n + 1 + d[1],
                             1 + d[0]:n + 1 + d[0]],
            state.shape, wl.agg_dtype)
        return wl.apply(state, agg, mask).astype(state.dtype)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self) -> int:
        return self.frac.side(self.r) ** 3


@dataclasses.dataclass(frozen=True)
class Squeeze3DEngine:
    """Compact 3D engine: O(k^r) memory via lambda3/nu3 per neighbor."""

    frac: f3.NBBFractal3D
    r: int
    workload: StencilWorkload = LIFE3D

    def __post_init__(self):
        _check_workload(self.workload)

    def _compact_grid(self):
        nx, ny, nz = self.frac.compact_dims(self.r)
        cz, cy, cx = jnp.meshgrid(jnp.arange(nz, dtype=jnp.int32),
                                  jnp.arange(ny, dtype=jnp.int32),
                                  jnp.arange(nx, dtype=jnp.int32),
                                  indexing="ij")
        return cx, cy, cz

    def init_random(self, seed: int) -> Array:
        expanded = BB3DEngine(self.frac, self.r,
                              self.workload).init_random(seed)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        return expanded[ez, ey, ex]

    def to_expanded(self, state: Array) -> Array:
        n = self.frac.side(self.r)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        out = jnp.zeros((n, n, n), state.dtype)
        return out.at[ez, ey, ex].set(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r, wl = self.frac, self.r, self.workload
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(frac, r, cx, cy, cz)

        def gather(d):
            nx_, ny_, nz_ = ex + d[0], ey + d[1], ez + d[2]
            valid = f3.is_fractal3(frac, r, nx_, ny_, nz_)
            bx, by, bz = f3.nu3_map(frac, r, nx_, ny_, nz_)
            return jnp.where(valid, state[bz, by, bx],
                             jnp.zeros((), state.dtype))

        agg = weighted_gather_agg(MOORE3, _weights3(wl), gather,
                                  state.shape, wl.agg_dtype)
        return wl.apply(state, agg, None).astype(state.dtype)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self) -> int:
        return self.frac.volume(self.r)
