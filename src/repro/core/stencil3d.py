"""3D compact stencil engines: the paper's case study lifted to 3D NBB
fractals (Menger sponge etc.) using the lambda3/nu3 maps — completing
the §5 "extend to 3D" future work at full performance parity with the
2D stack:

  * ``BB3DEngine``          — expanded bounding-volume baseline, O(n^3).
  * ``Squeeze3DEngine``     — paper-faithful per-cell compact engine
                              (one lambda3 per cell, one nu3 +
                              membership per neighbor), O(k^r) memory.
  * ``Squeeze3DBlockEngine``  — block-level Squeeze over
                              ``BlockLayout3D``: static 26-direction
                              block tables turn the step into
                              halo-gather + dense in-cube stencil, with
                              ``step_k`` depth-k temporal fusion (any
                              k >= 1; k > rho spans multiple block
                              rings through the offset tables).
  * ``Squeeze3DPallasEngine`` — the block engine with its step fused
                              into one of the 3D Pallas kernels
                              (kernels/squeeze_stencil3d.py): variant
                              'fused' (v4-style depth-k window in VMEM)
                              or 'mxu' (v5-style z-slab banded matmuls
                              on lane-packed macro-tiles). k <= rho.

All engines are parameterized by a single-channel ``StencilWorkload``
over the 26-cell Moore neighborhood; the defaults are 3D life B6/S5-7
(``LIFE3D``) and the 6-neighbor Jacobi heat workload (``HEAT3D``).
Holes and out-of-bounds never contribute, exactly like the 2D
adaptation in §4. Every ``run`` goes through the cached-jit machinery
of core/stencil.py: the step count is a *traced* loop bound (changing
it does not retrace) and ``donate=True`` donates the state buffer to
XLA for zero-copy steady-state stepping.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fractals3d as f3
from repro.core.compact3d import BlockLayout3D
from repro.core.stencil import _CachedRun, _FusedStepping
from repro.workloads.base import (MOORE3_DIRS, StencilWorkload,
                                  check_workload_ndim, weighted_gather_agg,
                                  weighted_moore_agg3)
from repro.workloads.rules import LIFE3D

Array = jnp.ndarray


def life3_rule(alive: Array, neighbors: Array) -> Array:
    """3D life B6/S5-7 (kept as a function for the original call sites)."""
    born = (neighbors == 6)
    survive = (alive > 0) & (neighbors >= 5) & (neighbors <= 7)
    return (born | survive).astype(jnp.uint8)


def _check_workload(workload: StencilWorkload):
    if workload.n_channels != 1:
        raise ValueError("3D engines support single-channel workloads only")
    check_workload_ndim(workload, 3)


@dataclasses.dataclass(frozen=True)
class BB3DEngine(_CachedRun):
    """Expanded bounding-volume baseline: O(n^3) memory."""

    frac: f3.NBBFractal3D
    r: int
    workload: StencilWorkload = LIFE3D

    def __post_init__(self):
        _check_workload(self.workload)

    def init_random(self, seed: int) -> Array:
        n = self.frac.side(self.r)
        mask = jnp.asarray(self.frac.mask(self.r))
        field = self.workload.init(jax.random.PRNGKey(seed), (n, n, n))
        return field * mask.astype(field.dtype)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        mask = jnp.asarray(self.frac.mask(self.r))
        padded = jnp.pad(state, 1)
        agg = weighted_moore_agg3(padded, wl.weights3d, wl.agg_dtype)
        return wl.apply(state, agg, mask).astype(state.dtype)

    def _run_impl(self, state: Array, steps) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def run(self, state: Array, steps, donate: bool = False) -> Array:
        return self._dispatch_run(state, steps, donate)

    def memory_bytes(self) -> int:
        return self.frac.side(self.r) ** 3


@dataclasses.dataclass(frozen=True)
class Squeeze3DEngine(_CachedRun):
    """Compact 3D engine: O(k^r) memory via lambda3/nu3 per neighbor."""

    frac: f3.NBBFractal3D
    r: int
    workload: StencilWorkload = LIFE3D

    def __post_init__(self):
        _check_workload(self.workload)

    def _compact_grid(self):
        nx, ny, nz = self.frac.compact_dims(self.r)
        cz, cy, cx = jnp.meshgrid(jnp.arange(nz, dtype=jnp.int32),
                                  jnp.arange(ny, dtype=jnp.int32),
                                  jnp.arange(nx, dtype=jnp.int32),
                                  indexing="ij")
        return cx, cy, cz

    def init_random(self, seed: int) -> Array:
        expanded = BB3DEngine(self.frac, self.r,
                              self.workload).init_random(seed)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        return expanded[ez, ey, ex]

    def to_expanded(self, state: Array) -> Array:
        n = self.frac.side(self.r)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        out = jnp.zeros((n, n, n), state.dtype)
        return out.at[ez, ey, ex].set(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r, wl = self.frac, self.r, self.workload
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(frac, r, cx, cy, cz)

        def gather(d):
            nx_, ny_, nz_ = ex + d[0], ey + d[1], ez + d[2]
            valid = f3.is_fractal3(frac, r, nx_, ny_, nz_)
            bx, by, bz = f3.nu3_map(frac, r, nx_, ny_, nz_)
            return jnp.where(valid, state[bz, by, bx],
                             jnp.zeros((), state.dtype))

        agg = weighted_gather_agg(MOORE3_DIRS, wl.weights3d, gather,
                                  state.shape, wl.agg_dtype)
        return wl.apply(state, agg, None).astype(state.dtype)

    def _run_impl(self, state: Array, steps) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def run(self, state: Array, steps, donate: bool = False) -> Array:
        """``steps`` steps in one cached jit whose loop bound is a
        *traced* scalar — changing the step count does not recompile
        (the old bare ``fori_loop`` baked the Python int into the
        trace, so every distinct count paid a full retrace; same fix as
        ``SqueezeCellEngine.run``). ``donate=True`` donates the input
        state buffer to XLA — zero-copy steady-state stepping; the
        caller must not reuse ``state`` afterwards."""
        return self._dispatch_run(state, steps, donate)

    def memory_bytes(self) -> int:
        return self.frac.volume(self.r)


@dataclasses.dataclass(frozen=True)
class Squeeze3DBlockEngine(_FusedStepping):
    """3D block-level Squeeze with static 26-direction neighbor tables.

    ``fusion_k`` sets the temporal-fusion depth used by ``run`` (None =
    the shared ``default_fusion_k`` heuristic on rho). The XLA
    ``step_k`` path supports any k >= 1 — depths beyond rho span
    multiple block rings through the depth-k offset tables.
    """

    layout: BlockLayout3D
    workload: StencilWorkload = LIFE3D
    fusion_k: Optional[int] = None

    def __post_init__(self):
        _check_workload(self.workload)
        if self.fusion_k is not None and self.fusion_k < 1:
            raise ValueError(f"fusion_k must be >= 1, got {self.fusion_k}")
        self.layout.materialize()

    @property
    def frac(self) -> f3.NBBFractal3D:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        expanded = BB3DEngine(self.frac, self.r,
                              self.workload).init_random(seed)
        return self.layout.from_expanded(expanded)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        wl = self.workload
        self.layout.materialize_halo(1)
        padded = self.layout.pad_with_halo_k(state, 1)
        agg = weighted_moore_agg3(padded, wl.weights3d, wl.agg_dtype)
        mask = self.layout.dev_micro_mask  # broadcasts over n_blocks
        return wl.apply(state, agg, mask).astype(state.dtype)

    # ------------------------------------------------------ temporal fusion
    def _materialize_fused(self, k: int) -> None:
        self.layout.materialize_halo(k)
        self.layout.materialize_halo(1)  # the remainder path's step()

    def step_k(self, state: Array, k: int) -> Array:
        """Advance ``k`` exact steps in one fused computation: one
        depth-k halo assembly, then k in-register substeps on the
        shrinking window (XLA path; any k >= 1, including k > rho)."""
        self.layout.materialize_halo(k)  # host tables outside the trace
        self.layout.materialize_halo(1)
        return self._step_k(state, k)

    @partial(jax.jit, static_argnums=(0, 2))
    def _step_k(self, state: Array, k: int) -> Array:
        wl = self.workload
        padded = self.layout.pad_with_halo_k(state, k)
        hmask = self.layout.dev_halo_mask(k)  # (nb, (rho+2k)^3)
        return wl.tile_rule_k(padded, hmask, k, ndim=3).astype(state.dtype)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.layout.memory_bytes(dtype_size)


@dataclasses.dataclass(frozen=True)
class Squeeze3DPallasEngine(_FusedStepping):
    """3D block-level Squeeze with the step fused into a Pallas kernel.

    ``variant`` selects the kernel of kernels/squeeze_stencil3d.py:
    'fused' (depth-k window assembled in VMEM, k substeps, one write)
    or 'mxu' (z-slab banded matmul aggregation on lane-packed
    macro-tiles). State layout and conversions are identical to
    ``Squeeze3DBlockEngine``; ``fusion_k`` must stay <= rho (the
    kernels' one-block-ring limit).
    """

    layout: BlockLayout3D
    workload: StencilWorkload = LIFE3D
    variant: str = "fused"
    fusion_k: Optional[int] = None
    #: MXU macro-tile packing override ('mxu' variant only, None = lane
    #: heuristic)
    macro_p: Optional[int] = None

    def __post_init__(self):
        if self.variant not in ("fused", "mxu"):
            raise ValueError(f"unknown 3D Pallas variant {self.variant!r}")
        _check_workload(self.workload)
        if self.fusion_k is not None and not (
                1 <= self.fusion_k <= self.layout.rho):
            raise ValueError(
                f"pallas fusion_k must be in [1, rho={self.layout.rho}], "
                f"got {self.fusion_k}")
        if self.macro_p is not None and self.variant != "mxu":
            raise ValueError(
                "macro_p only applies to the 'mxu' variant, got "
                f"variant={self.variant!r}")
        self.layout.materialize()

    @property
    def frac(self) -> f3.NBBFractal3D:
        return self.layout.frac

    @property
    def r(self) -> int:
        return self.layout.r

    def init_random(self, seed: int) -> Array:
        return Squeeze3DBlockEngine(self.layout,
                                    self.workload).init_random(seed)

    def to_expanded(self, state: Array) -> Array:
        return self.layout.to_expanded(state)

    def step(self, state: Array) -> Array:
        return self.step_k(state, 1)

    # ------------------------------------------------------ temporal fusion
    def _materialize_fused(self, k: int) -> None:
        # only what the fused kernels read — not the XLA path's
        # per-block halo_mask/offset_table host build
        for kk in {1, k}:  # k and the remainder path's single step
            _ = self.layout.dev_existence_table
            _ = self.layout.dev_window_mask(kk)
            if self.variant == "mxu":
                p = self.layout.macro_tiles(kk, p=self.macro_p)[0]
                _ = self.layout.dev_existence_padded(kk, p=p)

    def step_k(self, state: Array, k: int) -> Array:
        """Advance ``k`` exact steps in one fused kernel launch
        (k <= rho)."""
        from repro.kernels import squeeze_stencil3d as k3
        if self.variant == "mxu":
            return k3.stencil3d_step_mxu_k(self.layout, state,
                                           self.workload, k=k,
                                           p=self.macro_p)
        return k3.stencil3d_step_fused_k(self.layout, state, self.workload,
                                         k=k)

    def memory_bytes(self, dtype_size: int = 1) -> int:
        return self.layout.memory_bytes(dtype_size)
