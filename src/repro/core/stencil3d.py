"""3D compact stencil engines: the paper's game-of-life case study lifted
to 3D NBB fractals (Menger sponge etc.) using the lambda3/nu3 maps —
completing the §5 "extend to 3D" future work into a runnable simulator.

Rule: 3D life B6/S5-7 (a common 26-neighbor Moore variant); holes and
out-of-bounds never count, exactly like the 2D adaptation in §4.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import fractals3d as f3

Array = jnp.ndarray

MOORE3: Tuple[Tuple[int, int, int], ...] = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0))


def life3_rule(alive: Array, neighbors: Array) -> Array:
    born = (neighbors == 6)
    survive = (alive > 0) & (neighbors >= 5) & (neighbors <= 7)
    return (born | survive).astype(jnp.uint8)


@dataclasses.dataclass(frozen=True)
class BB3DEngine:
    """Expanded bounding-volume baseline: O(n^3) memory."""

    frac: f3.NBBFractal3D
    r: int

    def init_random(self, seed: int) -> Array:
        n = self.frac.side(self.r)
        mask = jnp.asarray(self.frac.mask(self.r))
        bits = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5,
                                    (n, n, n))
        return (bits & (mask > 0)).astype(jnp.uint8)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        mask = jnp.asarray(self.frac.mask(self.r))
        padded = jnp.pad(state, 1)
        n = state.shape[0]
        counts = jnp.zeros_like(state, jnp.int32)
        for dx, dy, dz in MOORE3:
            counts = counts + padded[1 + dz:n + 1 + dz, 1 + dy:n + 1 + dy,
                                     1 + dx:n + 1 + dx].astype(jnp.int32)
        return life3_rule(state, counts) * mask

    def memory_bytes(self) -> int:
        return self.frac.side(self.r) ** 3


@dataclasses.dataclass(frozen=True)
class Squeeze3DEngine:
    """Compact 3D engine: O(k^r) memory via lambda3/nu3 per neighbor."""

    frac: f3.NBBFractal3D
    r: int

    def _compact_grid(self):
        nx, ny, nz = self.frac.compact_dims(self.r)
        cz, cy, cx = jnp.meshgrid(jnp.arange(nz, dtype=jnp.int32),
                                  jnp.arange(ny, dtype=jnp.int32),
                                  jnp.arange(nx, dtype=jnp.int32),
                                  indexing="ij")
        return cx, cy, cz

    def init_random(self, seed: int) -> Array:
        expanded = BB3DEngine(self.frac, self.r).init_random(seed)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        return expanded[ez, ey, ex]

    def to_expanded(self, state: Array) -> Array:
        n = self.frac.side(self.r)
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(self.frac, self.r, cx, cy, cz)
        out = jnp.zeros((n, n, n), state.dtype)
        return out.at[ez, ey, ex].set(state)

    @partial(jax.jit, static_argnums=0)
    def step(self, state: Array) -> Array:
        frac, r = self.frac, self.r
        cx, cy, cz = self._compact_grid()
        ex, ey, ez = f3.lambda3_map(frac, r, cx, cy, cz)
        counts = jnp.zeros(state.shape, jnp.int32)
        for dx, dy, dz in MOORE3:
            nx_, ny_, nz_ = ex + dx, ey + dy, ez + dz
            valid = f3.is_fractal3(frac, r, nx_, ny_, nz_)
            bx, by, bz = f3.nu3_map(frac, r, nx_, ny_, nz_)
            val = state[bz, by, bx].astype(jnp.int32)
            counts = counts + jnp.where(valid, val, 0)
        return life3_rule(state, counts)

    def run(self, state: Array, steps: int) -> Array:
        return jax.lax.fori_loop(0, steps, lambda _, s: self.step(s), state)

    def memory_bytes(self) -> int:
        return self.frac.volume(self.r)
