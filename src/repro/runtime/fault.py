"""Fault-tolerance runtime: step watchdog (straggler detection), preemption
handling, and a restart supervisor.

At 1000+ nodes the failure model is: frequent single-host preemptions
(handled by checkpoint/restart — the supervisor here), slow hosts
(watchdog surfaces p95 outliers so the scheduler can cordon them), and
rare corrupt saves (prevented by the manager's atomic rename protocol).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to emulate a mid-run crash."""


@dataclasses.dataclass
class Watchdog:
    """Tracks step wall-times; flags stragglers beyond k x median."""
    straggler_factor: float = 3.0
    window: int = 50
    _times: List[float] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None
    stragglers: int = 0

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        dt = time.monotonic() - self._t0
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = sorted(self._times)[len(self._times) // 2]
        if len(self._times) >= 5 and dt > self.straggler_factor * med:
            self.stragglers += 1
        return dt

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]


class PreemptionHandler:
    """SIGTERM -> request a final checkpoint and a clean exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGUSR1, self._handler)
            except ValueError:
                pass  # not the main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def request(self):  # programmatic (tests / chaos)
        self.requested = True


def run_with_restarts(make_run: Callable[[], int], max_restarts: int = 3
                      ) -> int:
    """Supervisor: call ``make_run`` (which resumes from the latest
    checkpoint internally) until it returns, restarting on failures.

    Returns the final step. ``make_run`` must be idempotent-from-
    checkpoint — with the stateless data pipeline and bit-exact restore
    this makes the whole trajectory restart-invariant (tested)."""
    attempts = 0
    while True:
        try:
            return make_run()
        except SimulatedFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
