"""Fault-tolerance runtime: step watchdog (straggler detection), preemption
handling, and a restart supervisor.

At 1000+ nodes the failure model is: frequent single-host preemptions
(handled by checkpoint/restart — the supervisor here), slow hosts
(watchdog surfaces p95 outliers so the scheduler can cordon them), and
rare corrupt saves (prevented by the manager's atomic rename protocol).

Accounting lives on the telemetry registry (``repro.obs``): the
watchdog's step times land in a ``watchdog.step_seconds`` histogram
(one labeled series per watchdog — the bespoke ring buffer of samples
is gone), straggler fires count ``watchdog.stragglers``, and the
restart supervisor counts ``fault.restarts``. These record regardless
of the ``SQUEEZE_TELEMETRY`` toggle: constructing a watchdog or a
supervisor IS the opt-in, and both are control-flow state (the
straggler median and the give-up bound read them back), not optional
telemetry.
"""
from __future__ import annotations

import dataclasses
import itertools
import signal
import time
from typing import Callable, Optional

from repro.obs import Histogram, default_registry


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to emulate a mid-run crash."""


#: distinct default label per Watchdog instance, so two watchdogs (e.g.
#: successive train() calls in one process) never mix their step-time
#: distributions — the straggler median must see only its own steps
_WD_IDS = itertools.count()


@dataclasses.dataclass
class Watchdog:
    """Tracks step wall-times; flags stragglers beyond k x median.

    Samples live in the ``watchdog.step_seconds`` histogram on the
    default registry (``.histogram`` — exported by obs.report(), JSONL
    and Prometheus like every other metric); the straggler threshold
    uses its bucket-interpolated p50. ``name`` labels the series
    (default: a fresh ``wd<N>`` per instance).
    """
    straggler_factor: float = 3.0
    name: Optional[str] = None
    min_samples: int = 5
    _t0: Optional[float] = None
    stragglers: int = 0

    def __post_init__(self):
        if self.name is None:
            self.name = f"wd{next(_WD_IDS)}"

    @property
    def histogram(self) -> Histogram:
        """The step-time samples (seconds) of this watchdog."""
        return default_registry().histogram("watchdog.step_seconds",
                                            watchdog=self.name)

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        dt = time.monotonic() - self._t0
        h = self.histogram
        h.record(dt)
        if (h.count > self.min_samples
                and dt > self.straggler_factor * h.percentile(0.5)):
            self.stragglers += 1
            default_registry().counter("watchdog.stragglers",
                                       watchdog=self.name).inc()
        return dt

    @property
    def median(self) -> float:
        return self.histogram.percentile(0.5)


class PreemptionHandler:
    """SIGTERM -> request a final checkpoint and a clean exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGUSR1, self._handler)
            except ValueError:
                pass  # not the main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def request(self):  # programmatic (tests / chaos)
        self.requested = True


def run_with_restarts(make_run: Callable[[], int], max_restarts: int = 3
                      ) -> int:
    """Supervisor: call ``make_run`` (which resumes from the latest
    checkpoint internally) until it returns, restarting on failures.

    Returns the final step. ``make_run`` must be idempotent-from-
    checkpoint — with the stateless data pipeline and bit-exact restore
    this makes the whole trajectory restart-invariant (tested).

    Restarts count on the default registry's ``fault.restarts`` counter
    (the process-lifetime total a supervisor dashboard wants); the
    per-invocation give-up bound is the delta against the counter value
    at entry."""
    counter = default_registry().counter("fault.restarts")
    start = counter.value
    while True:
        try:
            return make_run()
        except SimulatedFailure:
            counter.inc()
            if counter.value - start > max_restarts:
                raise
