"""Fault-tolerance runtime: step watchdog (straggler + hang detection),
preemption handling, a restart supervisor with backoff, and the chaos
harness that proves all of it works.

At 1000+ nodes the failure model is: frequent single-host preemptions
(handled by checkpoint/restart — the supervisor here), slow hosts
(watchdog surfaces p95 outliers so the scheduler can cordon them), hung
kernels (watchdog hang threshold -> engine restart), and rare corrupt
saves (caught by the manager's per-leaf checksums, which fall back to
the previous intact step). ``FaultInjector`` turns each of those
failure classes into a *scriptable* event so the serving layer
(``repro.serving``) can be exercised against the full chaos matrix in
CI — see DESIGN.md Section 8. The shard-aware kinds (per-shard
exception, stalled fused launch, device loss, corrupted halo band,
damaged distributed checkpoint) extend the same plan format to the
elastic distributed runner (``repro.core.elastic``) — DESIGN.md
Section 9.

Accounting lives on the telemetry registry (``repro.obs``): the
watchdog's step times land in a ``watchdog.step_seconds`` histogram
(one labeled series per watchdog — the bespoke ring buffer of samples
is gone), straggler fires count ``watchdog.stragglers``, hang fires
count ``watchdog.hangs``, and the restart supervisor counts
``fault.restarts``. These record regardless of the
``SQUEEZE_TELEMETRY`` toggle: constructing a watchdog or a supervisor
IS the opt-in, and both are control-flow state (the straggler median
and the give-up bound read them back), not optional telemetry.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import random
import signal
import threading
import time
from typing import (Callable, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.obs import Histogram, default_registry


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to emulate a mid-run crash."""


class InjectedFault(SimulatedFailure):
    """A fault raised by :class:`FaultInjector` (transient by contract:
    supervisors retry it)."""


class DeviceLostError(SimulatedFailure):
    """A shard's device is gone (injected by the chaos harness;
    unrecoverable on the current mesh by contract — the elastic runner
    responds by resharding onto fewer devices)."""

    def __init__(self, msg: str, shard: int = 0):
        super().__init__(msg)
        self.shard = shard


class HaloCorruptionError(SimulatedFailure):
    """A post-launch state integrity check failed: cells the occupancy
    mask says are dead (fractal holes, padding blocks) came back
    nonzero — the signature of a damaged halo band / edge strip.
    Transient: supervisors restore the newest intact checkpoint."""


#: distinct default label per Watchdog instance, so two watchdogs (e.g.
#: successive train() calls in one process) never mix their step-time
#: distributions — the straggler median must see only its own steps
_WD_IDS = itertools.count()


@dataclasses.dataclass
class Watchdog:
    """Tracks step wall-times; flags stragglers beyond k x median and
    carries the hang threshold a supervisor enforces.

    Samples live in the ``watchdog.step_seconds`` histogram on the
    default registry (``.histogram`` — exported by obs.report(), JSONL
    and Prometheus like every other metric); the straggler threshold
    uses its bucket-interpolated p50. ``name`` labels the series
    (default: a fresh ``wd<N>`` per instance).

    Stragglers are detected *post hoc* (the step returned, just
    slowly). A hang never returns, so it cannot be detected here — the
    supervisor must bound the step's wall time externally
    (``asyncio.wait_for`` in the serving layer) using
    ``hang_threshold_s`` and report the kill via :meth:`flag_hang`.
    """
    straggler_factor: float = 3.0
    name: Optional[str] = None
    min_samples: int = 5
    #: wall-time bound a supervisor applies to one step/segment; None
    #: disables hang detection (nothing in this class sleeps or waits)
    hang_threshold_s: Optional[float] = None
    _t0: Optional[float] = None
    stragglers: int = 0
    hangs: int = 0

    def __post_init__(self):
        if self.name is None:
            self.name = f"wd{next(_WD_IDS)}"

    @property
    def histogram(self) -> Histogram:
        """The step-time samples (seconds) of this watchdog."""
        return default_registry().histogram("watchdog.step_seconds",
                                            watchdog=self.name)

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        dt = time.monotonic() - self._t0
        h = self.histogram
        h.record(dt)
        if (h.count > self.min_samples
                and dt > self.straggler_factor * h.percentile(0.5)):
            self.stragglers += 1
            default_registry().counter("watchdog.stragglers",
                                       watchdog=self.name).inc()
        return dt

    def flag_hang(self) -> None:
        """Record a supervisor-detected hang (the step exceeded
        ``hang_threshold_s`` and was abandoned/killed)."""
        self.hangs += 1
        default_registry().counter("watchdog.hangs",
                                   watchdog=self.name).inc()

    @property
    def median(self) -> float:
        return self.histogram.percentile(0.5)


class PreemptionHandler:
    """SIGTERM -> request a final checkpoint and a clean exit.

    Installing replaces the process's SIGTERM/SIGUSR1 handlers; the
    originals are kept and restored by :meth:`uninstall` (also the
    context-manager exit), so a scoped handler — one serve() call, one
    test — cannot leak its trap into the rest of the process.

    Handlers NEST: when the serving layer has one installed and an
    elastic distributed run installs another, the inner handler chains
    delivery to the saved outer handler (both see the signal), and
    :meth:`uninstall` restores a signal only while this instance's trap
    is still the live one — an out-of-order uninstall (outer first)
    leaves the inner trap untouched instead of clobbering it (the outer
    instance forfeits its restore; the inner's eventual uninstall
    re-installs the outer's trap function, which is harmless: it only
    sets a flag on the already-dismissed outer instance).
    """

    _SIGNALS = (signal.SIGTERM, signal.SIGUSR1)

    def __init__(self, install: bool = True):
        self.requested = False
        # ONE bound-method object: `self._handler` creates a fresh
        # bound method per attribute access, so the is-our-trap-live
        # identity check in uninstall() needs a stable reference
        self._trap = self._handler
        self._previous: List[Tuple[int, object]] = []
        if install:
            self.install()

    def install(self) -> None:
        if self._previous:
            return  # already installed
        for sig in self._SIGNALS:
            try:
                prev = signal.signal(sig, self._trap)
            except ValueError:
                break  # not the main thread (tests)
            self._previous.append((sig, prev))

    def uninstall(self) -> None:
        """Restore the signal handlers that were active before
        :meth:`install` (no-op if never installed). A signal whose live
        handler is no longer ours (a nested handler installed on top)
        is left alone — see the class docstring."""
        while self._previous:
            sig, prev = self._previous.pop()
            try:
                if signal.getsignal(sig) is not self._trap:
                    continue  # nested handler on top: don't clobber it
                signal.signal(sig, prev)
            except (ValueError, TypeError):
                pass

    def __enter__(self) -> "PreemptionHandler":
        return self

    def __exit__(self, *exc) -> bool:
        self.uninstall()
        return False

    def _handler(self, signum, frame):
        self.requested = True
        # chain to the handler we displaced so an outer
        # PreemptionHandler (or any user trap) also sees the signal
        for sig, prev in self._previous:
            if sig == signum and callable(prev):
                prev(signum, frame)
                break

    def request(self):  # programmatic (tests / chaos)
        self.requested = True


# --------------------------------------------------------------- backoff
def backoff_delays(base_s: float = 0.05, cap_s: float = 1.0,
                   factor: float = 2.0, seed: int = 0
                   ) -> Iterator[float]:
    """Exponential backoff with deterministic full jitter.

    Yields ``uniform(base/2, base) * factor**attempt`` capped at
    ``cap_s``, from a private ``random.Random(seed)`` — two supervisors
    with the same seed sleep the same schedule (testable), two with
    different seeds decorrelate (no thundering-herd retry alignment).
    """
    rng = random.Random(seed)
    attempt = 0
    while True:
        raw = min(cap_s, base_s * (factor ** attempt))
        yield raw * (0.5 + 0.5 * rng.random())
        attempt += 1


def run_with_restarts(make_run: Callable[[], int], max_restarts: int = 3,
                      backoff_base_s: float = 0.05,
                      backoff_cap_s: float = 1.0,
                      backoff_seed: int = 0,
                      max_elapsed_s: Optional[float] = None,
                      _sleep: Callable[[float], None] = time.sleep) -> int:
    """Supervisor: call ``make_run`` (which resumes from the latest
    checkpoint internally) until it returns, restarting on failures.

    Returns the final step. ``make_run`` must be idempotent-from-
    checkpoint — with the stateless data pipeline and bit-exact restore
    this makes the whole trajectory restart-invariant (tested).

    Each restart sleeps an exponentially growing, deterministically
    jittered delay (:func:`backoff_delays`; ``backoff_base_s=0``
    restarts immediately). Gives up — re-raising the failure — after
    ``max_restarts`` restarts or once ``max_elapsed_s`` of wall time
    has passed (whichever comes first), so a crash-looping job cannot
    hold its resources forever.

    Restarts count on the default registry's ``fault.restarts`` counter
    (the process-lifetime total a supervisor dashboard wants); the
    per-invocation give-up bound is the delta against the counter value
    at entry. ``_sleep`` is injectable so tests can assert the delay
    schedule without waiting it out."""
    counter = default_registry().counter("fault.restarts")
    start = counter.value
    t0 = time.monotonic()
    delays = backoff_delays(backoff_base_s, backoff_cap_s,
                            seed=backoff_seed)
    while True:
        try:
            return make_run()
        except SimulatedFailure:
            counter.inc()
            if counter.value - start > max_restarts:
                raise
            if (max_elapsed_s is not None
                    and time.monotonic() - t0 >= max_elapsed_s):
                raise
            delay = next(delays)
            if delay > 0:
                _sleep(delay)


# --------------------------------------------------------- chaos harness
@dataclasses.dataclass
class Fault:
    """One scheduled fault. ``at_segment`` indexes the supervisor's
    monotone event counter — the service's global segment counter, or
    the elastic distributed runner's launch counter — so a chaos plan
    is reproducible run to run.

    Serving-layer kinds:
      * ``exception``  — raise :class:`InjectedFault` in the worker
        thread right before the segment's XLA dispatch (the in-step
        crash class);
      * ``stall``      — sleep ``stall_s`` in the worker thread (past
        the watchdog hang threshold -> supervisor kills + restarts the
        engine);
      * ``preempt``    — deliver SIGTERM (``via_signal=True``, needs an
        installed handler) or call ``handler.request()`` directly: the
        service drains in-flight batches, checkpoints, sheds the rest;
      * ``corrupt``    — flip bytes in the newest checkpoint leaf of
        ``target_rid`` (or the next checkpoint saved) so the next
        restore must fall back to the previous intact step;
      * ``truncate``   — same, but truncate the leaf file instead.

    Shard-aware (distributed) kinds, fired at the elastic runner's
    :meth:`FaultInjector.in_launch` / :meth:`FaultInjector.corrupt_halo`
    hooks:
      * ``shard_exception`` — raise :class:`InjectedFault` on shard
        ``shard`` right before a fused launch (transient: the runner
        restores the newest intact checkpoint and retries);
      * ``shard_stall``     — sleep ``stall_s`` inside the launch (past
        the launch timeout -> the runner abandons the launch, rebuilds
        the engine, restores, retries);
      * ``device_loss``     — raise :class:`DeviceLostError` for shard
        ``shard`` (unrecoverable on the current mesh: the runner
        performs an elastic reshard onto fewer devices);
      * ``halo_corrupt``    — poison the edge bands of shard ``shard``'s
        block tiles in the freshly-launched state (``band_rows`` rows
        per tile; 0 = the whole tile), simulating a damaged halo strip
        gather. Detection relies on the mask-discipline invariant
        (fractal-hole and padding cells must stay zero), which
        whole-tile poison always violates for a true fractal;
      * ``strip_drop``      — a neighbor strip send from shard ``shard``
        is lost in flight, aborting the p2p exchange: raises
        :class:`InjectedFault` at the launch hook (transient — the
        runner restores the newest intact checkpoint and relaunches,
        re-issuing the permutes);
      * ``strip_corrupt``   — a RECEIVED neighbor strip was damaged on
        the wire: poisons the top and bottom ``band_rows`` rows (the
        rows a neighbor's edge strip feeds; 0 = depth 1) of shard
        ``shard``'s tiles post-launch. Caught by the same dead-cell
        integrity check as ``halo_corrupt`` -> checkpoint restore,
        bit-exact on either exchange path.
    """

    kind: str
    at_segment: int = 0
    stall_s: float = 0.0
    via_signal: bool = False
    target_rid: Optional[str] = None
    shard: int = 0
    band_rows: int = 0
    fired: bool = False

    _KINDS = ("exception", "stall", "preempt", "corrupt", "truncate",
              "shard_exception", "shard_stall", "device_loss",
              "halo_corrupt", "strip_drop", "strip_corrupt")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {self._KINDS}")


class FaultInjector:
    """Chaos harness: a scripted plan of :class:`Fault`\\ s fired at the
    serving layer's and the elastic distributed runner's hook points.

    The service calls three hooks:

      * :meth:`in_step` — from the WORKER thread, immediately before a
        segment's dispatch (exception / stall fire here, so a stall
        really does block the step the watchdog is timing);
      * :meth:`at_boundary` — from the scheduler, between segments
        (preempt fires here; a real SIGTERM round-trips through the
        installed :class:`PreemptionHandler`);
      * :meth:`on_checkpoint` — after every durable checkpoint save
        (corrupt / truncate damage the just-written files on disk).

    The elastic distributed runner adds two (its launch counter plays
    the role of the segment index):

      * :meth:`in_launch` — right before a fused launch's dispatch
        (shard_stall / device_loss / shard_exception fire here);
      * :meth:`corrupt_halo` — on the host copy of a freshly-launched
        state (halo_corrupt poisons one shard's tiles).

    Every fired fault appends ``(segment, kind, detail)`` to ``.log``
    and counts ``chaos.injected{kind=...}`` on the default registry, so
    a chaos run's injected-vs-recovered arithmetic is checkable from
    telemetry alone.

    Thread safety: hooks fire concurrently from the serving layer's
    executor threads (and the elastic runner's launch threads). The
    fire-once claim — scan for due faults, mark them fired, log, count
    — is atomic under an internal lock, so a fault scheduled once fires
    exactly once no matter how many threads hit its hook in the same
    segment. Side effects (sleeping, raising, damaging files) run
    outside the lock.
    """

    def __init__(self, faults: Sequence[Fault] = (),
                 handler: Optional[PreemptionHandler] = None):
        self.faults = list(faults)
        self.handler = handler
        self.log: List[Tuple[int, str, str]] = []
        self._lock = threading.Lock()

    def _claim(self, segment: int, kinds: Tuple[str, ...],
               pred: Optional[Callable[[Fault], bool]] = None
               ) -> List[Fault]:
        """Atomically claim (mark fired) every due fault of ``kinds``.
        The caller records and executes each claimed fault's effect
        outside the lock."""
        with self._lock:
            due = [f for f in self.faults
                   if not f.fired and f.kind in kinds
                   and f.at_segment <= segment
                   and (pred is None or pred(f))]
            for f in due:
                f.fired = True
            return due

    def _record(self, fault: Fault, segment: int,
                detail: str = "") -> None:
        with self._lock:
            self.log.append((segment, fault.kind, detail))
        default_registry().counter("chaos.injected",
                                   kind=fault.kind).inc()

    # ------------------------------------------------------------- hooks
    def in_step(self, segment: int) -> None:
        """Worker-thread hook, right before the segment's dispatch."""
        for f in self._claim(segment, ("stall",)):
            self._record(f, segment, f"stall {f.stall_s}s")
            time.sleep(f.stall_s)
        for f in self._claim(segment, ("exception",)):
            self._record(f, segment, "raise")
            raise InjectedFault(
                f"injected in-step failure at segment {segment}")

    def at_boundary(self, segment: int) -> None:
        """Scheduler hook, between segments (main thread)."""
        for f in self._claim(segment, ("preempt",)):
            self._record(f, segment,
                         "SIGTERM" if f.via_signal else "request()")
            if f.via_signal:
                os.kill(os.getpid(), signal.SIGTERM)
            elif self.handler is not None:
                self.handler.request()
            else:
                raise RuntimeError(
                    "preempt fault needs via_signal=True or a handler")

    def on_checkpoint(self, rid: str, path: str, segment: int = 0) -> None:
        """Post-save hook: damage the files of the checkpoint at
        ``path`` (a ``step_XXXXXXXX`` directory)."""
        pred = (lambda f: f.target_rid is None or f.target_rid == rid)
        for f in self._claim(segment, ("corrupt", "truncate"), pred):
            n = damage_checkpoint(path, mode=f.kind)
            self._record(f, segment, f"{f.kind} {n} file(s) in {path}")

    # ------------------------------------------- distributed chaos hooks
    def in_launch(self, launch: int) -> None:
        """Elastic-runner hook, right before a fused launch's dispatch
        (runs inside the launch thread, so a stall really blocks the
        launch the timeout watchdog is bounding)."""
        for f in self._claim(launch, ("shard_stall",)):
            self._record(f, launch, f"stall {f.stall_s}s")
            time.sleep(f.stall_s)
        for f in self._claim(launch, ("device_loss",)):
            self._record(f, launch, f"device lost on shard {f.shard}")
            raise DeviceLostError(
                f"injected device loss on shard {f.shard} "
                f"at launch {launch}", shard=f.shard)
        for f in self._claim(launch, ("shard_exception",)):
            self._record(f, launch, f"raise on shard {f.shard}")
            raise InjectedFault(
                f"injected shard failure on shard {f.shard} "
                f"at launch {launch}")
        for f in self._claim(launch, ("strip_drop",)):
            self._record(f, launch,
                         f"dropped neighbor strip from shard {f.shard}")
            raise InjectedFault(
                f"injected dropped neighbor-strip send from shard "
                f"{f.shard} at launch {launch}: halo exchange aborted")

    def corrupt_halo(self, launch: int, state: np.ndarray,
                     nb_local: int) -> Tuple[np.ndarray, bool]:
        """Post-launch hook: poison the edge bands of the due faults'
        target shards in a host copy of ``state`` (last three axes
        (nb, rho, rho); ``nb_local`` blocks per shard). Returns
        ``(state, poisoned)`` — the original array untouched when no
        halo_corrupt fault is due."""
        due = self._claim(launch, ("halo_corrupt", "strip_corrupt"))
        if not due:
            return state, False
        state = np.array(state, copy=True)
        for f in due:
            lo = f.shard * nb_local
            blocks = state[..., lo:lo + nb_local, :, :]
            if f.kind == "strip_corrupt":
                # a damaged neighbor strip feeds the receiving blocks'
                # outermost rows: poison both row bands of the shard
                rows = max(1, f.band_rows)
                blocks[..., :rows, :] = np.asarray(127, state.dtype)
                blocks[..., -rows:, :] = np.asarray(127, state.dtype)
                detail = (f"poisoned {rows} strip band row(s) of "
                          f"shard {f.shard}'s tiles")
            else:
                rows = f.band_rows if f.band_rows > 0 \
                    else blocks.shape[-2]
                blocks[..., :rows, :] = np.asarray(127, state.dtype)
                detail = (f"poisoned {rows} row(s) of shard "
                          f"{f.shard}'s tiles")
            self._record(f, launch, detail)
        return state, True

    # ----------------------------------------------------------- queries
    def pending(self) -> List[Fault]:
        with self._lock:
            return [f for f in self.faults if not f.fired]

    def all_fired(self) -> bool:
        return not self.pending()


def damage_checkpoint(path: str, mode: str = "corrupt") -> int:
    """Corrupt (bit-flip) or truncate every ``.npy`` leaf under the
    checkpoint directory ``path``. Returns the number of files damaged.
    Used by the chaos harness and directly by tests."""
    damaged = 0
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".npy"):
            continue
        fp = os.path.join(path, fn)
        if mode == "truncate":
            size = os.path.getsize(fp)
            with open(fp, "r+b") as f:
                f.truncate(max(0, size // 2))
        else:
            with open(fp, "r+b") as f:
                f.seek(-1, os.SEEK_END)
                last = f.read(1)
                f.seek(-1, os.SEEK_END)
                f.write(bytes([last[0] ^ 0xFF]))
        damaged += 1
    return damaged
