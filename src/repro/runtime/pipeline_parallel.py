"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis in
the multi-pod mesh): stage-sharded layer stacks, microbatched schedule,
activations forwarded with lax.ppermute.

This is the optional PP mode — the default distribution is DP x TP/FSDP
(launch/mesh.py); PP is exercised by its own test and available for
pipelining a layer stack across pods where cross-pod bandwidth (DCI) is
much lower than ICI: PP exchanges only (microbatch, seq, d_model)
activations per tick instead of full gradients.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jnp.ndarray


def pipeline_apply(stage_fn: Callable, stage_params, x: Array, *,
                   mesh: Mesh, axis: str, n_microbatches: int) -> Array:
    """Run ``y = stage_P-1( ... stage_0(x))`` with GPipe microbatching.

    stage_params: pytree whose leaves have leading dim P (stage-sharded
    over ``axis``); stage_fn(params_stage, x_mb) -> y_mb, same shape.
    x: (B, ...) with B % n_microbatches == 0. Output is replicated.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches

    def per_stage(params_stage, x_all):
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_microbatches + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        mbs = x_all.reshape(n_microbatches, mb, *x_all.shape[1:])
        # mark the loop carries as pod-varying up front (each stage holds
        # different values), else the fori carry types mismatch
        from repro.utils.jax_compat import pvary
        carry_in = pvary(jnp.zeros_like(mbs[0]), (axis,))
        outputs = pvary(jnp.zeros_like(mbs), (axis,))

        def tick(t, state):
            carry, outs = state
            # stage 0 injects microbatch t (if still available)
            inject = mbs[jnp.minimum(t, n_microbatches - 1)]
            inp = jnp.where(stage == 0, inject, carry)
            out = stage_fn(params_stage, inp)
            # last stage commits finished microbatch t - (P-1)
            done_idx = t - (n_stages - 1)
            commit = (stage == n_stages - 1) & (done_idx >= 0)
            updated = jax.lax.dynamic_update_index_in_dim(
                outs, out, jnp.maximum(done_idx, 0), 0)
            outs = jnp.where(commit, updated, outs)
            # forward activations to the next stage
            carry = jax.lax.ppermute(out, axis, fwd_perm)
            return carry, outs

        _, outputs = jax.lax.fori_loop(0, n_ticks, tick,
                                       (carry_in, outputs))
        # replicate final outputs from the last stage to all stages
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, 0.0), axis)
        return outputs.reshape(b, *x_all.shape[1:])

    from repro.utils.jax_compat import shard_map
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P())
    return fn(stage_params, x)


def stack_stages(layer_params_stacked, n_stages: int):
    """Reshape (L, ...) stacked layer params into (P, L/P, ...) stages."""
    def r(a):
        nl = a.shape[0]
        assert nl % n_stages == 0, (nl, n_stages)
        return a.reshape(n_stages, nl // n_stages, *a.shape[1:])
    return jax.tree.map(r, layer_params_stacked)
