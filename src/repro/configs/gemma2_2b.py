"""gemma2-2b [arXiv:2408.00118; hf] — 26L d_model=2304 8H (GQA kv=4)
d_ff=9216 vocab=256000; local(4096)+global alternating attention, logit
softcapping (attn 50, final 30), pre+post block norms, GeGLU, tied
embeddings scaled by sqrt(d)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    unit=(LayerSpec(kind="attn", window=4096),   # local
          LayerSpec(kind="attn")),               # global
    n_units=13,
    mlp_kind="geglu",
    post_norms=True,
    tie_embeddings=True,
    emb_scale=True,
    logit_softcap=30.0,
    attn_softcap=50.0,
)
