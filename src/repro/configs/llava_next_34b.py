"""llava-next-34b [hf:llava-hf/llava-v1.6; backbone only] — 60L
d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. The anyres vision
tower is a STUB: input_specs() provides precomputed patch embeddings
(B, 576, d) prepended to the text tokens."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    unit=(LayerSpec(kind="attn"),),
    n_units=60,
    mlp_kind="swiglu",
    n_patches=576,        # anyres base grid (24x24), stubbed
    rope_theta=1e6,
)
