"""recurrentgemma-9b [arXiv:2402.19427] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000; Griffin RG-LRU : local-attention in a 2:1 pattern
(38 = 12 x (rec, rec, attn) + 2-rec tail), local window 2048."""
from repro.models.config import LayerSpec, ModelConfig, RecSpec

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    unit=(LayerSpec(kind="rec"), LayerSpec(kind="rec"),
          LayerSpec(kind="attn", window=2048)),
    n_units=12,
    tail=(LayerSpec(kind="rec"), LayerSpec(kind="rec")),
    mlp_kind="geglu",
    emb_scale=True,
    rec=RecSpec(conv_width=4, d_rec=None),
)
