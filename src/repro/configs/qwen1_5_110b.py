"""qwen1.5-110b [hf:Qwen/Qwen1.5-0.5B family; hf] — 80L d_model=8192 64H
(GQA kv=8) d_ff=49152 vocab=152064, QKV bias."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab=152064,
    unit=(LayerSpec(kind="attn"),),
    n_units=80,
    mlp_kind="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)
