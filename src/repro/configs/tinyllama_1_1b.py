"""tinyllama-1.1b [arXiv:2401.02385; hf] — 22L d_model=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000, llama2 architecture."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab=32000,
    unit=(LayerSpec(kind="attn"),),
    n_units=22,
    mlp_kind="swiglu",
)
