"""mixtral-8x22b [arXiv:2401.04088; hf] — 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768, MoE 8 experts top-2, sliding-window attention."""
from repro.models.config import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,           # (expert hidden; dense d_ff unused under MoE)
    vocab=32768,
    unit=(LayerSpec(kind="attn", window=4096),),   # SWA (Mistral heritage)
    n_units=56,
    mlp_kind="swiglu",
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384),
    rope_theta=1e6,
)
