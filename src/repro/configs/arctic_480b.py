"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf] — 35L d_model=7168
56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2 + dense
residual MLP (Arctic's dense-MoE hybrid: experts run in parallel with a
persistent dense FFN)."""
from repro.models.config import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="arctic-480b",
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    unit=(LayerSpec(kind="attn"),),                # full attention
    n_units=35,
    mlp_kind="swiglu",
    moe=MoESpec(n_experts=128, top_k=2, d_ff_expert=4864,
                dense_residual_ff=4864),
)
