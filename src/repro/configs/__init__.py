"""Architecture registry: the 10 assigned archs (+ the paper's own
Sierpinski case study). ``get_config(arch_id)`` returns the exact full
config; ``get_smoke_config`` the reduced CPU-runnable one."""
from __future__ import annotations

import importlib

from repro.configs.common import SHAPES, reduced
from repro.models.config import ModelConfig

#: arch id -> module name
ARCHS = {
    "mixtral-8x22b": "mixtral_8x22b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-780m": "mamba2_780m",
    "whisper-small": "whisper_small",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma2-2b": "gemma2_2b",
    "smollm-135m": "smollm_135m",
    "llava-next-34b": "llava_next_34b",
}

#: long_500k policy: sub-quadratic archs only
LONG_CONTEXT_ARCHS = ("mixtral-8x22b", "recurrentgemma-9b", "mamba2-780m",
                      "gemma2-2b")

#: enc-dec archs have no 32k self-decode in the usual sense; shapes are
#: applied to the decoder backbone generically (frontend stubbed)
ALL_ARCHS = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


def cells(include_skipped: bool = False):
    """The assigned (arch x shape) matrix — 40 cells; long_500k cells for
    pure full-attention archs are skipped per the assignment."""
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            skipped = (shape == "long_500k"
                       and arch not in LONG_CONTEXT_ARCHS)
            if skipped and not include_skipped:
                continue
            yield arch, shape, skipped


__all__ = ["ARCHS", "ALL_ARCHS", "LONG_CONTEXT_ARCHS", "SHAPES", "cells",
           "get_config", "get_smoke_config", "reduced"]
