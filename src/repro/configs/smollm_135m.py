"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf] — 30L d_model=576 9H
(GQA kv=3) d_ff=1536 vocab=49152, llama architecture. Also the end-to-end
training example target (examples/train_lm.py)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    unit=(LayerSpec(kind="attn"),),
    n_units=30,
    mlp_kind="swiglu",
    tie_embeddings=True,
)
