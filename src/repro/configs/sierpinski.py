"""The paper's own case study: Conway's game of life on a discrete
Sierpinski triangle F^{3,2} in compact space (Squeeze engine). This is a
fractal-simulation config, not an LM config — see core/ and examples/."""
import dataclasses

from repro.core.fractals import SIERPINSKI


@dataclasses.dataclass(frozen=True)
class FractalConfig:
    fractal: str = "sierpinski"
    r: int = 10            # level (n = 2^r); paper sweeps r in [0, 20]
    m: int = 4             # block level: rho = s^m = 16 (paper's best)
    steps: int = 1000      # paper: 1000 iterations per run
    engine: str = "block"  # "bb" | "lambda" | "cell" | "block"


CONFIG = FractalConfig()
FRACTAL = SIERPINSKI
