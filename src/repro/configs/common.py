"""Config helpers: the smoke-test reducer and the input-shape table.

The FULL configs (exact per the assignment) are exercised only via the
dry-run (ShapeDtypeStruct, no allocation); smoke tests run ``reduced()``
versions of the same family on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import LayerSpec, ModelConfig, MoESpec, SSMSpec


#: assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-runnable smoke config of the same
    family (same layer kinds / unit structure / flavor knobs)."""
    kv = 1 if cfg.n_kv_heads == 1 else 2
    moe = None
    if cfg.moe is not None:
        moe = MoESpec(
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            capacity_factor=cfg.moe.capacity_factor,
            dense_residual_ff=64 if cfg.moe.dense_residual_ff else None,
            aux_loss_weight=cfg.moe.aux_loss_weight)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab=512,
        n_units=min(cfg.n_units, 2),
        n_enc_units=min(cfg.n_enc_units, 2),
        enc_seq=16 if cfg.n_enc_units else cfg.enc_seq,
        n_patches=8 if cfg.n_patches else 0,
        moe=moe,
        ssm=SSMSpec(d_state=16, head_dim=16, expand=2, chunk=8,
                    conv_width=cfg.ssm.conv_width,
                    n_groups=cfg.ssm.n_groups),
        unit=tuple(_shrink_spec(s) for s in cfg.unit),
        tail=tuple(_shrink_spec(s) for s in cfg.tail),
        max_seq=4096,
        dtype="float32",  # exactness on CPU smoke runs
        remat="none",
    )


def _shrink_spec(s: LayerSpec) -> LayerSpec:
    return LayerSpec(kind=s.kind,
                     window=8 if s.window is not None else None)
