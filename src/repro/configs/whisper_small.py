"""whisper-small [arXiv:2212.04356] — enc-dec, 12L encoder + 12L decoder,
d_model=768 12H (kv=12) d_ff=3072 vocab=51865. The conv/mel frontend is a
STUB: input_specs() provides precomputed frame embeddings (B, 1500, d)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    unit=(LayerSpec(kind="attn"),),    # decoder self-attn layers
    n_units=12,
    n_enc_units=12,
    enc_seq=1500,                      # 30 s of audio at 50 Hz
    mlp_kind="gelu",
    norm="ln",
    pos_embed="learned",
    qkv_bias=True,
    max_seq=65536,
)
