"""mamba2-780m [arXiv:2405.21060] — 48L d_model=1536 attention-free,
vocab=50280, SSD (state-space duality) with ssm_state=128."""
from repro.models.config import LayerSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-780m",
    d_model=1536,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,               # mixer-only layers, no FFN
    vocab=50280,
    unit=(LayerSpec(kind="ssm"),),
    n_units=48,
    tie_embeddings=True,
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, chunk=128,
                conv_width=4, n_groups=1),
)
