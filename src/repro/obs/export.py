"""Exporters for the telemetry registry: JSONL event log, Prometheus
text format, and the human ``report()`` table.

  * JSONL — one JSON object per line (counters/gauges/histograms, then
    completed span trees). ``load_jsonl`` round-trips the metrics back
    into a fresh ``MetricsRegistry`` (asserted by the tests), so the
    event log doubles as a snapshot format for the CI gate reports.
  * Prometheus — ``# TYPE``-annotated text exposition (histograms as
    cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``), scrapeable
    as-is.
  * ``report()`` — one aligned row per metric (counters/gauges: value;
    histograms: count / mean / p50 / p95 / max), the "where did this
    run spend its time, bytes and collectives" answer in one call.
"""
from __future__ import annotations

import json
import re
from typing import List, Optional

from repro.obs import trace as _trace
from repro.obs.registry import (Histogram, MetricsRegistry,
                                default_registry)

_PROM_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _reg(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    return default_registry() if registry is None else registry


# ------------------------------------------------------------------ JSONL
def to_jsonl(registry: Optional[MetricsRegistry] = None,
             include_spans: bool = True) -> str:
    """One JSON object per line: every metric snapshot, then every
    completed root span tree."""
    lines = [json.dumps(m.snapshot(), sort_keys=True)
             for m in _reg(registry).metrics()]
    if include_spans:
        lines += [json.dumps(s.snapshot(), sort_keys=True)
                  for s in _trace.spans()]
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(path: str,
                registry: Optional[MetricsRegistry] = None,
                include_spans: bool = True) -> str:
    with open(path, "w") as f:
        f.write(to_jsonl(registry, include_spans=include_spans))
    return path


def load_jsonl(text: str) -> MetricsRegistry:
    """Rebuild a registry from a JSONL dump (span lines are ignored —
    spans are events, not state). Metric values round-trip exactly."""
    reg = MetricsRegistry()
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        kind, labels = d.get("type"), d.get("labels", {})
        if kind == "counter":
            reg.counter(d["name"], **labels).inc(d["value"])
        elif kind == "gauge":
            reg.gauge(d["name"], **labels).set(d["value"])
        elif kind == "histogram":
            h = reg.histogram(d["name"], buckets=d["bounds"], **labels)
            h.bucket_counts = list(d["bucket_counts"])
            h.count = d["count"]
            h.sum = d["sum"]
            h.min = d["min"]
            h.max = d["max"]
    return reg


# ------------------------------------------------------------- Prometheus
def _prom_name(name: str, prefix: str) -> str:
    return prefix + _PROM_BAD.sub("_", name)


def _prom_labels(labels: dict, extra: str = "") -> str:
    parts = [f'{_PROM_BAD.sub("_", k)}="{v}"'
             for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus(registry: Optional[MetricsRegistry] = None,
                  prefix: str = "squeeze_") -> str:
    """Prometheus text exposition of every metric in the registry."""
    out: List[str] = []
    seen_types = set()
    for m in _reg(registry).metrics():
        name = _prom_name(m.name, prefix)
        if name not in seen_types:
            seen_types.add(name)
            out.append(f"# TYPE {name} {m.kind}")
        if isinstance(m, Histogram):
            cum = 0
            snap = m.snapshot()
            for le, c in zip(list(snap["bounds"]) + ["+Inf"],
                             snap["bucket_counts"]):
                cum += c
                out.append(f"{name}_bucket"
                           + _prom_labels(m.labels_dict, f'le="{le}"')
                           + f" {cum}")
            out.append(f"{name}_sum{_prom_labels(m.labels_dict)}"
                       f" {snap['sum']}")
            out.append(f"{name}_count{_prom_labels(m.labels_dict)}"
                       f" {snap['count']}")
        else:
            out.append(f"{name}{_prom_labels(m.labels_dict)} {m.value}")
    return "\n".join(out) + ("\n" if out else "")


# ----------------------------------------------------------------- report
def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def report(registry: Optional[MetricsRegistry] = None) -> str:
    """Aligned text table of every metric, sorted by name — counters and
    gauges as one value, histograms as count/mean/p50/p95/max."""
    rows = []
    for m in sorted(_reg(registry).metrics(),
                    key=lambda m: (m.name, m.labels)):
        series = m.name + (
            "{" + ",".join(f"{k}={v}" for k, v in m.labels) + "}"
            if m.labels else "")
        if isinstance(m, Histogram):
            val = (f"count={m.count} mean={_fmt(m.mean)} "
                   f"p50={_fmt(m.percentile(0.5))} "
                   f"p95={_fmt(m.percentile(0.95))} max={_fmt(m.max)}")
        else:
            val = _fmt(m.value)
        rows.append((m.kind, series, val))
    if not rows:
        return "(telemetry: no metrics recorded)"
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    return "\n".join(f"{k:<{w0}}  {s:<{w1}}  {v}" for k, s, v in rows)
