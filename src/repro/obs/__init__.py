"""Unified telemetry for the Squeeze stack: metrics, spans, exporters.

One import serves every instrumented call site::

    from repro import obs

    obs.inc("runner.cache.hit", kind="block")       # counter
    obs.set_gauge("engine.memory_bytes", n, kind=k) # gauge
    obs.observe("runner.run.seconds", dt, kind=k)   # histogram sample
    with obs.span("runner.run", kind=k):            # wall-time tree
        ...

Collection is OPT-IN: the ``SQUEEZE_TELEMETRY`` environment variable
("", "0", "off", "false", "no", "none" -> disabled; anything else ->
enabled) or ``obs.enable()`` / ``obs.disable()`` at runtime. When
disabled, every helper above is a bool check + early return and
``span`` returns a shared null context manager — instrumented hot
paths stay within 2% of the uninstrumented fast path (gated by
``benchmarks/workloads_bench.py --telemetry``).

Everything lands on the process-wide ``default_registry()`` (pass
``registry=`` to the exporters for a private one). Read it back with
``obs.report()`` (pretty table), ``obs.to_jsonl()`` / ``write_jsonl``
(event log, round-trips via ``load_jsonl``), ``obs.to_prometheus()``
(scrape text), or ``obs.chrome_trace()`` / ``write_chrome_trace``
(span trees for chrome://tracing / Perfetto; spans also enter
``jax.profiler.TraceAnnotation`` when jax is importable, so they show
up on real profiler captures).

``SQUEEZE_TELEMETRY_DUMP=<path>`` registers an atexit hook that writes
the final JSONL snapshot — how ``benchmarks/ci_gates.py`` captures a
telemetry snapshot from each gate subprocess. See DESIGN.md Section 7.
"""
from __future__ import annotations

import atexit
import os
import time
from contextlib import contextmanager

from repro.obs.registry import (  # noqa: F401  (public re-exports)
    DEFAULT_BUCKETS, Counter, Gauge, Histogram, MetricsRegistry,
    default_registry, disable, enable, enabled, parse_env)
from repro.obs.trace import (  # noqa: F401
    Span, chrome_trace, current_span, reset_spans, spans,
    write_chrome_trace)
from repro.obs.export import (  # noqa: F401
    load_jsonl, report, to_jsonl, to_prometheus, write_jsonl)


# ------------------------------------------------- gated fast-path helpers
def inc(name: str, n=1, **labels) -> None:
    """Increment a counter on the default registry (no-op if disabled)."""
    if enabled():
        default_registry().counter(name, **labels).inc(n)


def set_gauge(name: str, value, **labels) -> None:
    """Set a gauge on the default registry (no-op if disabled)."""
    if enabled():
        default_registry().gauge(name, **labels).set(value)


def observe(name: str, value, **labels) -> None:
    """Record a histogram sample on the default registry (no-op if
    disabled)."""
    if enabled():
        default_registry().histogram(name, **labels).record(value)


class _NullCtx:
    """Shared no-op context manager: the disabled-mode ``span``/``timed``
    return value (no allocation on the hot path)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


def span(name: str, **attrs):
    """A live ``Span`` when telemetry is enabled, the shared null
    context manager otherwise."""
    if not enabled():
        return _NULL
    return Span(name, attrs)


class _Timed:
    __slots__ = ("_name", "_labels", "_t0")

    def __init__(self, name, labels):
        self._name = name
        self._labels = labels

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        default_registry().histogram(
            self._name, **self._labels).record(
                time.perf_counter() - self._t0)
        return False


def timed(name: str, **labels):
    """Context manager recording elapsed seconds into a histogram
    (no-op if disabled)."""
    if not enabled():
        return _NULL
    return _Timed(name, labels)


def reset() -> None:
    """Zero every default-registry metric in place and drop completed
    spans (metric handles stay valid — safe mid-run)."""
    default_registry().reset()
    reset_spans()


@contextmanager
def enabled_scope(on: bool = True):
    """Temporarily force telemetry on/off (tests; restores on exit)."""
    prev = enabled()
    enable(on)
    try:
        yield default_registry()
    finally:
        enable(prev)


# ------------------------------------------------------------ atexit dump
_DUMP_PATH = os.environ.get("SQUEEZE_TELEMETRY_DUMP")
if _DUMP_PATH:  # pragma: no cover - exercised via ci_gates subprocesses
    def _dump_at_exit(path=_DUMP_PATH):
        try:
            write_jsonl(path)
        except Exception:
            pass  # never fail interpreter shutdown over telemetry

    atexit.register(_dump_at_exit)
