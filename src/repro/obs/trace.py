"""Wall-time span trees + Chrome-trace/Perfetto export.

``Span`` is a context manager recording ``perf_counter`` wall time; the
per-thread span stack links nested spans into a tree, and completed ROOT
spans accumulate in a bounded module buffer (``spans()``) from which
``chrome_trace()`` emits the Chrome ``traceEvents`` JSON that
chrome://tracing and Perfetto load directly.

When ``jax.profiler.TraceAnnotation`` is importable, every span also
enters one, so Squeeze spans show up on the device timeline of a real
``jax.profiler`` capture; without jax this module still works (the
annotation is a no-op).

The *gated* entry point is ``repro.obs.span`` — it returns a shared
null context manager when telemetry is disabled, so tracing costs one
bool check on disabled hot paths. Constructing a ``Span`` directly is
always live.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional, Tuple

try:  # optional: attach device-timeline annotations when jax is present
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax is installed in this repo
    _TraceAnnotation = None

#: completed root spans kept for export (bounded: a long-lived serving
#: process must not leak spans — oldest roots are dropped past the cap)
MAX_ROOT_SPANS = 4096

_roots: List["Span"] = []
_roots_lock = threading.Lock()
_local = threading.local()


def _stack() -> List["Span"]:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


class Span:
    """One timed region; nests via the per-thread span stack."""

    __slots__ = ("name", "attrs", "t0_us", "dur_us", "children",
                 "_tid", "_ann")

    def __init__(self, name: str, attrs: Optional[dict] = None):
        self.name = name
        self.attrs = dict(attrs or {})
        self.t0_us: float = 0.0
        self.dur_us: float = 0.0
        self.children: List["Span"] = []
        self._tid = threading.get_ident()
        self._ann = None

    def __enter__(self) -> "Span":
        if _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self.name)
            self._ann.__enter__()
        _stack().append(self)
        self.t0_us = time.perf_counter() * 1e6
        return self

    def __exit__(self, *exc) -> bool:
        self.dur_us = time.perf_counter() * 1e6 - self.t0_us
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        if st:
            st[-1].children.append(self)
        else:
            with _roots_lock:
                _roots.append(self)
                if len(_roots) > MAX_ROOT_SPANS:
                    del _roots[: len(_roots) - MAX_ROOT_SPANS]
        return False

    # ------------------------------------------------------------- export
    def walk(self):
        """Depth-first iteration over this span and its subtree."""
        yield self
        for c in self.children:
            yield from c.walk()

    def snapshot(self) -> dict:
        return {"type": "span", "name": self.name, "attrs": self.attrs,
                "ts_us": self.t0_us, "dur_us": self.dur_us,
                "children": [c.snapshot() for c in self.children]}


def current_span() -> Optional[Span]:
    """The innermost open span on this thread (None outside any span)."""
    st = _stack()
    return st[-1] if st else None


def spans() -> Tuple[Span, ...]:
    """Completed root spans, oldest first."""
    with _roots_lock:
        return tuple(_roots)


def reset_spans() -> None:
    with _roots_lock:
        _roots.clear()


def chrome_trace() -> dict:
    """Chrome ``traceEvents`` JSON (complete 'X' events, us timestamps)
    — load in chrome://tracing or ui.perfetto.dev."""
    pid = os.getpid()
    events = []
    for root in spans():
        for s in root.walk():
            events.append({
                "name": s.name, "ph": "X", "pid": pid, "tid": s._tid,
                "ts": s.t0_us, "dur": s.dur_us, "args": s.attrs,
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(), f)
    return path
