"""Metrics substrate: counters, gauges, bucketed histograms, and the
process-wide default ``MetricsRegistry``.

Design constraints (see DESIGN.md Section 7):

  * dependency-free — stdlib only, importable without jax;
  * label-aware — a metric's identity is ``(name, sorted labels)``, so
    ``registry.counter("runner.cache.hit", kind="block")`` and the same
    name with ``kind="dist-fused"`` are distinct series, exactly as in
    Prometheus;
  * cheap when disabled — the enabled flag lives HERE (module state,
    initialized from ``SQUEEZE_TELEMETRY``) and the gated helpers in
    ``repro.obs`` are a bool check + early return, so instrumented hot
    paths cost one function call when telemetry is off (guarded by the
    ``--telemetry`` overhead benchmark);
  * thread-safe — the checkpoint manager records from its async writer
    thread; every mutation takes the owning registry's lock.

Histograms are bucketed (default: powers of two spanning ~1us .. ~1e9,
so one bucket family serves seconds, batch sizes, step counts and byte
volumes); ``percentile`` interpolates linearly inside the landing
bucket and clamps to the observed min/max.
"""
from __future__ import annotations

import bisect
import os
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

#: label set folded into a metric's identity: sorted (key, value) pairs,
#: values stringified (JSON/Prometheus exporters need strings anyway)
Labels = Tuple[Tuple[str, str], ...]

#: default histogram bucket upper bounds: powers of two from 2^-20
#: (~1e-6 — microsecond latencies land mid-range) to 2^30 (~1e9 —
#: byte volumes and big step counts still resolve); +Inf is implicit
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    float(2.0 ** i) for i in range(-20, 31))


def _labels_key(labels: Dict[str, object]) -> Labels:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared identity plumbing of the three metric types."""

    __slots__ = ("name", "labels", "_lock")
    kind = "?"

    def __init__(self, name: str, labels: Labels,
                 lock: threading.RLock):
        self.name = name
        self.labels = labels
        self._lock = lock

    @property
    def labels_dict(self) -> Dict[str, str]:
        return dict(self.labels)

    def _head(self) -> dict:
        return {"type": self.kind, "name": self.name,
                "labels": self.labels_dict}


class Counter(_Metric):
    """Monotonically increasing count (int or float increments)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        self.value = 0

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n

    def reset(self) -> None:
        with self._lock:
            self.value = 0

    def snapshot(self) -> dict:
        return dict(self._head(), value=self.value)


class Gauge(_Metric):
    """Last-written value (set/add semantics)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, name, labels, lock):
        super().__init__(name, labels, lock)
        self.value = 0

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def add(self, dv) -> None:
        with self._lock:
            self.value += dv

    def reset(self) -> None:
        with self._lock:
            self.value = 0

    def snapshot(self) -> dict:
        return dict(self._head(), value=self.value)


class Histogram(_Metric):
    """Bucketed distribution: fixed upper bounds + an overflow bucket.

    ``bucket_counts[i]`` counts samples with ``bounds[i-1] < v <=
    bounds[i]`` (the last slot is the +Inf overflow); ``count``/``sum``/
    ``min``/``max`` track the exact aggregate alongside.
    """

    __slots__ = ("bounds", "bucket_counts", "count", "sum", "min", "max")
    kind = "histogram"

    def __init__(self, name, labels, lock,
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, labels, lock)
        bounds = tuple(sorted(buckets)) if buckets else DEFAULT_BUCKETS
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def record(self, v) -> None:
        v = float(v)
        with self._lock:
            self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Bucket-interpolated q-quantile (q in [0, 1]), clamped to the
        observed [min, max] — exact enough for p50/p95 straggler logic
        without keeping samples."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            if not self.count:
                return 0.0
            target = q * self.count
            cum = 0.0
            for i, c in enumerate(self.bucket_counts):
                if not c:
                    continue
                if cum + c >= target:
                    lo = self.bounds[i - 1] if i > 0 else 0.0
                    hi = (self.bounds[i] if i < len(self.bounds)
                          else self.max)
                    frac = (target - cum) / c
                    v = lo + (hi - lo) * frac
                    return min(max(v, self.min), self.max)
                cum += c
            return self.max

    def reset(self) -> None:
        with self._lock:
            self.bucket_counts = [0] * (len(self.bounds) + 1)
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                self._head(), count=self.count, sum=self.sum,
                min=self.min, max=self.max, bounds=list(self.bounds),
                bucket_counts=list(self.bucket_counts))


class MetricsRegistry:
    """Get-or-create registry of labeled metrics.

    ``counter``/``gauge``/``histogram`` return the existing instance for
    an already-seen ``(name, labels)`` (so call sites never cache metric
    handles unless they are hot); requesting an existing name with a
    different metric type raises. ``reset`` zeroes every metric in place
    — handles stay valid, which is what the test-suite fixtures and
    long-lived engines need.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: "OrderedDict[Tuple[str, Labels], _Metric]" = \
            OrderedDict()

    def _get_or_create(self, cls, name: str, labels: Dict[str, object],
                       **kw) -> _Metric:
        key = (name, _labels_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], self._lock, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r}{dict(key[1])} already registered "
                    f"as {m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels,
                                   buckets=buckets)

    # ------------------------------------------------------------ queries
    def get(self, name: str, **labels) -> Optional[_Metric]:
        """The metric at ``(name, labels)``, or None (never creates)."""
        return self._metrics.get((name, _labels_key(labels)))

    def value(self, name: str, **labels):
        """Counter/gauge value at ``(name, labels)``; None if absent."""
        m = self.get(name, **labels)
        return getattr(m, "value", None) if m is not None else None

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able dump grouped by metric type."""
        out = {"counters": [], "gauges": [], "histograms": []}
        for m in self.metrics():
            out[m.kind + "s"].append(m.snapshot())
        return out

    def reset(self) -> None:
        """Zero every metric in place (handles stay valid)."""
        for m in self.metrics():
            m.reset()


# --------------------------------------------------------- process state
#: falsy spellings of SQUEEZE_TELEMETRY (anything else enables)
_FALSY = ("", "0", "off", "false", "no", "none")


def parse_env(value: Optional[str]) -> bool:
    """SQUEEZE_TELEMETRY semantics: unset/0/off/false/no/none disable;
    any other value (1/on/comma-separated flags) enables."""
    return (value or "").strip().lower() not in _FALSY


_ENABLED: bool = parse_env(os.environ.get("SQUEEZE_TELEMETRY"))
_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def enabled() -> bool:
    """Is telemetry collection on? (The single gate every instrumented
    call site checks — see ``repro.obs``.)"""
    return _ENABLED


def enable(on: bool = True) -> None:
    global _ENABLED
    _ENABLED = bool(on)


def disable() -> None:
    enable(False)


def default_registry() -> MetricsRegistry:
    """The process-wide registry (a serving process wants exactly one)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = MetricsRegistry()
    return _DEFAULT
