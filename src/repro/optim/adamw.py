"""AdamW (raw JAX) with the distributed-optimization extras the big MoE
configs need:

  * optional **int8 moment quantization** (per-last-axis-block scales,
    error-free round-trip storage format) — halves-to-quarters the
    dominant optimizer-state HBM term for 100B+ models;
  * optional **int8 gradient compression with error feedback** (1-bit-
    Adam-style residual accumulation) for cross-pod all-reduce: the
    quantization residual is carried in optimizer state, so the scheme is
    unbiased over time;
  * global-norm clipping, decoupled weight decay, cosine schedule with
    linear warmup.

State is a pytree of plain arrays — checkpointable with the generic
manager, reshardable on restore.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    quantize_moments: bool = False   # int8 m/v
    compress_grads: bool = False     # int8 error-feedback grads


# ------------------------------------------------------ int8 block quant
# Shape-preserving layout: q keeps the parameter's shape (int8, last axis
# padded to the block size), scales are (..., last/BLOCK). This means the
# quantized state SHARDS with the same PartitionSpec as the parameter —
# critical at 100B+ scale (a flat layout would replicate; see
# launch/specs.opt_state_shardings).
_QBLOCK = 128


def _quantize(x: Array) -> Tuple[Array, Array]:
    if x.ndim == 0:
        x = x.reshape(1)
    last = x.shape[-1]
    pad = (-last) % _QBLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xp.shape[:-1], -1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return (q.reshape(*xp.shape[:-1], last + pad),
            scale[..., 0].astype(jnp.float32))


def _dequantize(q: Array, scale: Array, shape, size) -> Array:
    del size
    last = shape[-1] if len(shape) else 1
    blocks = q.reshape(*q.shape[:-1], -1, _QBLOCK).astype(jnp.float32)
    out = blocks * scale[..., None]
    out = out.reshape(*q.shape[:-1], q.shape[-1])[..., :last]
    return out.reshape(shape)


def _q_tree(tree):
    qs = jax.tree.map(lambda x: _quantize(x)[0], tree)
    ss = jax.tree.map(lambda x: _quantize(x)[1], tree)
    return {"q": qs, "scale": ss}


def _dq_tree(qtree, like):
    return jax.tree.map(
        lambda q, s, ref: _dequantize(q, s, ref.shape, ref.size),
        qtree["q"], qtree["scale"], like)


# -------------------------------------------------------------- schedule
def lr_schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# ------------------------------------------------------------- optimizer
def init(cfg: AdamWConfig, params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.quantize_moments:
        state["m"] = _q_tree(zeros)
        state["v"] = _q_tree(zeros)
    else:
        state["m"] = zeros
        state["v"] = zeros
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
    return state


def compress_decompress(g: Array, residual: Array
                        ) -> Tuple[Array, Array]:
    """Error-feedback int8 round-trip: returns (g_hat, new_residual).
    In deployment the int8 payload is what crosses the pod interconnect."""
    corrected = g + residual
    q, s = _quantize(corrected)
    g_hat = _dequantize(q, s, g.shape, g.size)
    return g_hat, corrected - g_hat


def update(cfg: AdamWConfig, grads, state, params):
    """-> (new_params, new_state, metrics)."""
    step = state["step"]
    metrics = {}

    if cfg.compress_grads:
        pairs = jax.tree.map(compress_decompress, grads, state["ef"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_ef = None

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    metrics["grad_norm"] = gnorm
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    m_prev = (_dq_tree(state["m"], params) if cfg.quantize_moments
              else state["m"])
    v_prev = (_dq_tree(state["v"], params) if cfg.quantize_moments
              else state["v"])

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm
                     + (1 - cfg.b1) * g.astype(jnp.float32), m_prev, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv
                     + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
                     v_prev, grads)

    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    lr = lr_schedule(cfg, step)
    metrics["lr"] = lr

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd *
                p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    new_state = {"step": step + 1,
                 "m": _q_tree(m) if cfg.quantize_moments else m,
                 "v": _q_tree(v) if cfg.quantize_moments else v}
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_params, new_state, metrics
