"""Batched multi-fractal simulation runtime.

Serving many concurrent fractal simulations means many independent initial
states over a small set of static configurations. The configuration
identity is :class:`repro.tuning.spec.EngineSpec` — the same object that
keys the serving buckets and the autotuner's tables — and this module
provides the building block:

  * one compiled step per static configuration, vmapped over a leading
    batch axis of independent states (B simulations advance in one XLA
    call); the 'pallas-mxu' kind instead dispatches ONE kernel over a
    native (B, n_macro_tiles) grid (``supports_native_batch`` on the
    engine), sharing the scalar-prefetched block tables across the batch
    — the vmap path stays as the fallback for every other kind;
  * fused multi-step serving: ``run`` tiles the step count into
    floor(steps/k) vmapped k-step launches (temporal fusion over the
    engines' depth-k halos) plus a single-step remainder; the fusion
    depth is part of the cache key (None resolves through the tuning
    table, then the static heuristic — ``EngineSpec.normalize()`` — so
    the default and an equal explicit depth share one entry);
  * zero-copy steady-state stepping: ``run(..., donate=True)`` routes
    through a ``donate_argnums`` jit so XLA reuses the incoming batch
    buffer for the output (the caller must not touch the input after);
  * an LRU cache of those compiled engines keyed by the NORMALIZED spec,
    so a serving process pays tracing/compilation once per
    configuration, not once per request;
  * multi-device placement: with a ``mesh``, regular kinds shard the
    BATCH axis (whole simulations spread across devices — many small
    fractals) while the 'dist-*' kinds shard the BLOCK axis (one fractal
    too large per device, k-fused strip halo exchange — see
    core/distributed.py and DESIGN.md Section 4); the mesh shape and
    fusion depth are part of the cache key;
  * trace/build counters (``RunnerStats``) so reuse is *testable* — the
    suite asserts >= 8 concurrent simulations share one compiled engine.

Every public method accepts either an ``EngineSpec`` first —
``run(spec, states, steps)`` — or the legacy argument list
``run(kind, frac, r, states, steps, ...)``; both flow through the one
normalization path (``EngineSpec.normalize()``), so a spec call and the
equivalent legacy call share one compiled entry. Custom (non-registry)
fractals are identified by their position mask; custom workloads are
identified by ``workload.name`` and must be passed as objects through
the legacy form (give them unique names — the cache cannot distinguish
two different workloads sharing one name).

The runner is dimension-agnostic: the 3D kinds ('bb3d' | 'cell3d' |
'block3d' | 'pallas-3d' | 'pallas-3d-mxu') dispatch states with 3D
spatial trailing axes — (B, nx, ny, nz) cell states, (B, n_blocks, rho,
rho, rho) block states — through the same vmapped-step/fused-run/LRU
machinery; 'block3d' and 'pallas-3d*' are block kinds, so the fusion
depth participates in their cache key exactly as in 2D.

See DESIGN.md Sections 3 and 11.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import TYPE_CHECKING, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.tuning.spec import EngineSpec, is_dist_kind
from repro.workloads.base import StencilWorkload
from repro.workloads.rules import LIFE

if TYPE_CHECKING:  # annotation-only; keeps runtime free of core imports
    from repro.core.fractals import NBBFractal

Array = jnp.ndarray

#: the cache identity of one simulation family: a *normalized*
#: EngineSpec — the same object serving buckets and tuning tables key on
Key = EngineSpec


def _is_dist(kind: str) -> bool:
    """Multi-device engine kinds (block-axis sharding over a mesh)."""
    return is_dist_kind(kind)


@dataclasses.dataclass
class RunnerStats:
    """Legacy per-runner counters (kept: cheap, always on, asserted by
    the reuse tests). The labeled equivalents land on the telemetry
    registry when ``SQUEEZE_TELEMETRY`` is enabled: ``runner.cache.{hit,
    miss,evict}``, ``runner.build`` / ``runner.trace`` (per-key compile
    counts), ``runner.runs`` + ``runner.run.seconds`` latency
    histograms, ``runner.batch_size`` / ``runner.steps`` histograms —
    see DESIGN.md Section 7."""

    builds: int = 0    # engines constructed (LRU misses)
    traces: int = 0    # jax traces of the batched step (recompilations)
    evictions: int = 0


@dataclasses.dataclass
class _Entry:
    engine: object
    batched_step: callable
    batched_run: callable
    batched_run_donated: callable


@dataclasses.dataclass(frozen=True)
class _Resolved:
    """One normalized configuration plus the objects ``_build`` needs
    (the spec alone cannot carry custom fractal/workload objects or a
    live mesh)."""

    spec: EngineSpec          # normalized: THE cache key
    frac: object
    workload: StencilWorkload
    mesh: object              # live Mesh or None


class BatchedRunner:
    """LRU cache of compiled batched engines keyed by normalized
    EngineSpec.

    Thread-safe: the serving layer (``repro.serving``) drives one runner
    from many worker threads, including abandoned hang threads that may
    race a fresh retry. Cache lookups/inserts/evictions hold an RLock;
    a cold build runs *outside* the lock behind a per-key build event,
    so (a) concurrent misses on the same key build the engine exactly
    once (the losers wait, then take the cache hit) and (b) a
    multi-second trace never blocks warm hits on other keys.
    """

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.stats = RunnerStats()
        self._cache: "OrderedDict[Key, _Entry]" = OrderedDict()
        self._lock = threading.RLock()
        self._building: Dict[Key, threading.Event] = {}

    # ------------------------------------------------------------- cache
    def _resolve(self, kind, frac=None, r: Optional[int] = None,
                 m: int = 0, workload: Optional[StencilWorkload] = None,
                 k: Optional[int] = None, mesh=None, axis: str = "data",
                 exchange: str = "auto") -> _Resolved:
        """THE normalization path: spec or legacy args in, normalized
        spec + build objects out. ``EngineSpec.normalize()`` does the
        alias rewrite, the non-block/non-dist knob zeroing, and the
        explicit > table > heuristic knob resolution; an explicit
        ``k < 1`` raises here, before any cache traffic."""
        if isinstance(kind, EngineSpec):
            # frac/workload/mesh act as OBJECT overrides here (custom
            # workloads are registry-invisible; the serving layer passes
            # the request's own objects) — identity still comes from the
            # spec alone
            norm = kind.normalize()
            return _Resolved(
                spec=norm,
                frac=frac if frac is not None else norm.build_frac(),
                workload=(workload if workload is not None
                          else norm.build_workload()),
                mesh=mesh if mesh is not None else norm.build_mesh())
        workload = LIFE if workload is None else workload
        spec = EngineSpec.from_args(kind, frac, r, m, workload,
                                    fusion_k=k, mesh=mesh, axis=axis,
                                    exchange=exchange)
        norm = spec.normalize()
        return _Resolved(spec=norm, frac=frac, workload=workload,
                         mesh=mesh)

    def _get(self, res: _Resolved) -> _Entry:
        key = res.spec
        while True:
            with self._lock:
                entry = self._cache.get(key)
                if entry is not None:
                    self._cache.move_to_end(key)
                    obs.inc("runner.cache.hit", kind=key.kind)
                    return entry
                ev = self._building.get(key)
                if ev is None:
                    # we build; racing threads wait on the event, then
                    # re-check the cache (or, if we failed/were evicted
                    # already, one of them becomes the next builder)
                    self._building[key] = threading.Event()
                    break
            ev.wait()
        try:
            entry = self._build(res)
            return self._insert(key, entry)
        finally:
            with self._lock:
                self._building.pop(key).set()

    def _build(self, res: _Resolved) -> _Entry:
        """Construct + wrap the engine for ``res`` (no lock held: engine
        construction and jax tracing can take seconds)."""
        spec = res.spec
        kind, k, workload = spec.kind, spec.fusion_k, res.workload
        obs.inc("runner.cache.miss", kind=kind)
        obs.inc("runner.build", kind=kind, workload=spec.workload, k=k)
        from repro.core.stencil import make_engine
        # the spec is already normalized — build with the table DISABLED
        # so make_engine does not re-consult it (one consult, and one
        # engine.tune.* outcome, per runner call; none per build)
        engine = make_engine(spec, frac=res.frac, workload=workload,
                             mesh=res.mesh, table=None)
        if _is_dist(kind):
            # the distributed engine owns its jit cache, its fused-launch
            # tiling (exactly ceil(steps/k) collectives) and its exchange
            # accounting — the runner must not wrap it in another jit, or
            # the Python-side collective counters would only run at trace
            # time. Its step/run handle (B, C?, nb_padded, rho, rho)
            # natively (one batched strip all-gather per launch).
            return _Entry(engine, engine.step_batched,
                          lambda states, steps: engine.run(
                              states, int(steps)),
                          lambda states, steps: engine.run(
                              states, int(steps), donate=True))
        fused = spec.is_block and k > 1
        stats = self.stats
        # the v5 'mxu' engine advances the whole batch through ONE kernel
        # dispatch over a (B, n_macro_tiles) grid — the scalar-prefetched
        # tables are shared across the batch instead of re-staged per
        # simulation by a vmap of pallas_call; every other kind keeps the
        # vmap path
        native = getattr(engine, "supports_native_batch", False)

        def trace_tick():
            """Runs only while tracing; cached calls skip it. Mirrored
            onto the registry so retrace regressions are assertable per
            (kind, workload, k) without a runner handle."""
            stats.traces += 1
            obs.inc("runner.trace", kind=kind, workload=spec.workload,
                    k=k)

        def traced_step(state):
            trace_tick()
            return engine.step(state)

        def traced_step_k(state):
            trace_tick()
            return engine.step_k(state, k)

        def traced_batch_step(states):
            trace_tick()
            return engine.step_batched(states)

        def traced_batch_step_k(states):
            trace_tick()
            return engine.step_k_batched(states, k)

        batched_step = jax.jit(
            traced_batch_step if native else jax.vmap(traced_step))

        def _run(states, steps):
            body = traced_batch_step if native else jax.vmap(traced_step)
            if fused:
                body_k = (traced_batch_step_k if native
                          else jax.vmap(traced_step_k))
                states = jax.lax.fori_loop(
                    0, steps // k, lambda _, s: body_k(s), states)
                return jax.lax.fori_loop(
                    0, steps % k, lambda _, s: body(s), states)
            return jax.lax.fori_loop(
                0, steps, lambda _, s: body(s), states)

        if fused and kind == "block":
            # XLA step_k tables, outside traces; the pallas kinds build
            # their (smaller) v4 set in the kernel entry point
            engine.layout.materialize_halo(k)
        return _Entry(engine, batched_step, jax.jit(_run),
                      jax.jit(_run, donate_argnums=0))

    def _insert(self, key: Key, entry: _Entry) -> _Entry:
        """Shared cache insert + build accounting + LRU eviction."""
        with self._lock:
            self._cache[key] = entry
            self.stats.builds += 1
            if len(self._cache) > self.capacity:
                self._cache.popitem(last=False)
                self.stats.evictions += 1
                obs.inc("runner.cache.evict")
        return entry

    def is_cached(self, kind, frac=None, r: Optional[int] = None,
                  m: int = 0, workload: Optional[StencilWorkload] = None,
                  k: Optional[int] = None, mesh=None,
                  axis: str = "data", exchange: str = "auto") -> bool:
        """Whether this configuration is a warm cache hit right now
        (no build, no LRU touch) — the serving layer's admission
        control uses this to bound concurrent cold compiles. Accepts a
        spec (``is_cached(spec)``) or legacy args."""
        res = self._resolve(kind, frac, r, m, workload, k, mesh, axis,
                            exchange)
        with self._lock:
            return res.spec in self._cache

    def invalidate(self, kind, frac=None, r: Optional[int] = None,
                   m: int = 0, workload: Optional[StencilWorkload] = None,
                   k: Optional[int] = None, mesh=None,
                   axis: str = "data", exchange: str = "auto") -> bool:
        """Drop one compiled entry (if cached): the serving layer's
        engine-restart path after a watchdog-detected hang — the next
        ``run`` rebuilds from scratch. Returns True if an entry was
        evicted. Accepts a spec or legacy args."""
        res = self._resolve(kind, frac, r, m, workload, k, mesh, axis,
                            exchange)
        with self._lock:
            entry = self._cache.pop(res.spec, None)
            if entry is not None:
                obs.inc("runner.cache.invalidate", kind=res.spec.kind)
            return entry is not None

    def engine_for(self, kind, frac=None, r: Optional[int] = None,
                   m: int = 0, workload: Optional[StencilWorkload] = None,
                   k: Optional[int] = None, mesh=None, axis: str = "data",
                   exchange: str = "auto"):
        """The (cached) underlying single-simulation engine. ``exchange``
        picks the dist-* halo-exchange mode ('auto' | 'p2p' | 'gather';
        ignored — and normalized out of the cache key — for
        single-device kinds). ``step``/``run`` use the 'auto' default,
        which resolves through the tuning table, then to the
        neighbor-only p2p exchange whenever the mesh supports it.
        Accepts a spec (``engine_for(spec)``) or legacy args."""
        return self._get(self._resolve(kind, frac, r, m, workload, k,
                                       mesh, axis, exchange)).engine

    def cache_size(self) -> int:
        return len(self._cache)

    # --------------------------------------------------------- mesh placement
    @staticmethod
    def place_batch(states: Array, mesh, axis: str = "data") -> Array:
        """Shard a batch of independent simulations over ``mesh``'s
        ``axis`` along the BATCH dimension (each device owns whole
        simulations — no halo traffic; the right placement for many small
        fractals). For one fractal too large per device, use the
        'dist-*' kinds instead: they shard the BLOCK axis and exchange
        k-fused halo strips (see DESIGN.md Section 4)."""
        from jax.sharding import NamedSharding, PartitionSpec
        spec = PartitionSpec(axis, *([None] * (states.ndim - 1)))
        return jax.device_put(states, NamedSharding(mesh, spec))

    # ---------------------------------------------------------- batched API
    def init_batch(self, kind, frac=None, r: Optional[int] = None,
                   seeds=None, m: int = 0,
                   workload: Optional[StencilWorkload] = None,
                   mesh=None, axis: str = "data") -> Array:
        """Stack independent initial states: (B, *state_shape). With a
        ``mesh``, 'dist-*' kinds come back sharded over the BLOCK axis
        (one fractal spread across devices); every other kind is sharded
        over the BATCH axis (whole simulations spread across devices).
        Spec form: ``init_batch(spec, seeds, mesh=...)``."""
        if isinstance(kind, EngineSpec) and seeds is None:
            seeds, frac = frac, None  # init_batch(spec, seeds) form
        res = self._resolve(kind, frac, r, m, workload, None, mesh, axis)
        engine = self._get(res).engine
        if _is_dist(res.spec.kind):
            return engine.init_batch(seeds)
        states = jnp.stack([engine.init_random(int(s)) for s in seeds])
        if res.mesh is not None:
            states = self.place_batch(states, res.mesh, axis)
        return states

    def step(self, kind, frac=None, r: Optional[int] = None,
             states: Optional[Array] = None, m: int = 0,
             workload: Optional[StencilWorkload] = None,
             mesh=None, axis: str = "data") -> Array:
        """One step of B independent simulations, one compiled call.
        Spec form: ``step(spec, states)``."""
        if isinstance(kind, EngineSpec) and states is None:
            states, frac = frac, None  # step(spec, states) form
        res = self._resolve(kind, frac, r, m, workload, None, mesh, axis)
        return self._get(res).batched_step(states)

    def run(self, kind, frac=None, r: Optional[int] = None,
            states: Optional[Array] = None, steps: Optional[int] = None,
            m: int = 0, workload: Optional[StencilWorkload] = None,
            k: Optional[int] = None, donate: bool = False,
            mesh=None, axis: str = "data") -> Array:
        """``steps`` steps of B independent simulations, tiled into
        floor(steps/k) fused k-step launches plus a steps%k single-step
        remainder (``k=None``: tuning table, then the engine heuristic;
        non-block kinds step singly). ``steps`` is a dynamic fori_loop
        bound: changing it does not retrace (the 'dist-*' kinds instead
        tile in the engine so the collective count is exactly
        ceil(steps/k); their remainder launch compiles once per distinct
        steps%k, bounded by k). ``donate=True`` hands the ``states``
        buffer to XLA for in-place reuse — zero-copy steady-state
        stepping; the caller must not use ``states`` afterwards.
        Spec form: ``run(spec, states, steps, donate=...)``.

        With telemetry enabled, each call records a ``runner.run.seconds``
        wall-time histogram sample (dispatch latency: time to hand the
        work to XLA, not device completion on async backends) plus batch
        size / step-count histograms, all labeled by ``kind``."""
        if isinstance(kind, EngineSpec) and states is None:
            states, steps, frac, r = frac, r, None, None
        t0 = time.perf_counter() if obs.enabled() else None
        res = self._resolve(kind, frac, r, m, workload, k, mesh, axis)
        entry = self._get(res)
        label = res.spec.kind
        fn = entry.batched_run_donated if donate else entry.batched_run
        with obs.span("runner.run", kind=label, steps=int(steps)):
            out = fn(states, jnp.asarray(steps, jnp.int32))
        if t0 is not None:
            obs.observe("runner.run.seconds",
                        time.perf_counter() - t0, kind=label)
            obs.observe("runner.batch_size", int(states.shape[0]),
                        kind=label)
            obs.observe("runner.steps", int(steps), kind=label)
            obs.inc("runner.runs", kind=label)
            if donate:
                obs.inc("runner.donated_runs", kind=label)
        return out

    def to_expanded(self, kind, frac=None, r: Optional[int] = None,
                    states: Optional[Array] = None, m: int = 0,
                    workload: Optional[StencilWorkload] = None,
                    mesh=None, axis: str = "data") -> Array:
        """Batched conversion to the (B, C?, n, n) expanded embedding.
        Spec form: ``to_expanded(spec, states)``."""
        if isinstance(kind, EngineSpec) and states is None:
            states, frac = frac, None
        res = self._resolve(kind, frac, r, m, workload, None, mesh, axis)
        engine = self._get(res).engine
        if hasattr(engine, "to_expanded"):
            return jax.vmap(engine.to_expanded)(states)
        return states  # BB/lambda states are already expanded


#: process-wide default runner (a serving process wants exactly one cache)
_DEFAULT: Optional[BatchedRunner] = None


def default_runner() -> BatchedRunner:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = BatchedRunner()
    return _DEFAULT
