"""Batched multi-fractal simulation runtime.

Serving many concurrent fractal simulations means many independent initial
states over a small set of static configurations ``(engine kind, fractal,
r, m, workload)``. This module provides the building block:

  * one compiled step per static configuration, vmapped over a leading
    batch axis of independent states (B simulations advance in one XLA
    call);
  * an LRU cache of those compiled engines keyed by the static tuple, so
    a serving process pays tracing/compilation once per configuration, not
    once per request;
  * trace/build counters (``RunnerStats``) so reuse is *testable* — the
    suite asserts >= 8 concurrent simulations share one compiled engine.

See DESIGN.md Section 3.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import TYPE_CHECKING, Hashable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.workloads.base import StencilWorkload
from repro.workloads.rules import LIFE

if TYPE_CHECKING:  # annotation-only; keeps runtime free of core imports
    from repro.core.fractals import NBBFractal

Array = jnp.ndarray

#: static configuration of one simulation family:
#: (kind, fractal, r, m, workload). The fractal stays ``Hashable`` here so
#: this module needs nothing from ``repro.core`` at import time.
Key = Tuple[str, Hashable, int, int, StencilWorkload]


@dataclasses.dataclass
class RunnerStats:
    builds: int = 0    # engines constructed (LRU misses)
    traces: int = 0    # jax traces of the batched step (recompilations)
    evictions: int = 0


@dataclasses.dataclass
class _Entry:
    engine: object
    batched_step: callable
    batched_run: callable


class BatchedRunner:
    """LRU cache of compiled batched engines over (kind, frac, r, m, wl)."""

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.stats = RunnerStats()
        self._cache: "OrderedDict[Key, _Entry]" = OrderedDict()

    # ------------------------------------------------------------- cache
    def _get(self, kind: str, frac: NBBFractal, r: int, m: int,
             workload: StencilWorkload) -> _Entry:
        if kind == "pallas":  # make_engine's alias; one cache slot, not two
            kind = "pallas-strips"
        key: Key = (kind, frac, r, m, workload)
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
            return entry
        from repro.core.stencil import make_engine
        engine = make_engine(kind, frac, r, m, workload=workload)
        stats = self.stats

        def traced_step(state):
            stats.traces += 1  # runs only while tracing; cached calls skip it
            return engine.step(state)

        batched_step = jax.jit(jax.vmap(traced_step))

        @jax.jit
        def batched_run(states, steps):
            body = jax.vmap(traced_step)
            return jax.lax.fori_loop(
                0, steps, lambda _, s: body(s), states)

        entry = _Entry(engine, batched_step, batched_run)
        self._cache[key] = entry
        stats.builds += 1
        if len(self._cache) > self.capacity:
            self._cache.popitem(last=False)
            stats.evictions += 1
        return entry

    def engine_for(self, kind: str, frac: NBBFractal, r: int, m: int = 0,
                   workload: StencilWorkload = LIFE):
        """The (cached) underlying single-simulation engine."""
        return self._get(kind, frac, r, m, workload).engine

    def cache_size(self) -> int:
        return len(self._cache)

    # ---------------------------------------------------------- batched API
    def init_batch(self, kind: str, frac: NBBFractal, r: int,
                   seeds, m: int = 0,
                   workload: StencilWorkload = LIFE) -> Array:
        """Stack independent initial states: (B, *state_shape)."""
        engine = self.engine_for(kind, frac, r, m, workload)
        return jnp.stack([engine.init_random(int(s)) for s in seeds])

    def step(self, kind: str, frac: NBBFractal, r: int, states: Array,
             m: int = 0, workload: StencilWorkload = LIFE) -> Array:
        """One step of B independent simulations, one compiled call."""
        return self._get(kind, frac, r, m, workload).batched_step(states)

    def run(self, kind: str, frac: NBBFractal, r: int, states: Array,
            steps: int, m: int = 0,
            workload: StencilWorkload = LIFE) -> Array:
        """``steps`` steps of B independent simulations. ``steps`` is a
        dynamic fori_loop bound: changing it does not retrace."""
        entry = self._get(kind, frac, r, m, workload)
        return entry.batched_run(states, jnp.asarray(steps, jnp.int32))

    def to_expanded(self, kind: str, frac: NBBFractal, r: int,
                    states: Array, m: int = 0,
                    workload: StencilWorkload = LIFE) -> Array:
        """Batched conversion to the (B, C?, n, n) expanded embedding."""
        engine = self.engine_for(kind, frac, r, m, workload)
        if hasattr(engine, "to_expanded"):
            return jax.vmap(engine.to_expanded)(states)
        return states  # BB/lambda states are already expanded


#: process-wide default runner (a serving process wants exactly one cache)
_DEFAULT: Optional[BatchedRunner] = None


def default_runner() -> BatchedRunner:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = BatchedRunner()
    return _DEFAULT
