"""Pluggable stencil workloads + the batched multi-fractal runtime.

``StencilWorkload`` carries everything rule-specific (dtype, neighbor
weights, update rule, init distribution); the engines in ``core/`` and the
Pallas kernels in ``kernels/`` are parameterized by one. ``BatchedRunner``
vmaps a compiled step over a batch of independent simulations and caches
compiled engines per static ``(kind, fractal, r, m, workload)`` tuple.
"""
from repro.workloads.base import StencilWorkload, weighted_moore_agg
from repro.workloads.rules import (GRAY_SCOTT, HEAT, HEAT3D, HIGHLIFE, LIFE,
                                   LIFE3D, SEEDS, WORKLOADS, GrayScott,
                                   HeatDiffusion, TotalisticCA, get_workload,
                                   life_rule)
from repro.workloads.runner import BatchedRunner, RunnerStats, default_runner

__all__ = [
    "StencilWorkload", "weighted_moore_agg",
    "TotalisticCA", "HeatDiffusion", "GrayScott",
    "LIFE", "LIFE3D", "HIGHLIFE", "SEEDS", "HEAT", "HEAT3D", "GRAY_SCOTT",
    "WORKLOADS", "get_workload", "life_rule",
    "BatchedRunner", "RunnerStats", "default_runner",
]
