"""The ``StencilWorkload`` abstraction: what the engines simulate.

The paper frames Squeeze as a general scheme for data-parallel computation
on a fractal with neighborhood access; the game of life of Section 4 is
one instance. A workload bundles everything rule-specific so that the
engines (BB, lambda, Squeeze cell/block/3D, the multi-device engine in
core/distributed.py) and the Pallas kernels stay rule-agnostic.

  * ``dtype`` / ``agg_dtype``  — cell state and accumulation dtypes;
  * ``n_channels``             — 1 (scalar field) or C (e.g. Gray-Scott's
                                 (U, V) pair). Multi-channel states carry a
                                 leading channel axis: (C, *spatial).
  * ``weight(offset)``         — per-direction neighbor weight, dimension
                                 agnostic ((dx, dy) or (dx, dy, dz)); a 0
                                 weight means the direction is never read;
  * ``apply(center, agg, mask)`` — the update rule, given the weighted
                                 neighbor aggregate. ``mask`` is the {0,1}
                                 occupancy (holes/boundary), or None when
                                 the caller's domain has no holes (cell
                                 engine: every compact cell is real);
  * ``init(key, shape)``       — the initial-state distribution over the
                                 *unmasked* spatial domain (engines mask).

Out-of-fractal and hole neighbors always contribute 0 to the aggregate —
dead cells for CA rules, Dirichlet-0 boundaries for the PDE rules — which
is exactly the paper's adaptation of life to the fractal (Section 4).

See DESIGN.md Section 3 for how this composes with the engines and the
batched runner.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

#: Moore neighborhood directions (dx, dy), y growing downward. Defined here
#: (the dependency-free layer); ``core.compact`` re-exports it, so the
#: workloads package imports nothing from ``core`` (no import cycle).
MOORE_DIRS: Tuple[Tuple[int, int], ...] = (
    (-1, -1), (0, -1), (1, -1),
    (-1, 0), (1, 0),
    (-1, 1), (0, 1), (1, 1),
)

#: 3D Moore neighborhood (dx, dy, dz), raster-ordered (dz slowest, dx
#: fastest) so the 26 directions line up with the 3D halo regions the
#: same way MOORE_DIRS lines up with the 2D ones.
MOORE3_DIRS: Tuple[Tuple[int, int, int], ...] = tuple(
    (dx, dy, dz)
    for dz in (-1, 0, 1) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
    if (dx, dy, dz) != (0, 0, 0))


class StencilWorkload:
    """Base class; concrete workloads are frozen dataclasses (hashable, so
    a workload can be a jit static argument and an engine-cache key)."""

    name: str = "abstract"
    #: number of state channels; multi-channel states are (C, *spatial)
    n_channels: int = 1
    #: spatial dimensionality the rule is defined for (None = any)
    ndim = None
    #: cell state dtype
    dtype = jnp.uint8
    #: accumulation dtype for the neighbor aggregate
    agg_dtype = jnp.int32

    # ------------------------------------------------------------- rule spec
    def weight(self, offset) -> float:
        """Weight of the neighbor at ``offset`` (any dimensionality)."""
        return 1

    def apply(self, center: Array, agg: Array, mask) -> Array:
        """Update rule: next state from (center, weighted neighbor aggregate).

        ``center``/``agg`` have a leading channel axis iff n_channels > 1.
        Implementations must zero holes when ``mask`` is given.
        """
        raise NotImplementedError

    def init(self, key, shape) -> Array:
        """Initial state over spatial ``shape`` ((C, *shape) if C > 1)."""
        raise NotImplementedError

    # ------------------------------------------------------------ conveniences
    @property
    def weights2d(self):
        """Weights over the 2D Moore directions, MOORE_DIRS order."""
        return tuple(self.weight(d) for d in MOORE_DIRS)

    @property
    def weights3x3(self) -> np.ndarray:
        """The 2D neighbor weights as a 3x3 float64 matrix indexed
        ``[dy+1, dx+1]`` (center weight 0: the aggregate never includes the
        cell itself — rules read it through ``center``)."""
        w = np.zeros((3, 3), np.float64)
        for dx, dy in MOORE_DIRS:
            w[dy + 1, dx + 1] = self.weight((dx, dy))
        return w

    @property
    def weights3d(self):
        """Weights over the 26 3D Moore directions, MOORE3_DIRS order."""
        return tuple(self.weight(d) for d in MOORE3_DIRS)

    @property
    def weights3x3x3(self) -> np.ndarray:
        """The 3D neighbor weights as a (3, 3, 3) float64 tensor indexed
        ``[dz+1, dy+1, dx+1]`` (center weight 0, as in ``weights3x3``)."""
        w = np.zeros((3, 3, 3), np.float64)
        for dx, dy, dz in MOORE3_DIRS:
            w[dz + 1, dy + 1, dx + 1] = self.weight((dx, dy, dz))
        return w

    @functools.cached_property
    def weight_factors3(self) -> Tuple[Tuple, Tuple, Tuple]:
        """Per-z-plane rank-1 decompositions of ``weights3x3x3``: a
        3-tuple (dz = -1, 0, +1), each entry the ``svd_rank1_terms`` of
        that plane's 3x3 xy weight matrix (empty for all-zero planes).
        This is the z-slab MXU formulation: the 26-neighbor aggregate of
        slab ``z`` is ``sum_dz sum_t R_t(dz) @ X[z+dz] @ C_t(dz)^T`` —
        each z-plane of the weight tensor is an independent 2D banded
        contraction applied to the neighboring slab (see DESIGN.md
        Section 5). Exactness is verified per plane at build time."""
        return tuple(
            svd_rank1_terms(plane) if plane.any() else ()
            for plane in self.weights3x3x3)

    @functools.cached_property
    def weight_factors(self) -> Tuple[Tuple[Tuple[float, ...],
                                            Tuple[float, ...]], ...]:
        """Rank-1 decomposition of ``weights3x3``: <= 3 ``(row, col)``
        pairs with ``sum_i outer(row_i, col_i) == weights3x3`` exactly (to
        float64 SVD precision; verified at build time). This is what turns
        the Moore aggregation into banded matmul contractions
        ``R_i @ X @ C_i^T`` on the MXU (see ``svd_rank1_terms`` and
        DESIGN.md Section 2.2). Cached on the frozen dataclass instance;
        hashability/equality (jit static args, runner cache keys) are
        untouched — dataclass hashing reads fields, not ``__dict__``."""
        return svd_rank1_terms(self.weights3x3)

    def tile_rule(self, center: Array, padded: Array, mask) -> Array:
        """One update on a halo-padded tile: ``center`` (C?, h, w), ``padded``
        (C?, h+2, w+2). This is the traced function the Pallas kernels call
        in place of the old hard-coded life rule."""
        agg = weighted_moore_agg(padded, self.weights2d, self.agg_dtype)
        return self.apply(center, agg, mask)

    def tile_rule_k(self, padded: Array, halo_mask, k: int,
                    ndim: int = 2) -> Array:
        """``k`` fused updates on a depth-``k`` padded tile (temporal
        blocking). ``padded`` is (C?, h+2k, w+2k); each substep updates the
        current window's interior and the window shrinks by one ring, so
        after ``k`` substeps the (C?, h, w) core has advanced ``k`` exact
        steps. ``halo_mask`` is the {0,1} occupancy of the *whole* window
        (trailing (h+2k, w+2k) axes; leading axes broadcast) or None; it is
        re-applied at every substep on a matching shrinking crop — halo
        cells belong to neighbor tiles whose holes/ghosts must stay zero
        mid-flight, not just at the final write.

        ``ndim=3`` runs the same discipline on a (C?, d+2k, h+2k, w+2k)
        volume over the 26-direction aggregate (the 3D block engines)."""
        crop = (Ellipsis,) + (slice(1, -1),) * ndim
        agg_of = weighted_moore_agg if ndim == 2 else weighted_moore_agg3
        weights = self.weights2d if ndim == 2 else self.weights3d
        cur = padded
        for _ in range(k):
            center = cur[crop]
            agg = agg_of(cur, weights, self.agg_dtype)
            if halo_mask is not None:
                halo_mask = halo_mask[crop]
            cur = self.apply(center, agg, halo_mask)
        return cur

    def masked(self, state: Array, mask) -> Array:
        return state if mask is None else state * mask.astype(state.dtype)


#: which pieces of a Moore halo a single radius-1 update actually reads:
#: edge strips (rows N/S, cols W/E) and the four corner cells.
HaloNeeds = Tuple[bool, ...]


def halo_needs(weights) -> "HaloNeeds":
    """(need_n, need_s, need_w, need_e, need_nw, need_ne, need_sw, need_se)
    for one radius-1 Moore update with the given ``weights2d``.

    A corner halo cell is read only by the matching diagonal shift, so a
    zero diagonal weight makes that gather dead; an edge strip is read by
    its orthogonal shift *and* both adjacent diagonal shifts, so it is dead
    only when all three weights are zero (HeatDiffusion: 4 orthogonal
    strips gathered, 4 corner gathers skipped). Single-step (k=1) kernels
    only — a fused k>=2 substep chain propagates corner values inward even
    under orthogonal-only weights.
    """
    w = dict(zip(MOORE_DIRS, weights))
    need_nw, need_ne = w[(-1, -1)] != 0, w[(1, -1)] != 0
    need_sw, need_se = w[(-1, 1)] != 0, w[(1, 1)] != 0
    need_n = need_nw or need_ne or w[(0, -1)] != 0
    need_s = need_sw or need_se or w[(0, 1)] != 0
    need_w = need_nw or need_sw or w[(-1, 0)] != 0
    need_e = need_ne or need_se or w[(1, 0)] != 0
    return (need_n, need_s, need_w, need_e,
            need_nw, need_ne, need_sw, need_se)


def svd_rank1_terms(weights3x3: np.ndarray, tol: float = 1e-9):
    """Decompose a 3x3 weight matrix into <= 3 rank-1 ``(row, col)`` terms
    by SVD: ``W = sum_i outer(row_i, col_i)`` with ``sqrt(sigma_i)`` folded
    into each factor (keeps both factors O(1), which matters once they are
    cast to the kernel's float32 operands).

    Singular values below ``tol * sigma_max`` are truncated — every
    shipped workload is exactly rank 2 (Life's ones-minus-center, Heat's
    5-point cross, Gray-Scott's 9-point Laplacian all have two equal
    rows), so truncation only drops numerical noise. Reconstruction is
    verified here: a workload whose weights the decomposition cannot
    represent exactly fails loudly at build time, not with silently wrong
    aggregates.
    """
    w = np.asarray(weights3x3, np.float64)
    if w.shape != (3, 3):
        raise ValueError(f"need a 3x3 weight matrix, got {w.shape}")
    u, s, vh = np.linalg.svd(w)
    keep = s > (tol * s[0] if s[0] > 0 else tol)
    terms = tuple(
        (tuple(float(x) for x in u[:, i] * np.sqrt(s[i])),
         tuple(float(x) for x in vh[i, :] * np.sqrt(s[i])))
        for i in range(3) if keep[i])
    recon = np.zeros((3, 3), np.float64)
    for row, col in terms:
        recon += np.outer(row, col)
    if not np.allclose(recon, w, rtol=0, atol=1e-12):
        raise ValueError(
            f"rank-1 SVD terms do not reconstruct the weight matrix "
            f"exactly (max err {np.abs(recon - w).max():.3e})")
    return terms


def banded_operators(terms, window: int, dtype=np.float32):
    """Build the banded contraction matrices for the rank-1 terms over a
    ``window x window`` tile: ``R`` (T, window, window) with
    ``R[t, y, y+dy] = row_t[dy+1]`` and ``C`` (T, window, window) with
    ``C[t, x, x+dx] = col_t[dx+1]``, so that ``R[t] @ X @ C[t].T`` sums
    ``row_t[dy+1] * col_t[dx+1] * X[y+dy, x+dx]`` over the 3x3 offsets.
    Border rows/cols get truncated bands (their outputs fall outside the
    shrinking live window of the fused substeps and are never read).
    """
    tm = np.zeros((len(terms), window, window), dtype)
    cm = np.zeros((len(terms), window, window), dtype)
    for t, (row, col) in enumerate(terms):
        for y in range(window):
            for d in (-1, 0, 1):
                if 0 <= y + d < window:
                    tm[t, y, y + d] = row[d + 1]
                    cm[t, y, y + d] = col[d + 1]
    return tm, cm


def check_workload_ndim(workload: "StencilWorkload", ndim: int):
    """Raise if a workload is bound to an engine of the wrong spatial
    dimensionality (e.g. the 2D heat instance on a 3D engine, whose
    Laplacian degree and stability bound would silently be wrong)."""
    if workload.ndim is not None and workload.ndim != ndim:
        raise ValueError(
            f"workload {workload.name!r} is {workload.ndim}D-only; "
            f"engine is {ndim}D")


def weighted_gather_agg(dirs, weights, gather, shape, agg_dtype) -> Array:
    """Weighted neighbor aggregate from a per-direction ``gather(offset)``
    callback (the gather/scatter engines' counterpart of
    ``weighted_moore_agg``). Zero-weight directions are never gathered;
    unit weights skip the multiply (keeps integer CA aggregates exact)."""
    agg = jnp.zeros(shape, agg_dtype)
    for d, wt in zip(dirs, weights):
        if wt == 0:
            continue
        val = gather(d).astype(agg_dtype)
        agg = agg + (val if wt == 1 else val * jnp.asarray(wt, agg_dtype))
    return agg


def _moore_split(weights):
    """(w_diag, w_orth) when the Moore weights are uniform per ring (all
    four diagonal weights equal, all four orthogonal weights equal) —
    every shipped workload — else None. Such a set separates as
    ``w_diag * ones3x3(minus center) + (w_orth - w_diag) * cross``, and
    the ones part factors into row/col partial sums: 6 shift-adds instead
    of 8 full-window gathers (fewer ops per substep — the lever that
    makes temporal fusion pay: the per-launch halo/exchange cost is
    amortized over k CHEAP substeps)."""
    w = dict(zip(MOORE_DIRS, weights))
    diag = {w[(-1, -1)], w[(1, -1)], w[(-1, 1)], w[(1, 1)]}
    orth = {w[(0, -1)], w[(-1, 0)], w[(1, 0)], w[(0, 1)]}
    if len(diag) == 1 and len(orth) == 1:
        return diag.pop(), orth.pop()
    return None


def _scaled(x: Array, wt, agg_dtype) -> Array:
    return x if wt == 1 else x * jnp.asarray(wt, agg_dtype)


def weighted_moore_agg(padded: Array, weights, agg_dtype) -> Array:
    """Weighted 8-neighbor aggregate from a (+1)-padded array.

    ``padded`` is (..., H+2, W+2); returns (..., H, W). Slicing runs on the
    trailing two axes, so channel/block leading axes broadcast through.
    Zero-weight directions are never read; unit weights skip the multiply
    (keeps integer CA aggregates exact).

    Ring-uniform weight sets (all shipped workloads) take a separable
    fast path: the ones3x3 component is built from row partial sums
    (R = x_up + x + x_down, then R_left + R + R_right minus the center),
    plus a 4-term cross correction when the rings differ — e.g. Life runs
    in 6 integer shift-adds instead of 8, bit-exact (pure adds, no
    weight multiplies).
    """
    h, w = padded.shape[-2] - 2, padded.shape[-1] - 2
    split = _moore_split(weights)
    if split is not None and split[0] != 0:
        wd, wo = split
        x = padded.astype(agg_dtype)
        # rows spans the padded width so the horizontal pass can shift it
        rows = (x[..., 0:h, :] + x[..., 1:h + 1, :] + x[..., 2:h + 2, :])
        sum9 = rows[..., 0:w] + rows[..., 1:w + 1] + rows[..., 2:w + 2]
        agg = _scaled(sum9 - x[..., 1:h + 1, 1:w + 1], wd, agg_dtype)
        if wo != wd:
            cross = (x[..., 0:h, 1:w + 1] + x[..., 1:h + 1, 0:w]
                     + x[..., 1:h + 1, 2:w + 2] + x[..., 2:h + 2, 1:w + 1])
            agg = agg + _scaled(cross, wo - wd, agg_dtype)
        return agg
    agg = jnp.zeros(padded.shape[:-2] + (h, w), agg_dtype)
    for (dx, dy), wt in zip(MOORE_DIRS, weights):
        if wt == 0:
            continue
        sl = padded[..., 1 + dy:h + 1 + dy, 1 + dx:w + 1 + dx]
        sl = sl.astype(agg_dtype)
        agg = agg + _scaled(sl, wt, agg_dtype)
    return agg


def weighted_moore_agg3(padded: Array, weights, agg_dtype) -> Array:
    """Weighted 26-neighbor aggregate from a (+1)-padded 3D array.

    ``padded`` is (..., D+2, H+2, W+2); returns (..., D, H, W) — the 3D
    counterpart of ``weighted_moore_agg``, slicing the trailing three axes
    so leading channel/block axes broadcast through.

    A uniform 26-weight set (LIFE3D) takes the separable fast path: the
    27-cell box sum is built from three axis passes (9 shift-adds instead
    of 26 gathers) and the center is subtracted — pure adds, bit-exact
    for integer CA aggregates. Every other set (e.g. HEAT3D's 6-point
    orthogonal Laplacian) falls back to the zero-skipping gather loop.
    """
    d = padded.shape[-3] - 2
    h = padded.shape[-2] - 2
    w = padded.shape[-1] - 2
    uniq = set(weights)
    if len(uniq) == 1 and 0 not in uniq:
        wt = uniq.pop()
        x = padded.astype(agg_dtype)
        # three separable passes: z, then y (spanning padded x so the
        # final pass can shift it), then x; minus the center
        slabs = x[..., 0:d, :, :] + x[..., 1:d + 1, :, :] \
            + x[..., 2:d + 2, :, :]
        rows = slabs[..., 0:h, :] + slabs[..., 1:h + 1, :] \
            + slabs[..., 2:h + 2, :]
        sum27 = rows[..., 0:w] + rows[..., 1:w + 1] + rows[..., 2:w + 2]
        return _scaled(sum27 - x[..., 1:d + 1, 1:h + 1, 1:w + 1], wt,
                       agg_dtype)
    agg = jnp.zeros(padded.shape[:-3] + (d, h, w), agg_dtype)
    for (dx, dy, dz), wt in zip(MOORE3_DIRS, weights):
        if wt == 0:
            continue
        sl = padded[..., 1 + dz:d + 1 + dz, 1 + dy:h + 1 + dy,
                    1 + dx:w + 1 + dx]
        agg = agg + _scaled(sl.astype(agg_dtype), wt, agg_dtype)
    return agg
