"""The ``StencilWorkload`` abstraction: what the engines simulate.

The paper frames Squeeze as a general scheme for data-parallel computation
on a fractal with neighborhood access; the game of life of Section 4 is
one instance. A workload bundles everything rule-specific so that the
engines (BB, lambda, Squeeze cell/block/3D) and the Pallas kernels stay
rule-agnostic. (The multi-device engine in core/distributed.py is still
life-only; its fused tile step has not been ported to workloads yet.)

  * ``dtype`` / ``agg_dtype``  — cell state and accumulation dtypes;
  * ``n_channels``             — 1 (scalar field) or C (e.g. Gray-Scott's
                                 (U, V) pair). Multi-channel states carry a
                                 leading channel axis: (C, *spatial).
  * ``weight(offset)``         — per-direction neighbor weight, dimension
                                 agnostic ((dx, dy) or (dx, dy, dz)); a 0
                                 weight means the direction is never read;
  * ``apply(center, agg, mask)`` — the update rule, given the weighted
                                 neighbor aggregate. ``mask`` is the {0,1}
                                 occupancy (holes/boundary), or None when
                                 the caller's domain has no holes (cell
                                 engine: every compact cell is real);
  * ``init(key, shape)``       — the initial-state distribution over the
                                 *unmasked* spatial domain (engines mask).

Out-of-fractal and hole neighbors always contribute 0 to the aggregate —
dead cells for CA rules, Dirichlet-0 boundaries for the PDE rules — which
is exactly the paper's adaptation of life to the fractal (Section 4).

See DESIGN.md Section 3 for how this composes with the engines and the
batched runner.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

Array = jnp.ndarray

#: Moore neighborhood directions (dx, dy), y growing downward. Defined here
#: (the dependency-free layer); ``core.compact`` re-exports it, so the
#: workloads package imports nothing from ``core`` (no import cycle).
MOORE_DIRS: Tuple[Tuple[int, int], ...] = (
    (-1, -1), (0, -1), (1, -1),
    (-1, 0), (1, 0),
    (-1, 1), (0, 1), (1, 1),
)


class StencilWorkload:
    """Base class; concrete workloads are frozen dataclasses (hashable, so
    a workload can be a jit static argument and an engine-cache key)."""

    name: str = "abstract"
    #: number of state channels; multi-channel states are (C, *spatial)
    n_channels: int = 1
    #: spatial dimensionality the rule is defined for (None = any)
    ndim = None
    #: cell state dtype
    dtype = jnp.uint8
    #: accumulation dtype for the neighbor aggregate
    agg_dtype = jnp.int32

    # ------------------------------------------------------------- rule spec
    def weight(self, offset) -> float:
        """Weight of the neighbor at ``offset`` (any dimensionality)."""
        return 1

    def apply(self, center: Array, agg: Array, mask) -> Array:
        """Update rule: next state from (center, weighted neighbor aggregate).

        ``center``/``agg`` have a leading channel axis iff n_channels > 1.
        Implementations must zero holes when ``mask`` is given.
        """
        raise NotImplementedError

    def init(self, key, shape) -> Array:
        """Initial state over spatial ``shape`` ((C, *shape) if C > 1)."""
        raise NotImplementedError

    # ------------------------------------------------------------ conveniences
    @property
    def weights2d(self):
        """Weights over the 2D Moore directions, MOORE_DIRS order."""
        return tuple(self.weight(d) for d in MOORE_DIRS)

    def tile_rule(self, center: Array, padded: Array, mask) -> Array:
        """One update on a halo-padded tile: ``center`` (C?, h, w), ``padded``
        (C?, h+2, w+2). This is the traced function the Pallas kernels call
        in place of the old hard-coded life rule."""
        agg = weighted_moore_agg(padded, self.weights2d, self.agg_dtype)
        return self.apply(center, agg, mask)

    def tile_rule_k(self, padded: Array, halo_mask, k: int) -> Array:
        """``k`` fused updates on a depth-``k`` padded tile (temporal
        blocking). ``padded`` is (C?, h+2k, w+2k); each substep updates the
        current window's interior and the window shrinks by one ring, so
        after ``k`` substeps the (C?, h, w) core has advanced ``k`` exact
        steps. ``halo_mask`` is the {0,1} occupancy of the *whole* window
        (trailing (h+2k, w+2k) axes; leading axes broadcast) or None; it is
        re-applied at every substep on a matching shrinking crop — halo
        cells belong to neighbor tiles whose holes/ghosts must stay zero
        mid-flight, not just at the final write."""
        cur = padded
        for _ in range(k):
            center = cur[..., 1:-1, 1:-1]
            agg = weighted_moore_agg(cur, self.weights2d, self.agg_dtype)
            if halo_mask is not None:
                halo_mask = halo_mask[..., 1:-1, 1:-1]
            cur = self.apply(center, agg, halo_mask)
        return cur

    def masked(self, state: Array, mask) -> Array:
        return state if mask is None else state * mask.astype(state.dtype)


#: which pieces of a Moore halo a single radius-1 update actually reads:
#: edge strips (rows N/S, cols W/E) and the four corner cells.
HaloNeeds = Tuple[bool, ...]


def halo_needs(weights) -> "HaloNeeds":
    """(need_n, need_s, need_w, need_e, need_nw, need_ne, need_sw, need_se)
    for one radius-1 Moore update with the given ``weights2d``.

    A corner halo cell is read only by the matching diagonal shift, so a
    zero diagonal weight makes that gather dead; an edge strip is read by
    its orthogonal shift *and* both adjacent diagonal shifts, so it is dead
    only when all three weights are zero (HeatDiffusion: 4 orthogonal
    strips gathered, 4 corner gathers skipped). Single-step (k=1) kernels
    only — a fused k>=2 substep chain propagates corner values inward even
    under orthogonal-only weights.
    """
    w = dict(zip(MOORE_DIRS, weights))
    need_nw, need_ne = w[(-1, -1)] != 0, w[(1, -1)] != 0
    need_sw, need_se = w[(-1, 1)] != 0, w[(1, 1)] != 0
    need_n = need_nw or need_ne or w[(0, -1)] != 0
    need_s = need_sw or need_se or w[(0, 1)] != 0
    need_w = need_nw or need_sw or w[(-1, 0)] != 0
    need_e = need_ne or need_se or w[(1, 0)] != 0
    return (need_n, need_s, need_w, need_e,
            need_nw, need_ne, need_sw, need_se)


def check_workload_ndim(workload: "StencilWorkload", ndim: int):
    """Raise if a workload is bound to an engine of the wrong spatial
    dimensionality (e.g. the 2D heat instance on a 3D engine, whose
    Laplacian degree and stability bound would silently be wrong)."""
    if workload.ndim is not None and workload.ndim != ndim:
        raise ValueError(
            f"workload {workload.name!r} is {workload.ndim}D-only; "
            f"engine is {ndim}D")


def weighted_gather_agg(dirs, weights, gather, shape, agg_dtype) -> Array:
    """Weighted neighbor aggregate from a per-direction ``gather(offset)``
    callback (the gather/scatter engines' counterpart of
    ``weighted_moore_agg``). Zero-weight directions are never gathered;
    unit weights skip the multiply (keeps integer CA aggregates exact)."""
    agg = jnp.zeros(shape, agg_dtype)
    for d, wt in zip(dirs, weights):
        if wt == 0:
            continue
        val = gather(d).astype(agg_dtype)
        agg = agg + (val if wt == 1 else val * jnp.asarray(wt, agg_dtype))
    return agg


def weighted_moore_agg(padded: Array, weights, agg_dtype) -> Array:
    """Weighted 8-neighbor aggregate from a (+1)-padded array.

    ``padded`` is (..., H+2, W+2); returns (..., H, W). Slicing runs on the
    trailing two axes, so channel/block leading axes broadcast through.
    Zero-weight directions are never read; unit weights skip the multiply
    (keeps integer CA aggregates exact).
    """
    h, w = padded.shape[-2] - 2, padded.shape[-1] - 2
    agg = jnp.zeros(padded.shape[:-2] + (h, w), agg_dtype)
    for (dx, dy), wt in zip(MOORE_DIRS, weights):
        if wt == 0:
            continue
        sl = padded[..., 1 + dy:h + 1 + dy, 1 + dx:w + 1 + dx]
        sl = sl.astype(agg_dtype)
        agg = agg + (sl if wt == 1 else sl * jnp.asarray(wt, agg_dtype))
    return agg
