"""Concrete stencil workloads.

  * ``TotalisticCA``  — outer-totalistic cellular automaton with arbitrary
                        born/survive neighbor-count sets; ``LIFE`` (B3/S23)
                        is the paper's Section 4 case study, ``LIFE3D``
                        (B6/S5-7) is the 3D variant used by stencil3d.
  * ``HeatDiffusion`` — float32 Jacobi iteration of the heat equation with
                        Dirichlet-0 holes (orthogonal-neighbor Laplacian).
  * ``GrayScott``     — 2-channel float32 Gray-Scott reaction-diffusion
                        (9-point Laplacian, Karl Sims' classic parameters).

All are frozen dataclasses: hashable, usable as jit static arguments and
as compiled-engine cache keys (workloads/runner.py).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet

import jax
import jax.numpy as jnp

from repro.workloads.base import StencilWorkload

Array = jnp.ndarray


def life_rule(alive: Array, neighbors: Array) -> Array:
    """Conway B3/S23, uint8 in/out (the paper's Section 4 rule; kept as a
    function because the engine tests and kernel oracles bind to it)."""
    born = neighbors == 3
    survive = (alive > 0) & (neighbors == 2)
    return (born | survive).astype(jnp.uint8)


def _count_in(agg: Array, counts: FrozenSet[int]) -> Array:
    """Boolean: agg is one of the (static) counts."""
    hit = jnp.zeros(agg.shape, bool)
    for c in sorted(counts):
        hit = hit | (agg == c)
    return hit


@dataclasses.dataclass(frozen=True)
class TotalisticCA(StencilWorkload):
    """Outer-totalistic CA over the Moore neighborhood: a dead cell is born
    when its live-neighbor count is in ``born``; a live cell survives when
    it is in ``survive``. Holes and out-of-fractal cells count 0."""

    name: str = "life"
    born: FrozenSet[int] = frozenset({3})
    survive: FrozenSet[int] = frozenset({2, 3})

    def apply(self, center, agg, mask):
        alive = center > 0
        nxt = jnp.where(alive, _count_in(agg, self.survive),
                        _count_in(agg, self.born)).astype(jnp.uint8)
        return self.masked(nxt, mask)

    def init(self, key, shape):
        return jax.random.bernoulli(key, 0.5, shape).astype(jnp.uint8)


@dataclasses.dataclass(frozen=True)
class HeatDiffusion(StencilWorkload):
    """Explicit Jacobi step u += alpha * lap(u) with Dirichlet-0 holes.

    The Laplacian is the orthogonal-neighbor stencil ``agg - degree * u``
    (degree = 4 in 2D, 6 in 3D); diagonal directions carry weight 0 and
    are never gathered. Stable for alpha <= 1/degree.
    """

    name: str = "heat"
    alpha: float = 0.2
    degree: int = 4  # 2 * ndim

    dtype = jnp.float32
    agg_dtype = jnp.float32

    @property
    def ndim(self):
        return self.degree // 2  # degree = 2 * ndim orthogonal neighbors

    def weight(self, offset):
        return 1 if sum(abs(d) for d in offset) == 1 else 0

    def apply(self, center, agg, mask):
        u = center.astype(jnp.float32)
        nxt = u + self.alpha * (agg - self.degree * u)
        return self.masked(nxt, mask)

    def init(self, key, shape):
        return jax.random.uniform(key, shape, jnp.float32)


@dataclasses.dataclass(frozen=True)
class GrayScott(StencilWorkload):
    """Gray-Scott reaction-diffusion, channels (U, V):

        u' = u + du * lap(u) - u v^2 + feed (1 - u)
        v' = v + dv * lap(v) + u v^2 - (feed + kill) v

    with the normalized 9-point Laplacian (0.2 orthogonal, 0.05 diagonal,
    weights sum to 1: lap = agg - u) and dt = 1. Holes are Dirichlet-0 in
    both channels.
    """

    name: str = "gray-scott"
    du: float = 1.0
    dv: float = 0.5
    feed: float = 0.055
    kill: float = 0.062

    n_channels = 2
    ndim = 2
    dtype = jnp.float32
    agg_dtype = jnp.float32

    def weight(self, offset):
        if len(offset) != 2:
            raise ValueError("GrayScott is a 2D workload")
        return 0.2 if sum(abs(d) for d in offset) == 1 else 0.05

    def apply(self, center, agg, mask):
        u, v = center[0].astype(jnp.float32), center[1].astype(jnp.float32)
        lap_u = agg[0] - u
        lap_v = agg[1] - v
        uvv = u * v * v
        nu = u + self.du * lap_u - uvv + self.feed * (1.0 - u)
        nv = v + self.dv * lap_v + uvv - (self.feed + self.kill) * v
        return self.masked(jnp.stack([nu, nv]), mask)

    def init(self, key, shape):
        seeds = jax.random.bernoulli(key, 0.02, shape)
        u = 1.0 - 0.5 * seeds.astype(jnp.float32)
        v = 0.25 * seeds.astype(jnp.float32)
        return jnp.stack([u, v])


LIFE = TotalisticCA()
LIFE3D = TotalisticCA(name="life3d", born=frozenset({6}),
                      survive=frozenset({5, 6, 7}))
HIGHLIFE = TotalisticCA(name="highlife", born=frozenset({3, 6}),
                        survive=frozenset({2, 3}))
SEEDS = TotalisticCA(name="seeds", born=frozenset({2}),
                     survive=frozenset())
HEAT = HeatDiffusion()
HEAT3D = HeatDiffusion(name="heat3d", alpha=0.125, degree=6)
GRAY_SCOTT = GrayScott()

#: name -> workload registry (2D engine-compatible entries only)
WORKLOADS = {w.name: w for w in
             (LIFE, HIGHLIFE, SEEDS, HEAT, GRAY_SCOTT)}


def get_workload(name: str) -> StencilWorkload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; "
                       f"have {sorted(WORKLOADS)}") from None
