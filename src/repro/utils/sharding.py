"""Sharding rules: map parameter-tree paths and activation roles onto
PartitionSpecs for the production mesh.

Philosophy (MaxText-style, divisibility-safe):
  * batch shards over ("pod", "data") — DP across pods, DP/FSDP within;
  * "model" is the tensor-parallel axis: attention heads, ffn hidden,
    vocab, experts;
  * parameters additionally FSDP-shard their d_model-sized axis over
    "data" (ZeRO-3); the per-layer all-gather is emitted by XLA inside the
    scan body;
  * every rule degrades to replication when the dimension does not divide
    the axis size (e.g. 12 heads on a 16-wide model axis) — a wrong-but-
    compiling spec is worse than a replicated one.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical roles of the mesh axes (None = role absent in this mesh)."""
    batch: Tuple[str, ...] = ("pod", "data")
    fsdp: Optional[str] = "data"
    model: Optional[str] = "model"

    def present(self, mesh: Mesh) -> "MeshAxes":
        names = set(mesh.axis_names)
        return MeshAxes(
            batch=tuple(a for a in self.batch if a in names),
            fsdp=self.fsdp if self.fsdp in names else None,
            model=self.model if self.model in names else None,
        )


def axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    if axis is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    """Can `dim` be sharded over `axis` (str or tuple) on this mesh?"""
    if axis is None:
        return False
    if isinstance(axis, str):
        size = axis_size(mesh, axis)
    else:
        size = 1
        for a in axis:
            size *= axis_size(mesh, a)
    return size > 1 and dim % size == 0


# --------------------------------------------------------------- param rules
#: (path-regex, per-dim axis roles). Roles: "model", "fsdp", None.
#: First match wins; checked against "/".join(path keys).
_PARAM_RULES = (
    # embeddings / unembedding: vocab model-sharded, d_model fsdp-sharded
    (r"(tok_embed|pos_embed|lm_head)$", ("model", "fsdp")),
    # attention projections (leading unit-stack dim handled separately):
    # wq/wkv: (d_model, heads, head_dim); wo: (heads, head_dim, d_model)
    (r"attn/wq$", ("fsdp", "model", None)),
    (r"attn/w[kv]$", ("fsdp", "model", None)),
    (r"attn/wo$", ("model", None, "fsdp")),
    (r"attn/b[qkv]$", (None, None)),
    # MoE experts: (E, d_model, d_ff) — EP over model if E divides, else
    # fall through to ffn TP on the hidden dim
    (r"moe/(w_gate|w_up)$", ("model_or_none", "fsdp", "model_if_free")),
    (r"moe/w_down$", ("model_or_none", "model_if_free", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    # arctic-style dense residual MLP nested under moe/dense/
    (r"dense/(w_gate|w_up)$", ("fsdp", "model")),
    (r"dense/w_down$", ("model", "fsdp")),
    # dense ffn: hidden dim model-sharded
    (r"mlp/(w_gate|w_up)$", ("fsdp", "model")),
    (r"mlp/w_down$", ("model", "fsdp")),
    # recurrent (RG-LRU) and SSM: inner dim model-sharded
    (r"(rec|ssm)/(w_x|w_gate|in_proj)$", ("fsdp", "model")),
    (r"(rec|ssm)/(out_proj|w_out)$", ("model", "fsdp")),
    (r"(rec|ssm)/", (None,)),  # small per-channel params: replicate
    # norms, biases, scalars: replicated
    (r"", (None,)),
)


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh,
                   axes: MeshAxes, stacked: bool = False) -> P:
    """PartitionSpec for one parameter. ``stacked`` strips the leading
    layer-stack dim (it is never sharded)."""
    dims = list(shape[1:] if stacked else shape)
    for pattern, roles in _PARAM_RULES:
        if re.search(pattern, path):
            spec = []
            model_used = False
            roles = list(roles) + [None] * (len(dims) - len(roles))
            for dim, role in zip(dims, roles):
                if role == "model" and _fits(dim, mesh, axes.model):
                    spec.append(axes.model)
                    model_used = True
                elif role == "model_or_none" and _fits(dim, mesh, axes.model):
                    spec.append(axes.model)
                    model_used = True
                elif role == "fsdp" and _fits(dim, mesh, axes.fsdp):
                    spec.append(axes.fsdp)
                elif role == "model_if_free" and not model_used \
                        and _fits(dim, mesh, axes.model):
                    spec.append(axes.model)
                    model_used = True
                else:
                    spec.append(None)
            if stacked:
                spec = [None] + spec
            return P(*spec)
    return P()


def param_specs(params, mesh: Mesh, axes: Optional[MeshAxes] = None,
                stacked_prefixes: Tuple[str, ...] = ("units", "tail",
                                                     "enc_units",
                                                     "dec_units")):
    """Build a PartitionSpec tree matching a parameter tree.

    Leaves under any ``stacked_prefixes`` subtree are treated as stacked
    (leading scan dim unsharded).
    """
    axes = (axes or MeshAxes()).present(mesh)

    def one(path_tuple, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None))
                for k in path_tuple]
        path = "/".join(str(k) for k in keys)
        stacked = any(str(keys[0]) == p for p in stacked_prefixes) \
            if keys else False
        return spec_for_param(path, leaf.shape, mesh, axes, stacked=stacked)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------- activation specs
def batch_spec(mesh: Mesh, axes: Optional[MeshAxes] = None, *,
               extra_dims: int = 1) -> P:
    """P(batch_axes, None * extra_dims) for (B, S, ...) activations."""
    axes = (axes or MeshAxes()).present(mesh)
    lead = axes.batch if axes.batch else None
    return P(lead, *([None] * extra_dims))


def constraint(x, mesh: Optional[Mesh], spec: P):
    """with_sharding_constraint that degrades to identity without a mesh."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
