"""Version compatibility shims for the jax API surface this repo uses.

The repo targets the newer jax API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``); the pinned CI / container runtime is jax 0.4.x where
``shard_map`` still lives in ``jax.experimental`` and ``Mesh`` has no axis
types. Route every use through here so call sites stay version-agnostic.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = True):
    """``jax.shard_map`` where available, else the jax.experimental one.

    ``check_rep`` is forwarded under whichever name the installed jax
    uses (``check_rep`` on 0.4.x/experimental, ``check_vma`` on newer
    ``jax.shard_map``) so disabling replication checks behaves the same
    across versions.
    """
    import inspect
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    params = inspect.signature(fn).parameters
    for name in ("check_rep", "check_vma"):
        if name in params:
            kwargs[name] = check_rep
            break
    return fn(f, **kwargs)


def pvary(x, axis_names):
    """``jax.lax.pvary`` where it exists (newer jax requires marking values
    as device-varying inside shard_map); identity on 0.4.x, where every
    value is implicitly varying."""
    fn = getattr(jax.lax, "pvary", None)
    return x if fn is None else fn(x, axis_names)


def cost_analysis_dict(compiled):
    """``compiled.cost_analysis()`` normalized to a flat dict (0.4.x returns
    a one-element list of dicts, newer jax returns the dict directly, some
    backends return None)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh``, passing ``axis_types`` only where it exists.

    On jax >= 0.5 explicit ``AxisType.Auto`` matches the old implicit
    default; on 0.4.x every mesh axis is Auto and the kwarg is absent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and auto_axes:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
