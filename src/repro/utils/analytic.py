"""First-principles (napkin-math) roofline model per (arch x shape x mesh).

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts loop bodies ONCE
(verified: a 10-trip scan of matmuls reports the flops of one trip), and
our programs keep the layer stack, the chunked-attention blocks and the
SSD chunk recurrence inside scans — so compiled counts undercount by the
trip counts. The roofline terms in EXPERIMENTS.md are therefore computed
here, from the model math we control, with the compiled artifact used for
(a) proving the cell lowers/compiles and fits memory, (b) the collective
op inventory + per-trip payloads (spot-checked against these estimates).

All byte counts are per device; flops are reported both global and per
device. Collective cost uses ring algorithms: all-gather/reduce-scatter
move size*(n-1)/n per device; all-reduce 2x that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.common import SHAPES
from repro.models.config import ModelConfig
from repro.utils import hlo as hlo_lib


@dataclasses.dataclass
class MeshModel:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def n_chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:           # batch shards
        return self.pod * self.data


def _ring_ag(size_bytes: float, n: int) -> float:
    """Per-device bytes moved by a ring all-gather of a size/n shard."""
    return size_bytes * (n - 1) / n if n > 1 else 0.0


def _ring_ar(size_bytes: float, n: int) -> float:
    return 2.0 * size_bytes * (n - 1) / n if n > 1 else 0.0


def _layer_specs(cfg: ModelConfig):
    return list(cfg.unit) * cfg.n_units + list(cfg.tail)


def _attn_kv_len(spec_window: Optional[int], s: int) -> float:
    """Average effective kv length per query (causal; window-clipped)."""
    if spec_window is None or spec_window >= s:
        return (s + 1) / 2.0
    w = spec_window
    # first w tokens see (i+1), the rest see w
    return (w * (w + 1) / 2.0 + (s - w) * w) / s


def flops_model(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    """Global flops, split useful (6ND-style) vs executed (incl. remat)."""
    seq, gbatch, kind = SHAPES[shape_name]
    s_text = seq - (cfg.n_patches or 0)
    d = cfg.d_model
    hd = cfg.head_dim_

    if kind == "train":
        tokens = gbatch * s_text
        s_ctx = s_text
    elif kind == "prefill":
        tokens = gbatch * s_text
        s_ctx = s_text
    else:
        tokens = gbatch * 1
        s_ctx = seq  # attends over the full cache

    # --- matmul params touched per token (active for MoE)
    n_active = cfg.active_param_count()
    mat_flops_fwd = 2.0 * n_active * tokens

    # --- attention score/value flops (not in 6ND)
    attn_flops_fwd = 0.0
    for spec in _layer_specs(cfg):
        if spec.kind != "attn":
            continue
        if kind == "decode":
            kv = min(spec.window, s_ctx) if spec.window else s_ctx
        else:
            kv = _attn_kv_len(spec.window, s_ctx)
        attn_flops_fwd += 4.0 * cfg.n_heads * hd * kv * tokens
    # encoder stack (bidirectional, enc_seq ctx) for enc-dec
    if cfg.family == "encdec" and kind != "decode":
        enc_tokens = gbatch * cfg.enc_seq
        attn_flops_fwd += (4.0 * cfg.n_heads * hd * cfg.enc_seq
                           * enc_tokens * cfg.n_enc_units)
        # cross-attention reads enc memory from every decoder layer
        attn_flops_fwd += (4.0 * cfg.n_heads * hd * cfg.enc_seq
                           * tokens * cfg.n_units)
    if cfg.family == "encdec" and kind == "decode":
        attn_flops_fwd += (4.0 * cfg.n_heads * hd * cfg.enc_seq
                           * tokens * cfg.n_units)

    # --- SSD state flops (chunked scan; not matmul-param flops)
    ssd_fwd = 0.0
    n_ssm = sum(1 for sp in _layer_specs(cfg) if sp.kind == "ssm")
    if n_ssm:
        s_ssm = cfg.ssm
        d_in = s_ssm.expand * d
        if kind == "decode":
            # state update: dt*B x + C.h per head: ~4 * d_in * N
            ssd_fwd = 4.0 * d_in * s_ssm.d_state * tokens * n_ssm
        else:
            # intra-chunk quadratic (~2*L*(d_in + h*N)) + states
            l_ = s_ssm.chunk
            per_tok = 2.0 * l_ * d_in + 4.0 * d_in * s_ssm.d_state
            ssd_fwd = per_tok * tokens * n_ssm

    fwd = mat_flops_fwd + attn_flops_fwd + ssd_fwd
    useful = fwd if kind != "train" else 6.0 * n_active * tokens

    if kind == "train":
        # fwd + bwd(2x) + full-remat recompute (~1x fwd)
        remat = 1.0 if cfg.remat == "full" else 0.0
        executed = fwd * (3.0 + remat) + attn_flops_fwd * (3.0 + remat) * 0
    else:
        executed = fwd
    return {"useful": useful, "executed": executed, "fwd": fwd,
            "attn_fwd": attn_flops_fwd, "tokens": float(tokens)}


def bytes_model(cfg: ModelConfig, shape_name: str, mesh: MeshModel
                ) -> Dict[str, float]:
    """Per-device HBM bytes per step (dominant terms)."""
    seq, gbatch, kind = SHAPES[shape_name]
    s_text = seq - (cfg.n_patches or 0)
    d = cfg.d_model
    n_params = cfg.param_count()
    dp, tp = mesh.dp, mesh.model

    if kind == "train":
        p_bytes = 4.0 * n_params / mesh.n_chips     # fp32 sharded (FSDP+TP)
        opt_bytes = 8.0 * n_params / mesh.n_chips   # m+v fp32
        if cfg.param_count() > 5e10:
            opt_bytes = 2.0 * n_params / mesh.n_chips + 0.1e9  # int8 m/v
        grad_bytes = 4.0 * n_params / mesh.n_chips
        b_local = gbatch / dp
        sp_div = tp if (cfg.seq_shard and s_text % tp == 0) else 1
        act_bytes = (b_local * s_text * d * 2.0      # bf16 unit boundaries
                     * (len(cfg.unit) and cfg.n_units)) / sp_div
        logits_bytes = (b_local * s_text * cfg.vocab_padded * 4.0 / tp
                        if cfg.vocab_padded % tp == 0
                        else b_local * s_text * cfg.vocab_padded * 4.0)
        # params touched 3x (fwd, remat, bwd) + grads + opt read/write
        total = (3.0 * p_bytes + 2.0 * grad_bytes + 2.0 * opt_bytes
                 + 3.0 * act_bytes + 3.0 * logits_bytes)
        return {"total": total, "params": p_bytes, "opt": opt_bytes,
                "acts": act_bytes, "logits": logits_bytes}

    p_bytes = 2.0 * n_params / mesh.n_chips          # bf16 serve
    if kind == "prefill":
        b_local = gbatch / dp if gbatch % dp == 0 else gbatch
        act_bytes = b_local * s_text * d * 2.0 * cfg.n_layers
        return {"total": p_bytes + act_bytes, "params": p_bytes,
                "acts": act_bytes, "kv": 0.0}

    # decode: params once + KV cache read per token
    kv_elem_bytes = 1.0 + 4.0 / cfg.head_dim_ if cfg.kv_quant else 2.0
    kv_bytes = 0.0
    for spec in _layer_specs(cfg):
        if spec.kind == "attn":
            s_kv = min(spec.window, seq) if spec.window else seq
            per_layer = (2.0 * cfg.n_kv_heads * cfg.head_dim_ * s_kv
                         * kv_elem_bytes)
            kv_bytes += per_layer * gbatch
        elif spec.kind == "ssm":
            d_in = cfg.ssm.expand * d
            nh = d_in // cfg.ssm.head_dim
            kv_bytes += 4.0 * nh * cfg.ssm.head_dim * cfg.ssm.d_state \
                * gbatch
        elif spec.kind == "rec":
            kv_bytes += 4.0 * (cfg.rec.d_rec or d) * gbatch
    if cfg.family == "encdec":
        kv_bytes += (2.0 * cfg.n_heads * cfg.head_dim_ * cfg.enc_seq
                     * 2.0 * gbatch * cfg.n_units)
    kv_bytes /= mesh.n_chips  # cache sharded (batch x heads/seq)
    return {"total": p_bytes + 2.0 * kv_bytes, "params": p_bytes,
            "kv": kv_bytes, "acts": 0.0}


def collective_model(cfg: ModelConfig, shape_name: str, mesh: MeshModel
                     ) -> Dict[str, float]:
    """Per-device collective payload bytes per step."""
    seq, gbatch, kind = SHAPES[shape_name]
    s_text = seq - (cfg.n_patches or 0)
    d = cfg.d_model
    n_params = cfg.param_count()
    dp, tp = mesh.dp, mesh.model
    out: Dict[str, float] = {}

    if kind == "train":
        p_shard = 4.0 * n_params / mesh.n_chips
        # FSDP: AG params (fwd + remat) + RS grads, over the data axis
        out["fsdp_ag"] = 2.0 * _ring_ag(p_shard * mesh.data, mesh.data)
        out["fsdp_rs"] = _ring_ag(p_shard * mesh.data, mesh.data)
        # DP across pods: grads all-reduce over pod axis
        out["pod_ar"] = _ring_ar(4.0 * n_params / (mesh.data * mesh.model),
                                 mesh.pod)
        # TP: 2 all-reduces per layer (attn-out + mlp-out), fwd+bwd.
        # Under SP the ARs become RS+AG pairs — same ring bytes, so the
        # collective term is unchanged (the SP win is the memory term).
        b_local = gbatch / dp
        act = b_local * s_text * d * 2.0
        n_ar = sum(2 if sp.kind == "attn" else 1
                   for sp in _layer_specs(cfg))
        out["tp_ar"] = _ring_ar(act, tp) * n_ar * 2.0
        if cfg.moe is not None:
            # shard-local dispatch (the default): tokens never cross
            # shards; the cross-shard cost is the expert-weight FSDP
            # all-gather over the data axis (fwd + remat'd bwd) + the
            # grads reduce-scatter — already covered by fsdp_* above for
            # the expert share. The old global-dispatch a2a term is gone.
            out["moe_a2a"] = 0.0
    else:
        b_local = gbatch / dp if gbatch % dp == 0 else gbatch
        s_eff = 1 if kind == "decode" else s_text
        act = b_local * s_eff * d * 2.0
        n_ar = sum(2 if sp.kind == "attn" else 1
                   for sp in _layer_specs(cfg))
        out["tp_ar"] = _ring_ar(act, tp) * n_ar
        # MoE: shard-local dispatch — weights replicated for serving (bf16
        # params already counted in bytes_model); no token a2a.
    out["total"] = sum(out.values())
    return out


def analytic_roofline(cfg: ModelConfig, shape_name: str, mesh: MeshModel
                      ) -> hlo_lib.Roofline:
    fl = flops_model(cfg, shape_name)
    by = bytes_model(cfg, shape_name, mesh)
    co = collective_model(cfg, shape_name, mesh)
    return hlo_lib.Roofline(
        flops=fl["executed"] / mesh.n_chips,
        hbm_bytes=by["total"],
        coll_bytes=co["total"],
        n_chips=mesh.n_chips,
        model_flops=fl["useful"],
    )
