"""Compiled-HLO analysis: collective-bytes extraction and the three-term
roofline (compute / memory / collective) for TPU v5e targets.

collective_bytes is not in cost_analysis(); we parse the compiled module
text and sum the output-operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (tuple outputs
included).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (approx; per spec)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.   %x = f32[64,512]{1,0} all-reduce(...)
#        %y = (f32[8,4]{...}, f32[8,4]{...}) all-gather(...)
_OP_LINE = re.compile(
    r"=\s*(\(?[^=]*?)\s*(" + "|".join(_COLLECTIVES)
    + r")(?:-(?:start|done))?\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of collective output bytes per op kind, over the whole module.

    ``-start`` variants counted, ``-done`` skipped (same transfer)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_LINE.search(line)
        if not m:
            continue
        kind = m.group(2)
        type_str = m.group(1)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(type_str))
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled step on one mesh.

    NOTE on units: XLA SPMD emits one per-device program, and both
    cost_analysis() and the parsed HLO shapes are **per-device** numbers.
    The roofline terms therefore divide by per-chip rates only (this is
    algebraically identical to the spec's global_FLOPs/(chips*peak) form,
    since global = per_device * chips for SPMD); ``model_flops`` is global
    and is normalised by n_chips where compared."""
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective payload bytes
    n_chips: int
    model_flops: float = 0.0     # GLOBAL 6*N*D (6*N_active*D for MoE)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-device-normalised) — remat and
        redundancy waste detector (< 1 means HLO does extra work)."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.n_chips) / self.flops

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the dominant roofline term."""
        if self.t_bound <= 0:
            return 0.0
        per_dev_useful_t = (self.model_flops / self.n_chips) / PEAK_FLOPS
        return per_dev_useful_t / self.t_bound

    def row(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, n_chips: int,
                           model_flops: float = 0.0,
                           hlo_text: Optional[str] = None) -> Roofline:
    from repro.utils.jax_compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled) or {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(txt)
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total"]),
        n_chips=n_chips,
        model_flops=model_flops,
    )
