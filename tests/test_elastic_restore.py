"""Elastic rescale: a 1-device checkpoint restores onto an 8-device mesh
(new shardings via the put() hook) and training continues."""
import os
import pathlib
import subprocess
import sys

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticMarkov
from repro.launch.train import train
from repro.optim import adamw


def test_one_device_checkpoint_restores_on_eight(tmp_path):
    # phase 1: train 4 steps on THIS (1-device) process and checkpoint
    cfg = configs.get_smoke_config("smollm-135m")
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4)
    data = SyntheticMarkov(vocab=cfg.vocab, seq_len=16, global_batch=4,
                           seed=3)
    train(cfg, opt_cfg, data, steps=4, ckpt_dir=str(tmp_path),
          ckpt_every=4, log_every=0)
    assert CheckpointManager(str(tmp_path)).latest_step() == 4

    # phase 2: restore in an 8-device subprocess with mesh shardings
    script = pathlib.Path(__file__).parent / "_elastic_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script), str(tmp_path)],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    assert "ELASTIC_OK" in out.stdout
