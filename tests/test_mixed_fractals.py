"""Mixed-level NBB fractals (paper §5 future work): inverse property,
volume conservation, mask agreement, and exact reduction to the uniform
maps when every level uses the same generator."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import fractals, maps
from repro.core.mixed import MixedFractal

GENS = [fractals.SIERPINSKI, fractals.CARPET, fractals.VICSEK,
        fractals.EMPTY_BOTTLES]


def _all_compact(mf):
    rows, cols = mf.compact_dims()
    cy, cx = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    return (jnp.asarray(cx.reshape(-1).astype(np.int32)),
            jnp.asarray(cy.reshape(-1).astype(np.int32)))


CASES = [
    ("sier-carpet", (fractals.SIERPINSKI, fractals.CARPET)),
    ("carpet-vicsek-sier", (fractals.CARPET, fractals.VICSEK,
                            fractals.SIERPINSKI)),
    ("bottles-sier-sier", (fractals.EMPTY_BOTTLES, fractals.SIERPINSKI,
                           fractals.SIERPINSKI)),
]


@pytest.mark.parametrize("name,levels", CASES, ids=[c[0] for c in CASES])
def test_mixed_nu_inverts_lambda(name, levels):
    mf = MixedFractal(name, levels)
    rows, cols = mf.compact_dims()
    assert rows * cols == mf.volume
    cx, cy = _all_compact(mf)
    ex, ey = mf.lambda_map(cx, cy)
    bx, by, valid = mf.nu_map(ex, ey)
    assert bool(jnp.all(valid))
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(cx))
    np.testing.assert_array_equal(np.asarray(by), np.asarray(cy))


@pytest.mark.parametrize("name,levels", CASES, ids=[c[0] for c in CASES])
def test_mixed_lambda_lands_on_mask(name, levels):
    mf = MixedFractal(name, levels)
    cx, cy = _all_compact(mf)
    ex, ey = mf.lambda_map(cx, cy)
    mask = mf.mask()
    assert int(mask.sum()) == mf.volume
    assert mask[np.asarray(ey), np.asarray(ex)].all()
    # and images are unique
    n = mf.side
    flat = np.asarray(ey).astype(np.int64) * n + np.asarray(ex)
    assert len(np.unique(flat)) == mf.volume


def test_uniform_mixed_reduces_to_standard_maps():
    frac, r = fractals.SIERPINSKI, 4
    mf = MixedFractal("uniform", (frac,) * r)
    cx, cy = _all_compact(mf)
    ex_m, ey_m = mf.lambda_map(cx, cy)
    ex_s, ey_s = maps.lambda_map(frac, r, cx, cy)
    np.testing.assert_array_equal(np.asarray(ex_m), np.asarray(ex_s))
    np.testing.assert_array_equal(np.asarray(ey_m), np.asarray(ey_s))
    bx_m, by_m, _ = mf.nu_map(ex_m, ey_m)
    bx_s, by_s = maps.nu_map(frac, r, ex_s, ey_s)
    np.testing.assert_array_equal(np.asarray(bx_m), np.asarray(bx_s))
    np.testing.assert_array_equal(np.asarray(by_m), np.asarray(by_s))


@given(st.lists(st.sampled_from(GENS), min_size=1, max_size=4))
@settings(max_examples=40, deadline=None)
def test_property_mixed_roundtrip(levels):
    mf = MixedFractal("prop", tuple(levels))
    if mf.volume > 50000:
        return
    cx, cy = _all_compact(mf)
    # sample a handful
    idx = np.linspace(0, len(cx) - 1, 17).astype(int)
    ex, ey = mf.lambda_map(cx[idx], cy[idx])
    bx, by, valid = mf.nu_map(ex, ey)
    assert bool(jnp.all(valid))
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(cx[idx]))
    np.testing.assert_array_equal(np.asarray(by), np.asarray(cy[idx]))
