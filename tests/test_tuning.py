"""The autotuner: tables, precedence, telemetry, sweep quality gates.

* TuningTable JSON persistence: exact round-trip, versioning, diff;
* knob resolution precedence (explicit > table hit > heuristic) with
  ``engine.tune.{hit,miss,fallback}`` telemetry, the ``SQUEEZE_TUNING``
  kill switch and the ``SQUEEZE_TUNING_TABLE`` override;
* the sweep itself on a tiny config: the winner is parity-exact vs the
  heuristic engine and never slower than it on the same measurement
  matrix (the baseline is always swept);
* the SHIPPED table: loads, covers its preset, and is consulted by
  ``make_engine``/runner when ``fusion_k`` is left None.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.core.stencil import make_engine
from repro.tuning import (Candidate, EngineSpec, TableEntry, TuningTable,
                          candidate_space, default_table, preset_specs,
                          reset_default_table_cache, tune_many, tune_spec)
from repro.tuning.table import DEFAULT_TABLE_PATH, TABLE_VERSION
from repro.workloads.runner import BatchedRunner

SPEC = EngineSpec("block", 2, "sierpinski", 4, 1, "life")       # rho 2
MXU = EngineSpec("pallas-mxu", 2, "sierpinski", 4, 1, "life")


@pytest.fixture
def reg():
    prev = obs.enabled()
    obs.enable(True)
    obs.reset()
    try:
        yield obs.default_registry()
    finally:
        obs.reset()
        obs.enable(prev)


@pytest.fixture(autouse=True)
def _fresh_table_cache():
    reset_default_table_cache()
    yield
    reset_default_table_cache()


# ----------------------------------------------------------- the table
def test_table_round_trip_and_diff(tmp_path):
    t = TuningTable()
    t.put(SPEC, TableEntry(fusion_k=2, meta={"speedup": 1.25}))
    t.put(MXU, TableEntry(fusion_k=1, macro_p=4))
    path = str(tmp_path / "t.json")
    t.save(path)
    t2 = TuningTable.load(path)
    assert len(t2) == 2
    assert t2.get(SPEC).fusion_k == 2
    assert t2.get(SPEC).meta == {"speedup": 1.25}
    assert t2.get(MXU).macro_p == 4
    # different tunables, same identity -> same key (value update)
    t2.put(SPEC, TableEntry(fusion_k=1))
    d = t2.diff(t)
    assert not d["added"] and not d["removed"]
    assert list(d["changed"]) == [SPEC.tuning_key()]


def test_table_version_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": TABLE_VERSION + 1,
                                "entries": {}}))
    with pytest.raises(ValueError, match="version"):
        TuningTable.load(str(path))


def test_corrupt_table_degrades_to_fallback(tmp_path, monkeypatch, reg):
    path = tmp_path / "corrupt.json"
    path.write_text("{not json")
    monkeypatch.setenv("SQUEEZE_TUNING_TABLE", str(path))
    reset_default_table_cache()
    assert default_table() is None  # warned, not raised
    norm = SPEC.normalize()
    assert norm.fusion_k == 2      # heuristic (rho=2 -> k=2)
    assert reg.value("engine.tune.fallback", kind="block") == 1


# ------------------------------------------------- resolution precedence
def test_precedence_explicit_beats_table_beats_heuristic(reg):
    table = TuningTable()
    table.put(SPEC, TableEntry(fusion_k=1))
    # table hit overrides the heuristic (which says 2 for rho=2)
    assert SPEC.normalize(table=table).fusion_k == 1
    assert reg.value("engine.tune.hit", kind="block") == 1
    # explicit knob wins outright — fully resolved, no consult at all
    expl = dataclasses.replace(SPEC, fusion_k=2)
    assert expl.normalize(table=table).fusion_k == 2
    assert reg.value("engine.tune.hit", kind="block") == 1
    # no entry -> miss + heuristic
    other = dataclasses.replace(SPEC, r=3)
    assert other.normalize(table=table).fusion_k == 2
    assert reg.value("engine.tune.miss", kind="block") == 1
    # table=None -> heuristic only, silent
    assert SPEC.normalize(table=None).fusion_k == 2


def test_table_k_clamped_to_rho():
    table = TuningTable()
    table.put(SPEC, TableEntry(fusion_k=99))
    assert SPEC.normalize(table=table).fusion_k == SPEC.rho


def test_env_kill_switch(monkeypatch, tmp_path, reg):
    table = TuningTable()
    table.put(SPEC, TableEntry(fusion_k=1))
    path = str(tmp_path / "t.json")
    table.save(path)
    monkeypatch.setenv("SQUEEZE_TUNING_TABLE", path)
    reset_default_table_cache()
    assert SPEC.normalize().fusion_k == 1          # table active
    monkeypatch.setenv("SQUEEZE_TUNING", "off")
    assert SPEC.normalize().fusion_k == 2          # heuristic again
    assert reg.value("engine.tune.fallback", kind="block") == 1


def test_runner_consults_override_table(monkeypatch, tmp_path):
    """End to end: a table entry changes what k=None builds."""
    table = TuningTable()
    table.put(SPEC, TableEntry(fusion_k=1))
    path = str(tmp_path / "t.json")
    table.save(path)
    monkeypatch.setenv("SQUEEZE_TUNING_TABLE", path)
    reset_default_table_cache()
    runner = BatchedRunner()
    frac = SPEC.build_frac()
    eng = runner.engine_for("block", frac, 4, m=1)         # k=None
    assert eng.effective_fusion_k == 1                     # tuned
    # ...and shares the slot with the explicit equivalent
    assert runner.engine_for("block", frac, 4, m=1, k=1) is eng


# ------------------------------------------------------------ the sweep
def test_candidate_space_contains_baseline_and_bounds():
    cands = candidate_space(SPEC, n_blocks=27)
    assert Candidate(SPEC.normalize(table=None).fusion_k) in cands
    assert {c.fusion_k for c in cands} == {1, 2}            # 1..rho
    assert all(c.macro_p is None for c in cands)            # not MXU
    mxu = candidate_space(MXU, n_blocks=27)
    assert any(c.macro_p is not None for c in mxu)
    assert all(c.macro_p is None or 1 <= c.macro_p <= 27 for c in mxu)
    with pytest.raises(ValueError, match="no tunable knobs"):
        candidate_space(EngineSpec("cell", 2, "sierpinski", 4),
                        n_blocks=27)


def test_tune_spec_winner_is_parity_exact_and_not_slower():
    res = tune_spec(SPEC, steps=4, rounds=2, seed=3)
    assert not res.parity_failures
    assert res.baseline.label in res.times
    assert res.speedup >= 1.0       # baseline is in the sweep
    # bit-exact CA parity of the recorded winner vs the heuristic
    win = dataclasses.replace(SPEC, fusion_k=res.best.fusion_k,
                              macro_p=res.best.macro_p)
    base = SPEC.normalize(table=None)
    e_win, e_base = make_engine(win), make_engine(base)
    out_w = e_win.to_expanded(e_win.run(e_win.init_random(3), 6))
    out_b = e_base.to_expanded(e_base.run(e_base.init_random(3), 6))
    np.testing.assert_array_equal(np.asarray(out_w), np.asarray(out_b))


def test_tune_many_builds_consultable_table():
    table, results = tune_many([SPEC], steps=2, rounds=1)
    assert len(table) == 1 and len(results) == 1
    entry = table.get(SPEC)
    assert entry.fusion_k == results[0].best.fusion_k
    assert SPEC.normalize(table=table).fusion_k == entry.fusion_k


# ------------------------------------------------------ the shipped table
def test_shipped_table_loads_and_covers_presets():
    shipped = TuningTable.load(DEFAULT_TABLE_PATH)
    assert len(shipped) >= 1
    for spec in preset_specs("default"):
        assert shipped.get(spec) is not None, spec.tuning_key()
        # shipped winners carry provenance
        assert "speedup" in shipped.get(spec).meta


def test_make_engine_hits_shipped_table(monkeypatch, reg):
    monkeypatch.delenv("SQUEEZE_TUNING", raising=False)
    monkeypatch.delenv("SQUEEZE_TUNING_TABLE", raising=False)
    reset_default_table_cache()
    spec = preset_specs("ci")[0]            # covered by the shipped table
    assert spec.fusion_k is None
    eng = make_engine(spec)
    assert reg.value("engine.tune.hit", kind=spec.kind) == 1
    shipped = TuningTable.load(DEFAULT_TABLE_PATH)
    want = max(1, min(shipped.get(spec).fusion_k, spec.rho))
    assert eng.effective_fusion_k == want
