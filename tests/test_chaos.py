"""The chaos matrix: every injected fault class — in-step exception,
watchdog-detected hang, SIGTERM preemption, corrupted/truncated
checkpoint — must recover AUTOMATICALLY with bit-exact CA results vs an
uninterrupted run. Also covers the FaultInjector harness itself and the
recovery telemetry the CI chaos job uploads."""
import signal
import threading

import numpy as np
import pytest

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core import fractals
from repro.core.stencil import make_engine
from repro.runtime.fault import (Fault, FaultInjector, InjectedFault,
                                 PreemptionHandler, damage_checkpoint)
from repro.serving import FractalService, ServiceConfig, SimRequest
from repro.workloads import LIFE

FRAC = fractals.SIERPINSKI
STEPS = 24
N = 3


@pytest.fixture(scope="module")
def refs():
    """Uninterrupted ground truth, one per seed."""
    eng = make_engine("block", FRAC, 4, 1, workload=LIFE)
    return [np.asarray(eng.run(eng.init_random(s), STEPS))
            for s in range(N)]


def _cfg(tmp_path, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("backoff_cap_s", 0.05)
    kw.setdefault("hang_threshold_s", 1.0)
    kw.setdefault("compile_grace_s", 60.0)
    kw.setdefault("ckpt_dir", str(tmp_path / "ckpts"))
    return ServiceConfig(**kw)


def _reqs(prefix):
    return [SimRequest(frac=FRAC, r=4, steps=STEPS, m=1, seed=s,
                       snapshot_every=8, rid=f"{prefix}-{s}")
            for s in range(N)]


def _assert_bit_exact(res, refs):
    for i, r in enumerate(res):
        assert r.status == "ok", (r.rid, r.status, r.error)
        assert r.steps_done == STEPS
        np.testing.assert_array_equal(refs[i], r.state)


# --------------------------------------------------------- fault classes
def test_in_step_exception_recovers_bit_exact(tmp_path, refs):
    inj = FaultInjector([Fault(kind="exception", at_segment=1)])
    svc = FractalService(_cfg(tmp_path), injector=inj)
    res = svc.serve(_reqs("exc"))
    assert inj.all_fired()
    _assert_bit_exact(res, refs)
    assert all(r.recoveries >= 1 for r in res)
    assert all(r.retries >= 1 for r in res)


def test_watchdog_hang_restarts_engine_bit_exact(tmp_path, refs):
    inj = FaultInjector([Fault(kind="stall", at_segment=1, stall_s=2.5)])
    svc = FractalService(_cfg(tmp_path), injector=inj)
    res = svc.serve(_reqs("hang"))
    assert inj.all_fired()
    _assert_bit_exact(res, refs)
    assert svc.watchdog.hangs == 1  # detected, killed, restarted


def test_sigterm_preemption_drains_then_resumes_bit_exact(tmp_path,
                                                          refs):
    cfg = _cfg(tmp_path)
    inj = FaultInjector(
        [Fault(kind="preempt", at_segment=2, via_signal=True)])
    svc = FractalService(cfg, injector=inj)
    res = svc.serve(_reqs("pre"), install_signals=True)
    # drained: checkpointed mid-run, nothing lost, nothing wedged
    assert all(r.status == "preempted" for r in res)
    assert all(0 < r.steps_done < STEPS for r in res)
    # the trap was uninstalled on stop (satellite: handler restore)
    assert signal.getsignal(signal.SIGTERM) == signal.SIG_DFL
    # resume: same rids on a fresh service pick up the checkpoints
    svc2 = FractalService(_cfg(tmp_path))
    res2 = svc2.serve(_reqs("pre"))
    _assert_bit_exact(res2, refs)
    assert all(r.steps_done == STEPS for r in res2)


def test_programmatic_preemption_without_signals(tmp_path, refs):
    inj = FaultInjector([Fault(kind="preempt", at_segment=2)])
    svc = FractalService(_cfg(tmp_path), injector=inj)
    res = svc.serve(_reqs("ppre"))  # injector uses handler.request()
    assert all(r.status == "preempted" for r in res)
    res2 = FractalService(_cfg(tmp_path)).serve(_reqs("ppre"))
    _assert_bit_exact(res2, refs)


@pytest.mark.parametrize("mode", ["corrupt", "truncate"])
def test_damaged_checkpoint_falls_back_to_previous_step(tmp_path, refs,
                                                        mode):
    """Damage the newest checkpoint, then crash: recovery must fall
    back to the previous intact step and still finish bit-exact."""
    inj = FaultInjector([Fault(kind=mode, at_segment=1),
                         Fault(kind="exception", at_segment=2)])
    svc = FractalService(_cfg(tmp_path), injector=inj)
    res = svc.serve(_reqs(f"dmg-{mode}"))
    assert inj.all_fired()
    _assert_bit_exact(res, refs)


def test_composed_chaos_run(tmp_path, refs):
    """Everything at once, in sequence: exception, hang, corruption —
    one run survives the full matrix and stays bit-exact."""
    inj = FaultInjector([
        Fault(kind="exception", at_segment=1),
        Fault(kind="stall", at_segment=3, stall_s=2.0),
        Fault(kind="corrupt", at_segment=4),
        Fault(kind="exception", at_segment=5),
    ])
    svc = FractalService(_cfg(tmp_path, max_segment_steps=4),
                         injector=inj)
    res = svc.serve(_reqs("all"))
    assert inj.all_fired()
    _assert_bit_exact(res, refs)


def test_chaos_without_checkpoints_recomputes_from_seed(refs):
    """No durable dir at all: recovery falls back to recompute-from-
    seed and still lands bit-exact (slower, never wrong)."""
    inj = FaultInjector([Fault(kind="exception", at_segment=1)])
    svc = FractalService(
        ServiceConfig(max_batch=4, backoff_base_s=0.01,
                      hang_threshold_s=5.0, ckpt_dir=None),
        injector=inj)
    res = svc.serve(_reqs("nock"))
    _assert_bit_exact(res, refs)


# ------------------------------------------------------ recovery metrics
def test_recovery_metrics_surface(tmp_path, refs):
    """The counters the CI chaos job uploads: injected == recovered
    arithmetic is checkable from telemetry alone."""
    with obs.enabled_scope(True) as reg:
        obs.reset()
        inj = FaultInjector([Fault(kind="exception", at_segment=1),
                             Fault(kind="stall", at_segment=3,
                                   stall_s=2.0)])
        svc = FractalService(_cfg(tmp_path), injector=inj)
        res = svc.serve(_reqs("met"))
        _assert_bit_exact(res, refs)
        assert reg.counter("chaos.injected", kind="exception").value == 1
        assert reg.counter("chaos.injected", kind="stall").value == 1
        assert reg.counter("serve.retries", kind="block").value >= 1
        assert reg.counter("serve.restarts", kind="block").value == 1
        assert reg.counter("serve.recoveries", kind="block").value == 2
        rec = reg.histogram("serve.recovery_seconds", kind="block")
        assert rec.count == 2


# ------------------------------------------------------- injector harness
def test_injector_fires_each_fault_once():
    inj = FaultInjector([Fault(kind="exception", at_segment=0)])
    with pytest.raises(InjectedFault):
        inj.in_step(0)
    inj.in_step(1)  # already fired: no second raise
    assert inj.all_fired()
    assert inj.log == [(0, "exception", "raise")]


def test_injector_preempt_requires_route():
    inj = FaultInjector([Fault(kind="preempt", at_segment=0)])
    with pytest.raises(RuntimeError):
        inj.at_boundary(0)
    h = PreemptionHandler(install=False)
    inj2 = FaultInjector([Fault(kind="preempt", at_segment=0)],
                         handler=h)
    inj2.at_boundary(0)
    assert h.requested


def test_injector_claim_is_atomic_under_hammer():
    """8 threads race every hook call: each scheduled fault must fire
    EXACTLY once (the claim — scan, mark fired, log, count — is atomic
    under the injector's lock; without it two threads could both raise
    the same fault, double-counting chaos.injected)."""
    n_faults, n_threads = 50, 8
    with obs.enabled_scope(True) as reg:
        obs.reset()
        inj = FaultInjector([Fault(kind="exception", at_segment=s)
                             for s in range(n_faults)])
        barrier = threading.Barrier(n_threads)
        raises = [0] * n_threads

        def worker(i):
            # segments advance in lockstep so exactly one fault is due
            # per round — the contention is WITHIN each round, where
            # all 8 threads hit the same due fault at once
            for seg in range(n_faults):
                barrier.wait()
                try:
                    inj.in_step(seg)
                except InjectedFault:
                    raises[i] += 1

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sum(raises) == n_faults  # once each, never twice
        assert len(inj.log) == n_faults
        assert inj.all_fired()
        c = reg.counter("chaos.injected", kind="exception")
        assert c.value == n_faults


def test_dist_engine_rows_survive_chaos_bit_exact(tmp_path, refs):
    """dist-* rows route through the same recovery state machine:
    crash + damaged checkpoint on a dist-block request must restore
    from the sharded checkpoint (mesh-independent dense state) and
    finish bit-exact vs the single-device block reference."""
    inj = FaultInjector([Fault(kind="exception", at_segment=1),
                         Fault(kind="corrupt", at_segment=2),
                         Fault(kind="exception", at_segment=3)])
    svc = FractalService(_cfg(tmp_path), injector=inj)
    reqs = [SimRequest(frac=FRAC, r=4, steps=STEPS, m=1, seed=s,
                       kind="dist-block", snapshot_every=8,
                       rid=f"dchaos-{s}")
            for s in range(N)]
    res = svc.serve(reqs)
    assert inj.all_fired()
    _assert_bit_exact(res, refs)
    assert all(r.recoveries >= 1 for r in res)


def test_fault_kind_validated():
    with pytest.raises(ValueError):
        Fault(kind="meteor")


def test_damage_checkpoint_is_detectable(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": np.arange(16.0)})
    n = damage_checkpoint(str(tmp_path / "step_00000001"),
                          mode="corrupt")
    assert n == 1
    from repro.checkpoint.manager import CheckpointCorruptError
    with pytest.raises(CheckpointCorruptError):
        mgr.restore({"a": np.zeros(16)})
