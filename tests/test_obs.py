"""The telemetry layer (repro.obs) and its instrumentation hooks.

Three tiers: (1) the registry / span / exporter primitives in
isolation; (2) the wiring — runner cache counters, engine retrace
detectors, distributed collective accounting unified with
``exchange_stats()``, watchdog / restart / checkpoint counters; (3)
the acceptance path: one ``BatchedRunner.run`` on a distributed-fused
engine, then one ``obs.report()`` showing per-run latency histograms,
fused-launch and collective counts, cache hit/miss and memory-bytes
gauges (DESIGN.md Section 7).

Every test runs against a reset default registry with collection
forced on (and the ambient enabled/disabled state restored after) —
except the explicitly-disabled tests, which assert the no-op contract.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.core import fractals
from repro.core.compact import BlockLayout
from repro.core.distributed import make_distributed_engine
from repro.core.stencil import make_engine
from repro.workloads.rules import HIGHLIFE, LIFE
from repro.workloads.runner import BatchedRunner

FRAC = fractals.SIERPINSKI


@pytest.fixture
def reg():
    """Fresh default-registry state with telemetry ON; restores the
    ambient enabled flag afterwards."""
    prev = obs.enabled()
    obs.enable(True)
    obs.reset()
    try:
        yield obs.default_registry()
    finally:
        obs.reset()
        obs.enable(prev)


# ------------------------------------------------------------- registry
def test_counter_gauge_histogram_semantics(reg):
    c = reg.counter("c", kind="x")
    c.inc()
    c.inc(3)
    assert reg.value("c", kind="x") == 4
    # same (name, labels) -> the same metric object; new labels -> new
    assert reg.counter("c", kind="x") is c
    assert reg.counter("c", kind="y") is not c
    g = reg.gauge("g")
    g.set(7)
    g.add(-2)
    assert reg.value("g") == 5
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.record(v)
    assert h.count == 4 and h.sum == 10.0
    assert h.min == 1.0 and h.max == 4.0
    assert reg.get("missing") is None and reg.value("missing") is None


def test_type_collision_raises(reg):
    reg.counter("m")
    with pytest.raises(ValueError):
        reg.gauge("m")


def test_label_order_is_canonical(reg):
    a = reg.counter("c", x=1, y=2)
    b = reg.counter("c", y=2, x=1)
    assert a is b


def test_histogram_percentiles(reg):
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.record(float(v))
    # bucketed estimate: right order of magnitude + clamped to range
    assert h.percentile(0.0) == 1.0
    assert h.percentile(1.0) == 100.0
    assert 30.0 <= h.percentile(0.5) <= 70.0
    assert h.percentile(0.95) <= 100.0


def test_reset_zeros_in_place(reg):
    c = reg.counter("c")
    c.inc(5)
    h = reg.histogram("h")
    h.record(1.0)
    reg.reset()
    assert c.value == 0 and h.count == 0
    c.inc()  # handles stay live after reset
    assert reg.value("c") == 1


# ------------------------------------------------------------ exporters
def test_jsonl_round_trip(reg):
    reg.counter("c", kind="x").inc(3)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h", kind="x")
    h.record(0.5)
    h.record(4.0)
    back = obs.load_jsonl(obs.to_jsonl(reg))
    assert back.snapshot() == reg.snapshot()


def test_prometheus_text(reg):
    reg.counter("runner.cache.hit", kind="block").inc(2)
    reg.histogram("runner.run.seconds").record(0.25)
    text = obs.to_prometheus(reg)
    assert 'squeeze_runner_cache_hit{kind="block"} 2' in text
    assert "# TYPE squeeze_runner_run_seconds histogram" in text
    assert 'squeeze_runner_run_seconds_bucket{le="+Inf"} 1' in text
    assert "squeeze_runner_run_seconds_count 1" in text


def test_report_table(reg):
    reg.counter("c", kind="x").inc(2)
    reg.histogram("h").record(1.0)
    out = obs.report(reg)
    assert "c{kind=x}" in out and "2" in out
    assert "count=1" in out


# ---------------------------------------------------------------- spans
def test_span_nesting_and_chrome_trace(reg):
    with obs.span("outer", kind="x"):
        with obs.span("inner"):
            pass
    roots = obs.spans()
    assert roots[-1].name == "outer"
    assert [c.name for c in roots[-1].children] == ["inner"]
    assert roots[-1].dur_us >= roots[-1].children[0].dur_us
    events = obs.chrome_trace()["traceEvents"]
    names = [e["name"] for e in events]
    assert "outer" in names and "inner" in names
    json.dumps(events)  # must be serializable as-is


def test_timed_records_histogram(reg):
    with obs.timed("t.seconds", kind="x"):
        pass
    assert reg.get("t.seconds", kind="x").count == 1


# ------------------------------------------------------------- disabled
def test_disabled_helpers_are_noops(reg):
    # reset() zeroes in place but keeps handles alive, so assert no NEW
    # metrics appear (the registry is process-wide across tests)
    obs.enable(False)
    obs.inc("noop.c")
    obs.set_gauge("noop.g", 1)
    obs.observe("noop.h", 1.0)
    with obs.span("noop.s") as sp:
        assert sp is None  # the shared null context
    assert reg.get("noop.c") is None
    assert reg.get("noop.g") is None
    assert reg.get("noop.h") is None
    assert obs.spans() == ()


def test_enabled_scope_restores(reg):
    obs.enable(False)
    with obs.enabled_scope():
        assert obs.enabled()
        obs.inc("c")
    assert not obs.enabled()
    assert reg.value("c") == 1


def test_parse_env():
    for off in ("", "0", "off", "false", "no", "none", "OFF", None):
        assert not obs.parse_env(off)
    for on in ("1", "true", "yes", "on", "anything"):
        assert obs.parse_env(on)


# ------------------------------------------------------- runner wiring
def test_runner_cache_counters(reg, monkeypatch):
    monkeypatch.setenv("SQUEEZE_TUNING", "off")  # pin the heuristic k
    runner = BatchedRunner(capacity=1)
    states = runner.init_batch("block", FRAC, 4, seeds=range(2), m=1,
                               workload=LIFE)
    runner.run("block", FRAC, 4, states, steps=2, m=1, workload=LIFE)
    assert reg.value("runner.cache.miss", kind="block") == 1
    assert reg.value("runner.cache.hit", kind="block") >= 1
    # the runner resolves k=None to the heuristic before building
    # (rho = 3^1 -> k = 2), and labels the build with the resolved k
    assert reg.value("runner.build", kind="block", workload="life",
                     k=2) == 1
    assert reg.get("runner.run.seconds", kind="block").count == 1
    assert reg.get("runner.batch_size", kind="block").max == 2.0
    # capacity-1 cache: a second key evicts the first
    runner.init_batch("cell", FRAC, 4, seeds=range(2), workload=LIFE)
    assert reg.value("runner.cache.evict") == 1
    # registry counters mirror RunnerStats exactly
    assert runner.stats.evictions == 1
    assert runner.stats.builds == 2


def test_runner_trace_counter_matches_stats(reg):
    runner = BatchedRunner()
    states = runner.init_batch("block", FRAC, 4, seeds=range(2), m=1,
                               workload=HIGHLIFE)
    runner.run("block", FRAC, 4, states, steps=2, m=1, workload=HIGHLIFE)
    runner.run("block", FRAC, 4, states, steps=3, m=1, workload=HIGHLIFE)
    total = sum(m.value for m in reg.metrics()
                if m.name == "runner.trace")
    assert total == runner.stats.traces


# ------------------------------------------------------- engine wiring
def test_engine_retrace_counters_stay_constant(reg):
    # unlikely config (highlife, block, r=3, m=1) so earlier tests in
    # the process haven't already populated jit caches for it; the
    # invariant asserted is *constancy* across dynamic step counts, not
    # an absolute trace count
    eng = make_engine("block", FRAC, 3, 1, workload=HIGHLIFE)
    s = eng.init_random(seed=0)
    eng.run(s, 4)
    key = dict(engine=type(eng).__name__, fn="run")
    traces = reg.value("engine.trace", **key)
    launches = reg.value("engine.runs", engine=type(eng).__name__,
                         variant=getattr(eng, "variant", ""))
    eng.run(s, 7)
    eng.run(s, 2)
    assert reg.value("engine.trace", **key) == traces  # no retrace
    assert reg.value("engine.runs", engine=type(eng).__name__,
                     variant=getattr(eng, "variant", "")) == launches + 2


def test_engine_build_and_memory_gauge(reg):
    make_engine("block", FRAC, 4, 2, workload=LIFE)
    assert reg.value("engine.builds", kind="block") == 1
    assert reg.value("engine.memory_bytes", kind="block") > 0


def test_fused_launch_accounting(reg):
    eng = make_engine("block", FRAC, 4, 2, workload=LIFE, fusion_k=3)
    s = eng.init_random(seed=0)
    eng.run(s, 7)  # 2 fused launches of 3 + 1 single step
    labels = dict(engine=type(eng).__name__,
                  variant=getattr(eng, "variant", ""))
    assert reg.value("engine.fused_launches", **labels) == 2
    assert reg.value("engine.single_steps", **labels) == 1
    assert reg.value("engine.steps", **labels) == 7


# -------------------------------------------------- distributed wiring
def test_distributed_collectives_match_exchange_stats(reg):
    eng = make_distributed_engine(BlockLayout(FRAC, 5, 2), workload=LIFE,
                                  compute="fused", fusion_k=2)
    s = eng.init_random(0)
    eng.run(s, 5)  # ceil(5/2) = 3 exchange rounds
    st = eng.exchange_stats()
    assert st.collectives == 3
    assert reg.value("dist.collectives", compute="fused") == \
        st.collectives
    assert reg.value("dist.bytes_gathered", compute="fused") == \
        st.bytes_gathered
    assert reg.value("dist.steps", compute="fused") == st.steps == 5
    # the p2p counters mirror too (zero in gather mode, but PRESENT —
    # dashboards can subtract modes without schema branches)
    assert reg.value("dist.bytes_permuted", compute="fused") == \
        st.bytes_permuted
    assert reg.value("dist.neighbor_sends", compute="fused") == \
        st.neighbor_sends


def test_distributed_p2p_counters_match_exchange_stats(reg):
    """The p2p exchange mirrors its wire accounting into telemetry:
    dist.bytes_permuted / dist.neighbor_sends equal exchange_stats(),
    and the gather counter stays zero."""
    eng = make_distributed_engine(BlockLayout(FRAC, 5, 2), workload=LIFE,
                                  compute="jnp", fusion_k=2,
                                  exchange="p2p")
    assert eng.exchange_mode == "p2p"
    eng.run(eng.init_random(0), 5)
    st = eng.exchange_stats()
    assert st.collectives == 3 and st.bytes_gathered == 0
    for name, want in (("dist.collectives", st.collectives),
                       ("dist.bytes_permuted", st.bytes_permuted),
                       ("dist.neighbor_sends", st.neighbor_sends),
                       ("dist.bytes_gathered", 0),
                       ("dist.steps", 5)):
        assert reg.value(name, compute="jnp") == want, name


# ----------------------------------------------------- acceptance path
def test_end_to_end_report_on_distributed_fused(reg):
    runner = BatchedRunner()
    states = runner.init_batch("dist-fused", FRAC, 5, seeds=range(2),
                               m=2, workload=LIFE)
    out = runner.run("dist-fused", FRAC, 5, states, steps=5, m=2,
                     workload=LIFE)
    assert np.asarray(out).shape == np.asarray(states).shape
    # per-run latency histogram
    h = reg.get("runner.run.seconds", kind="dist-fused")
    assert h is not None and h.count == 1 and h.sum > 0
    # cache hit/miss (init_batch missed once, run hit)
    assert reg.value("runner.cache.miss", kind="dist-fused") == 1
    assert reg.value("runner.cache.hit", kind="dist-fused") >= 1
    # fused launches + collectives on the distributed engine
    assert reg.value("engine.fused_launches",
                     engine="DistributedSqueezeEngine",
                     variant="fused") >= 1
    assert reg.value("dist.collectives", compute="fused") >= 1
    # memory gauge from the build
    assert reg.value("engine.memory_bytes", kind="dist-fused") > 0
    # ...and all of it shows in one report() / JSONL export
    text = obs.report(reg)
    for needle in ("runner.run.seconds", "runner.cache.hit",
                   "engine.fused_launches", "dist.collectives",
                   "engine.memory_bytes"):
        assert needle in text, f"report missing {needle}\n{text}"
    back = obs.load_jsonl(obs.to_jsonl(reg))
    assert back.value("dist.collectives", compute="fused") == \
        reg.value("dist.collectives", compute="fused")


# ------------------------------------------------- fault + checkpoint
def test_watchdog_uses_registry_histogram(reg):
    from repro.runtime.fault import Watchdog
    wd = Watchdog(straggler_factor=3.0, min_samples=3)
    for _ in range(6):
        wd.start_step()
        wd.end_step()
    assert wd.histogram.count == 6
    assert wd.median >= 0.0
    assert reg.get("watchdog.step_seconds", watchdog=wd.name).count == 6


def test_watchdog_instances_do_not_share_samples(reg):
    from repro.runtime.fault import Watchdog
    a, b = Watchdog(), Watchdog()
    a.start_step()
    a.end_step()
    assert a.name != b.name
    assert a.histogram.count == 1
    assert b.histogram.count == 0


def test_run_with_restarts_counts_on_registry(reg):
    from repro.runtime.fault import SimulatedFailure, run_with_restarts
    calls = {"n": 0}

    def make_run():
        calls["n"] += 1
        if calls["n"] < 3:
            raise SimulatedFailure("boom")
        return 42

    assert run_with_restarts(make_run, max_restarts=3) == 42
    assert reg.value("fault.restarts") == 2


def test_checkpoint_counters(reg, tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ck"))
    tree = {"w": np.arange(8, dtype=np.float32)}
    mgr.save(3, tree)
    mgr.restore(tree)
    assert reg.value("checkpoint.saves") == 1
    assert reg.value("checkpoint.restores") == 1
    assert reg.value("checkpoint.bytes") == 32
    assert reg.get("checkpoint.save_seconds").count == 1
    assert reg.get("checkpoint.restore_seconds").count == 1
