"""Dry-run plumbing on a small mesh (subprocess, 8 devices): build_cell ->
lower -> compile -> roofline extraction for every step kind and family."""
import os
import pathlib
import subprocess
import sys


def test_dryrun_machinery_small_mesh():
    script = pathlib.Path(__file__).parent / "_dryrun_small_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    assert "DRYRUN_SMALL_OK" in out.stdout


def test_full_matrix_results_exist_and_pass():
    """The committed full-matrix results (68 cells x 2 meshes) all ok."""
    import json
    path = pathlib.Path(__file__).resolve().parents[1] / \
        "dryrun_results.jsonl"
    if not path.exists():
        import pytest
        pytest.skip(
            "full matrix not yet run (python -m repro.launch.dryrun --all)")
    rows = [json.loads(line) for line in open(path)]
    ok = [r for r in rows if r.get("ok")]
    assert len(ok) >= 68, f"only {len(ok)} passing cells"
    meshes = {r["mesh"] for r in ok}
    assert {"16x16", "2x16x16"} <= meshes
