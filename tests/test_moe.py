"""MoE dispatch: the compact capacity-bounded sort dispatch must equal a
dense per-token expert evaluation when capacity is ample; overflow drops
deterministically; aux loss behaves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod
from repro.models.config import LayerSpec, ModelConfig, MoESpec


def _cfg(capacity_factor=8.0, dense_residual=False, n_experts=4, top_k=2):
    return ModelConfig(
        name="m", d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        d_ff=64, vocab=64, unit=(LayerSpec(kind="attn"),), n_units=1,
        dtype="float32",
        moe=MoESpec(n_experts=n_experts, top_k=top_k, d_ff_expert=48,
                    capacity_factor=capacity_factor,
                    dense_residual_ff=48 if dense_residual else None))


def _dense_reference(p, x, cfg):
    """Evaluate every expert densely and combine with the router's top-k
    (no capacity) — the semantics the compact dispatch must reproduce."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    outs = []
    for e in range(m.n_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, axis=1)  # (T, E, d)
    w = jnp.zeros((t, m.n_experts)).at[
        jnp.arange(t)[:, None], top_e].add(top_p)
    return jnp.einsum("te,ted->td", w, outs).reshape(b, s, d)


def test_compact_dispatch_matches_dense_reference():
    cfg = _cfg(capacity_factor=8.0)  # ample capacity: nothing dropped
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    got, aux = moe_mod.apply_moe(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_capacity_overflow_drops_not_corrupts():
    cfg = _cfg(capacity_factor=0.25)  # force overflow
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    got, _ = moe_mod.apply_moe(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))
    # dropped tokens -> outputs differ, but norm can only shrink
    assert float(jnp.linalg.norm(got)) <= float(
        jnp.linalg.norm(want)) * 1.05


def test_dense_residual_branch():
    cfg = _cfg(dense_residual=True)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32))
    got, _ = moe_mod.apply_moe(p, x, cfg)
    got_no_dense, _ = moe_mod.apply_moe(
        {k: v for k, v in p.items() if k != "dense"},
        x, dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dense_residual_ff=None)))
    assert not np.allclose(np.asarray(got), np.asarray(got_no_dense))


def test_aux_loss_prefers_balance():
    """Uniform routing gives aux ~ 1; collapsed routing gives aux ~ E/2
    (top-2 of a one-hot router still splits mass across two experts)."""
    cfg = _cfg()
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    # positive inputs so a positive router column dominates for EVERY token
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32))) + 0.5
    # near-uniform router at init
    _, aux_uniform = moe_mod.apply_moe(p, x, cfg)
    # collapse the router onto expert 0
    p_collapsed = dict(p)
    router = np.zeros((32, 4), np.float32)
    router[:, 0] = 1.0
    p_collapsed["router"] = jnp.asarray(router)
    _, aux_collapsed = moe_mod.apply_moe(p_collapsed, x, cfg)
    assert float(aux_collapsed) > float(aux_uniform) * 1.5


def test_grad_flows_through_dispatch():
    cfg = _cfg()
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 32))

    def loss(p):
        out, aux = moe_mod.apply_moe(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
