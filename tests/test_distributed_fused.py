"""In-process coverage of the k-fused distributed engine (main coverage;
the 8-device subprocess check in test_distributed_stencil.py stays as the
multi-device smoke test).

These run on a 1-device mesh — shard_map, the strip all-gather, the
padded-table gathers and every shard-local compute backend execute
exactly as on a real mesh (the collective degenerates), so the full
parity matrix (workload x k x kind), the exchange accounting and the
donation path are all exercised in-process where failures are debuggable.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractals
from repro.core.compact import BlockLayout
from repro.core.distributed import make_distributed_engine
from repro.core.stencil import SqueezeBlockEngine, make_engine
from repro.workloads.rules import GRAY_SCOTT, HEAT, HIGHLIFE, LIFE
from repro.workloads.runner import BatchedRunner

FRAC, R, M = fractals.SIERPINSKI, 5, 2
WORKLOADS = (LIFE, HIGHLIFE, HEAT, GRAY_SCOTT)
COMPUTES = ("jnp", "fused", "mxu")


def _layout():
    return BlockLayout(FRAC, R, M)


def _reference(layout, wl, seed, steps):
    eng = SqueezeBlockEngine(layout, wl, fusion_k=1)
    s = eng.init_random(seed)
    for _ in range(steps):
        s = eng.step(s)
    return np.asarray(s)


def _assert_state_eq(wl, got, want, msg):
    if jnp.issubdtype(jnp.dtype(wl.dtype), jnp.integer):
        np.testing.assert_array_equal(got, want, err_msg=msg)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=msg)


# ----------------------------------------------------------- strip geometry
@pytest.mark.parametrize("k", [1, 2, 4])
def test_edge_strips_reconstruct_gather_halo_k(k):
    """pack_edge_strips + halo_from_strips_k == the fused kernels' direct
    depth-k strip gather, for every piece (the exchange ships exactly the
    bytes the kernels read)."""
    from repro.kernels.squeeze_stencil import _gather_halo_k
    layout = _layout()
    layout.materialize()
    key = jax.random.PRNGKey(0)
    s = jax.random.randint(key, (1, layout.n_blocks, layout.rho,
                                 layout.rho), 0, 255, jnp.int32)
    strips = layout.pack_edge_strips(s, k)
    strips = jnp.concatenate(
        [strips, jnp.zeros((1, 1) + strips.shape[2:], strips.dtype)],
        axis=1)
    table = jnp.asarray(layout.offset_table(k))
    table = jnp.where(table == layout.ghost, layout.n_blocks, table)
    got = layout.halo_from_strips_k(strips, table, k)
    want = _gather_halo_k(layout, s, k)
    for name, g, w in zip(("top", "bot", "west", "east"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"piece {name} k={k}")


def test_edge_strips_bounds():
    layout = _layout()
    state = jnp.zeros((1, layout.n_blocks, layout.rho, layout.rho),
                      jnp.uint8)
    with pytest.raises(ValueError):
        layout.pack_edge_strips(state, 0)
    with pytest.raises(ValueError):
        layout.pack_edge_strips(state, layout.rho + 1)


# ------------------------------------------------------------ parity matrix
@pytest.mark.parametrize("wl", WORKLOADS, ids=lambda w: w.name)
@pytest.mark.parametrize("compute", COMPUTES)
@pytest.mark.parametrize("k", [1, 2, 4])
def test_distributed_parity(wl, compute, k):
    """workload x compute x k parity vs the single-device block engine:
    CA bit-exact, PDE workloads allclose; padding blocks stay dead."""
    layout = _layout()
    steps = 5
    dist = make_distributed_engine(layout, workload=wl, compute=compute,
                                   fusion_k=k, interpret=True)
    out = dist.run(dist.init_random(7), steps)
    want = _reference(layout, wl, 7, steps)
    _assert_state_eq(wl, np.asarray(dist.to_dense(out)), want,
                     f"{wl.name}/{compute}/k={k}")
    pad = np.asarray(out)[..., layout.n_blocks:, :, :]
    assert (pad == 0).all(), "padding blocks came alive"


@pytest.mark.parametrize("compute", COMPUTES)
def test_distributed_batched_parity(compute):
    """B independent simulations through one engine match per-seed
    single-device runs (native batched strip exchange)."""
    layout = _layout()
    seeds, steps = [1, 2, 3], 4
    dist = make_distributed_engine(layout, workload=LIFE, compute=compute,
                                   fusion_k=2, interpret=True)
    out = dist.run(dist.init_batch(seeds), steps)
    dense = np.asarray(dist.to_dense(out))
    for i, seed in enumerate(seeds):
        np.testing.assert_array_equal(
            dense[i], _reference(layout, LIFE, seed, steps),
            err_msg=f"batch element {i} (seed {seed}) {compute}")


def test_multi_channel_batched():
    """Gray-Scott (C=2) with a batch axis: (B, C, nb, rho, rho)."""
    layout = _layout()
    dist = make_distributed_engine(layout, workload=GRAY_SCOTT,
                                   compute="jnp", fusion_k=2,
                                   interpret=True)
    out = dist.run(dist.init_batch([5, 6]), 3)
    assert out.shape[:2] == (2, 2)
    for i, seed in enumerate([5, 6]):
        _assert_state_eq(GRAY_SCOTT, np.asarray(dist.to_dense(out))[i],
                         _reference(layout, GRAY_SCOTT, seed, 3),
                         f"gs batch {i}")


# -------------------------------------------------------- exchange accounting
@pytest.mark.parametrize("exchange", ["gather", "p2p"])
@pytest.mark.parametrize("steps,k", [(5, 2), (6, 3), (7, 4), (4, 1), (3, 4)])
def test_exactly_ceil_steps_over_k_collectives(steps, k, exchange):
    """A run of ``steps`` at fusion depth ``k`` issues exactly
    ceil(steps/k) halo exchanges — the fused remainder launch included
    (NOT floor(steps/k) + (steps % k) single steps) — in BOTH exchange
    modes."""
    layout = _layout()
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=k, interpret=True,
                                   exchange=exchange)
    dist.run(dist.init_random(0), steps)
    st = dist.exchange_stats()
    assert st.steps == steps
    assert st.collectives == math.ceil(steps / k), st
    if exchange == "gather":
        assert st.exchanged_bytes > 0
    else:
        # exact wire model; zero on this 1-shard mesh (nothing crosses
        # a device boundary — the permutes carry (n_shards-1) payloads)
        assert st.exchanged_bytes == (math.ceil(steps / k)
                                      * dist.permute_bytes(k))
    dist.reset_exchange_stats()
    assert dist.exchange_stats().collectives == 0


def test_one_all_gather_in_lowered_step():
    """Structural check behind the counters: the lowered fused gather
    step contains exactly ONE all_gather op (strips only, once per
    launch)."""
    layout = _layout()
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True,
                                   exchange="gather")
    txt = dist.lowered_step_text(dist.init_random(0), 2)
    assert txt.count('"stablehlo.all_gather"') == 1, txt[:2000]


def test_p2p_lowered_step_structure():
    """The p2p twin: two neighbor collective_permutes (forward and
    backward shift), NO all_gather anywhere in the lowered launch —
    neighbor-only exchange is structural, not just accounted."""
    layout = _layout()
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True,
                                   exchange="p2p")
    assert dist.exchange_mode == "p2p"
    txt = dist.lowered_step_text(dist.init_random(0), 2)
    assert txt.count('"stablehlo.all_gather"') == 0, txt[:2000]
    assert txt.count('"stablehlo.collective_permute"') == 2, txt[:2000]


def test_exchange_bytes_model():
    """bytes_gathered matches the analytic strip volume: one depth-k
    gather ships 4*k*rho cells per block (vs 4*(rho+2) per step per block
    for k=1 stepping — per step, fusion trades k collectives for one)."""
    layout = _layout()
    k = 3
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=k, interpret=True,
                                   exchange="gather")
    dist.run(dist.init_random(0), k)  # one fused launch
    st = dist.exchange_stats()
    assert st.collectives == 1
    assert st.bytes_gathered == dist.strip_bytes(k)
    assert st.bytes_permuted == 0 and st.neighbor_sends == 0
    assert dist.strip_bytes(k) == (dist.nb_padded * 4 * k * layout.rho
                                   * jnp.dtype(LIFE.dtype).itemsize)


def test_exchange_bytes_model_p2p():
    """The p2p twin: bytes_permuted matches the analytic per-neighbor
    routing volume (ms_prev + ms_next slots per device per launch),
    neighbor_sends counts 2*(n_shards-1) directed sends per launch, and
    nothing is all-gathered."""
    layout = _layout()
    k = 3
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=k, interpret=True,
                                   exchange="p2p")
    dist.run(dist.init_random(0), k)  # one fused launch
    st = dist.exchange_stats()
    assert st.collectives == 1
    assert st.bytes_gathered == 0
    assert st.bytes_permuted == dist.permute_bytes(k)
    assert st.neighbor_sends == 2 * (dist.n_shards - 1)
    d = dist.decomp
    assert dist.wire_bytes_per_device(k) == (
        (d.ms_prev + d.ms_next) * 4 * k * layout.rho
        * jnp.dtype(LIFE.dtype).itemsize)


def test_memory_bytes():
    layout = _layout()
    dist = make_distributed_engine(layout, workload=GRAY_SCOTT,
                                   interpret=True)
    assert dist.memory_bytes() == (2 * dist.nb_padded * layout.rho ** 2
                                   * 4)  # C=2, f32


# ------------------------------------------------------------------ donation
def test_run_donate_parity():
    layout = _layout()
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True)
    s = dist.init_random(11)
    want = np.asarray(dist.to_dense(dist.run(s, 5)))
    s2 = dist.init_random(11)
    got = np.asarray(dist.to_dense(dist.run(s2, 5, donate=True)))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------- errors
def test_fusion_k_bounds():
    layout = _layout()
    with pytest.raises(ValueError):
        make_distributed_engine(layout, fusion_k=0)
    with pytest.raises(ValueError):
        make_distributed_engine(layout, fusion_k=layout.rho + 1)
    dist = make_distributed_engine(layout, interpret=True)
    with pytest.raises(ValueError):
        dist.step_k(dist.init_random(0), layout.rho + 1)
    with pytest.raises(ValueError):
        make_distributed_engine(layout, compute="vpu")


# ----------------------------------------------------------- engine registry
def test_make_engine_dist_kinds():
    eng = make_engine("dist-mxu", FRAC, R, M, workload=HEAT, fusion_k=2)
    assert eng.compute == "mxu" and eng.workload is HEAT
    assert eng.effective_fusion_k == 2
    eng = make_engine("dist-block", FRAC, R, M)
    assert eng.compute == "jnp"
    eng = make_engine("dist-fused", FRAC, R, M)
    assert eng.compute == "fused"


# ------------------------------------------------------------------- runner
def test_runner_dist_kind_parity_and_cache():
    runner = BatchedRunner()
    mesh = jax.sharding.Mesh(jax.devices(), ("data",))
    seeds, steps = [4, 9], 5
    states = runner.init_batch("dist-block", FRAC, R, seeds, m=M,
                               workload=LIFE, mesh=mesh)
    out = runner.run("dist-block", FRAC, R, states, steps, m=M,
                     workload=LIFE, k=2, mesh=mesh)
    layout = _layout()
    eng = runner.engine_for("dist-block", FRAC, R, M, LIFE, k=2, mesh=mesh)
    dense = np.asarray(eng.to_dense(out))
    for i, seed in enumerate(seeds):
        np.testing.assert_array_equal(
            dense[i], _reference(layout, LIFE, seed, steps))
    # ceil(steps/k) collectives through the runner path too
    assert eng.exchange_stats().collectives == math.ceil(steps / 2)
    # one cached engine per (kind, ..., k, mesh); same config hits cache
    builds = runner.stats.builds
    runner.engine_for("dist-block", FRAC, R, M, LIFE, k=2, mesh=mesh)
    assert runner.stats.builds == builds


def test_runner_batch_placement_regular_kind():
    """Non-dist kinds with a mesh shard the BATCH axis (whole sims per
    device) — run still matches the meshless path."""
    runner = BatchedRunner()
    mesh = jax.sharding.Mesh(jax.devices(), ("data",))
    states = runner.init_batch("block", FRAC, R, [1, 2], m=M,
                               workload=LIFE, mesh=mesh)
    out = runner.run("block", FRAC, R, states, 3, m=M, workload=LIFE, k=2)
    layout = _layout()
    for i, seed in enumerate([1, 2]):
        np.testing.assert_array_equal(
            np.asarray(out)[i], _reference(layout, LIFE, seed, 3))


def test_runner_to_expanded_dist():
    runner = BatchedRunner()
    mesh = jax.sharding.Mesh(jax.devices(), ("data",))
    states = runner.init_batch("dist-block", FRAC, R, [0], m=M,
                               workload=LIFE, mesh=mesh)
    exp = runner.to_expanded("dist-block", FRAC, R, states, m=M,
                             workload=LIFE, mesh=mesh)
    n = FRAC.side(R)
    assert exp.shape == (1, n, n)
