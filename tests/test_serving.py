"""The continuous-batching service on its happy paths: admission
control, bucketed batching onto one compiled engine, continuous joins,
deadlines, snapshots, donation parity, circuit breaker and the serve.*
telemetry surface. The failure paths live in tests/test_chaos.py."""
import asyncio
import time

import numpy as np
import pytest

from repro import obs
from repro.core import fractals
from repro.core.stencil import make_engine
from repro.runtime.fault import Fault, FaultInjector
from repro.serving import (AdmissionError, CircuitBreaker, FractalService,
                           ServiceConfig, SimRequest, SimResult)
from repro.workloads import HEAT, LIFE, BatchedRunner

FRAC = fractals.SIERPINSKI


def _cfg(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("hang_threshold_s", 5.0)
    kw.setdefault("compile_grace_s", 60.0)
    return ServiceConfig(**kw)


def _reqs(n, steps=12, snapshot_every=0, prefix="t", **kw):
    return [SimRequest(frac=FRAC, r=4, steps=steps, m=1, seed=s,
                       snapshot_every=snapshot_every,
                       rid=f"{prefix}-{s}", **kw)
            for s in range(n)]


def _ref_states(n, steps, wl=LIFE):
    eng = make_engine("block", FRAC, 4, 1, workload=wl)
    return [np.asarray(eng.run(eng.init_random(s), steps))
            for s in range(n)]


# ------------------------------------------------------------ happy path
def test_serve_matches_direct_engine_run():
    svc = FractalService(_cfg())
    res = svc.serve(_reqs(3, steps=12, prefix="direct"))
    refs = _ref_states(3, 12)
    for i, r in enumerate(res):
        assert r.ok and r.steps_done == 12
        np.testing.assert_array_equal(refs[i], r.state)


def test_requests_share_one_compiled_engine():
    runner = BatchedRunner()
    svc = FractalService(_cfg(max_batch=8), runner=runner)
    res = svc.serve(_reqs(6, steps=8, prefix="share"))
    assert all(r.ok for r in res)
    assert runner.stats.builds == 1  # six requests, one bucket, one build


def test_mixed_buckets_route_to_distinct_engines():
    runner = BatchedRunner()
    svc = FractalService(_cfg(), runner=runner)
    reqs = _reqs(2, steps=6, prefix="life") + [
        SimRequest(frac=FRAC, r=4, steps=6, m=1, workload=HEAT,
                   seed=s, rid=f"heat-{s}") for s in range(2)]
    res = svc.serve(reqs)
    assert all(r.ok for r in res)
    assert runner.stats.builds == 2  # one per (workload) bucket
    heat_ref = make_engine("block", FRAC, 4, 1, workload=HEAT)
    ref = np.asarray(heat_ref.run(heat_ref.init_random(0), 6))
    np.testing.assert_allclose(ref, res[2].state, rtol=1e-6, atol=1e-6)


def test_snapshots_at_cadence_and_bit_exact():
    svc = FractalService(_cfg())
    res = svc.serve(_reqs(2, steps=12, snapshot_every=4, prefix="snap"))
    eng = make_engine("block", FRAC, 4, 1, workload=LIFE)
    for seed, r in enumerate(res):
        assert [s for s, _ in r.snapshots] == [4, 8]
        state = eng.init_random(seed)
        for _, snap in r.snapshots:
            state = eng.run(state, 4)  # advance to the next boundary
            np.testing.assert_array_equal(np.asarray(state), snap)


def test_heterogeneous_step_counts_in_one_bucket():
    svc = FractalService(_cfg(max_batch=8))
    reqs = [SimRequest(frac=FRAC, r=4, steps=st, m=1, seed=i,
                       rid=f"het-{i}")
            for i, st in enumerate((5, 9, 16))]
    res = svc.serve(reqs)
    eng = make_engine("block", FRAC, 4, 1, workload=LIFE)
    for i, (st, r) in enumerate(zip((5, 9, 16), res)):
        assert r.ok and r.steps_done == st
        ref = np.asarray(eng.run(eng.init_random(i), st))
        np.testing.assert_array_equal(ref, r.state)


def test_continuous_join_mid_flight():
    """A request submitted while its bucket is already running joins at
    a segment boundary instead of waiting for a full drain."""
    async def go():
        svc = FractalService(_cfg(max_batch=8, max_segment_steps=2))
        await svc.start()
        try:
            first = asyncio.ensure_future(
                svc.submit(SimRequest(frac=FRAC, r=4, steps=40, m=1,
                                      seed=0, rid="join-0")))
            await asyncio.sleep(0.05)  # let the bucket start
            late = await svc.submit(SimRequest(frac=FRAC, r=4, steps=8,
                                               m=1, seed=1, rid="join-1"))
            return await first, late
        finally:
            await svc.stop()
    r0, r1 = asyncio.run(go())
    assert r0.ok and r0.steps_done == 40
    assert r1.ok and r1.steps_done == 8
    eng = make_engine("block", FRAC, 4, 1, workload=LIFE)
    np.testing.assert_array_equal(
        np.asarray(eng.run(eng.init_random(1), 8)), r1.state)


# ------------------------------------------------------------- admission
def test_queue_full_rejects_with_retry_after():
    async def go():
        svc = FractalService(_cfg(max_queue=2))
        await svc.start()
        try:
            svc._queued = 2  # saturate the queue deterministically
            with pytest.raises(AdmissionError) as ei:
                await svc.submit(SimRequest(frac=FRAC, r=4, steps=4,
                                            m=1, seed=0, rid="qf-0"))
            assert ei.value.reason == "queue_full"
            assert ei.value.retry_after_s > 0
        finally:
            svc._queued = 0
            await svc.stop()
    asyncio.run(go())


def test_deadline_times_out_long_request():
    svc = FractalService(_cfg(max_segment_steps=1))
    res = svc.serve([SimRequest(frac=FRAC, r=4, steps=100000, m=1, seed=0,
                                deadline_s=0.2, rid="dl-0")])
    assert res[0].status == "timeout"
    assert 0 < res[0].steps_done < 100000


def test_submit_before_start_raises():
    svc = FractalService(_cfg())
    with pytest.raises(RuntimeError):
        asyncio.run(svc.submit(_reqs(1)[0]))


# -------------------------------------------------------- circuit breaker
def test_circuit_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=1.0, clock=lambda: t[0])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()
    assert br.state == "open" and not br.allow()
    assert br.retry_after() == pytest.approx(1.0)
    t[0] = 1.5
    assert br.state == "half-open" and br.allow()  # the probe
    br.record_failure()  # half-open probe fails -> reopen immediately
    assert br.state == "open"
    t[0] = 3.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed"


def test_breaker_sheds_load_after_sustained_failure():
    inj = FaultInjector([Fault(kind="exception", at_segment=i)
                         for i in range(8)])
    svc = FractalService(
        _cfg(max_retries=1, breaker_threshold=2,
             breaker_cooldown_s=30.0), injector=inj)
    res = svc.serve(_reqs(1, steps=8, prefix="brk"))
    assert res[0].status == "failed"
    assert svc.breaker.state == "open"
    # breaker open -> admission rejects with retry-after, not collapse
    res2 = svc.serve(_reqs(1, steps=8, prefix="brk2"))
    assert res2[0].status == "rejected"
    assert res2[0].error == "breaker_open"
    assert res2[0].retry_after_s > 0


# ------------------------------------------------------------- telemetry
def test_serve_metrics_emitted():
    with obs.enabled_scope(True) as reg:
        obs.reset()
        svc = FractalService(_cfg())
        res = svc.serve(_reqs(3, steps=8, snapshot_every=4,
                              prefix="met"))
        assert all(r.ok for r in res)
        assert reg.counter("serve.admitted", kind="block").value == 3
        assert reg.counter("serve.completed", kind="block").value == 3
        assert reg.counter("serve.joins", kind="block").value == 3
        assert reg.counter("serve.batches", kind="block").value >= 1
        assert reg.counter("serve.segments", kind="block").value >= 2
        lat = reg.histogram("serve.latency_seconds", kind="block",
                            status="ok")
        assert lat.count == 3
        assert reg.gauge("serve.queue_depth").value == 0


def test_result_latency_accounting():
    svc = FractalService(_cfg())
    t0 = time.monotonic()
    res = svc.serve(_reqs(1, steps=8, prefix="lat"))
    wall = time.monotonic() - t0
    assert 0 < res[0].latency_s <= wall + 0.1
    assert 0 <= res[0].queue_wait_s <= res[0].latency_s


# ------------------------------------------------------------- misc types
def test_request_validation():
    with pytest.raises(ValueError):
        SimRequest(frac=FRAC, r=4, steps=0)
    with pytest.raises(ValueError):
        SimRequest(frac=FRAC, r=4, steps=4, snapshot_every=-1)


def test_result_ok_property():
    assert SimResult(rid="x", status="ok").ok
    assert not SimResult(rid="x", status="failed").ok
