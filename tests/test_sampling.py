"""Sampling decode: greedy determinism, temperature variety, top-k
restriction, and consistency with the cache path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as model_lib


def _setup():
    cfg = configs.get_smoke_config("smollm-135m")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    return cfg, params, {"tokens": tokens}


def test_greedy_is_deterministic():
    cfg, params, batch = _setup()
    a = model_lib.generate(params, batch, cfg, max_new=6, max_len=16)
    b = model_lib.generate(params, batch, cfg, max_new=6, max_len=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sampling_seed_controls_variety():
    cfg, params, batch = _setup()
    a = model_lib.generate(params, batch, cfg, max_new=8, max_len=16,
                           temperature=1.5, seed=0)
    b = model_lib.generate(params, batch, cfg, max_new=8, max_len=16,
                           temperature=1.5, seed=0)
    c = model_lib.generate(params, batch, cfg, max_new=8, max_len=16,
                           temperature=1.5, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, -1.0]])
    keys = [jax.random.PRNGKey(i) for i in range(64)]
    toks = {int(model_lib._select_token(logits, k, 1.0, 2)[0])
            for k in keys}
    assert toks <= {2, 3}
    assert int(model_lib._select_token(logits, keys[0], 0.0, 0)[0]) == 3


def test_all_tokens_in_vocab():
    cfg, params, batch = _setup()
    out = model_lib.generate(params, batch, cfg, max_new=8, max_len=16,
                             temperature=1.0, top_k=10, seed=3)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))
