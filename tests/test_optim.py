"""AdamW unit tests: convergence, schedule, int8 moment quantization,
error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.optim import adamw


def _quad_problem():
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros(())}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + (p["b"] - 1.0) ** 2
    return params, loss_fn, target


@pytest.mark.parametrize("quant", [False, True], ids=["fp32", "int8"])
def test_adamw_converges_on_quadratic(quant):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            total_steps=400, min_lr_frac=1.0,
                            quantize_moments=quant)
    params, loss_fn, target = _quad_problem()
    state = adamw.init(cfg, params)
    for _ in range(400):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = adamw.update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)
    assert abs(float(params["b"]) - 1.0) < 0.05


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s)))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4              # peak after warmup
    assert lrs[-1] < 0.2 * 1e-3                    # decays toward min
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9            # respects floor


def test_quantized_states_are_small_and_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (513, 300)) * 0.01
    q, s = adamw._quantize(x)
    assert q.dtype == jnp.int8
    assert q.shape == (513, 384)                   # padded to 128
    back = adamw._dequantize(q, s, x.shape, x.size)
    assert back.shape == x.shape
    # blockwise absmax int8: relative error bounded by ~1/127 per block
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(jnp.max(jnp.abs(x))) / 100)


def test_grad_compression_error_feedback_is_unbiased():
    """Sum of compressed grads ~ sum of true grads (residual carries)."""
    rng = jax.random.PRNGKey(1)
    residual = jnp.zeros((256,))
    total_true = jnp.zeros((256,))
    total_hat = jnp.zeros((256,))
    for i in range(50):
        rng, k = jax.random.split(rng)
        g = jax.random.normal(k, (256,)) * 0.1
        g_hat, residual = adamw.compress_decompress(g, residual)
        total_true += g
        total_hat += g_hat
    # residual is bounded; accumulated estimates track the true sum
    err = float(jnp.max(jnp.abs(total_true - (total_hat + residual))))
    assert err < 1e-4
    del cfg


@given(st.integers(min_value=1, max_value=400),
       st.integers(min_value=1, max_value=5))
@settings(max_examples=20, deadline=None)
def test_property_quantize_roundtrip_shape(n, m):
    x = jnp.linspace(-1.0, 1.0, n * m).reshape(n, m)
    q, s = adamw._quantize(x)
    back = adamw._dequantize(q, s, x.shape, x.size)
    assert back.shape == x.shape
    assert float(jnp.max(jnp.abs(back - x))) <= 2.0 / 127 + 1e-6
