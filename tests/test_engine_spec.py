"""EngineSpec: the one configuration identity (DESIGN.md Section 11).

Covers the tentpole contracts of the spec-first redesign:

* exact JSON round-trip (tables persist specs; nothing may drift);
* ``canonical()``/``normalize()`` reproduce the partition the runner's
  old ``_resolve_key``/``_resolve_k`` pair induced, for every registry
  kind — alias rewrite, non-block knob zeroing, dist-only knob zeroing,
  heuristic fusion-depth resolution;
* the runner, ``make_engine`` and ``SimRequest.bucket`` all key on the
  SAME normalized object (one normalization code path);
* the legacy argument lists keep working: ``make_engine(kind, frac,
  r, ...)`` warns but builds, runner legacy calls share the compiled
  slot with the equivalent spec call.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import fractals
from repro.core.stencil import default_fusion_k, make_engine
from repro.serving.types import SimRequest
from repro.tuning.spec import (KIND_ALIASES, KINDS, EngineSpec,
                               is_block_kind, is_dist_kind)
from repro.workloads.rules import LIFE
from repro.workloads.runner import BatchedRunner


@pytest.fixture(autouse=True)
def _heuristics_only(monkeypatch):
    """Identity tests must not depend on what the shipped table says."""
    monkeypatch.setenv("SQUEEZE_TUNING", "off")


def _spec_for(kind: str) -> EngineSpec:
    """A small valid spec of the given kind."""
    if kind.endswith("3d") or kind == "pallas-3d-mxu":
        return EngineSpec(kind, 2, "sierpinski3d", 3,
                          m=1 if is_block_kind(kind) else 0,
                          workload="life3d")
    return EngineSpec(kind, 2, "sierpinski", 4,
                      m=1 if is_block_kind(kind) else 0,
                      workload="life",
                      mesh_shape=(1,) if is_dist_kind(kind) else None)


# ------------------------------------------------------- JSON round-trip
def test_json_round_trip_exact_every_kind():
    for kind in KINDS:
        spec = _spec_for(kind)
        d = spec.to_json()
        json.dumps(d)  # plain JSON, no custom encoder needed
        assert EngineSpec.from_json(d) == spec
        norm = spec.normalize()
        assert EngineSpec.from_json(norm.to_json()) == norm


def test_json_round_trip_mask_identity():
    custom = fractals.NBBFractal("custom", 2, ((0, 0), (1, 1)))
    spec = EngineSpec.from_args("block", custom, 4, 1, LIFE, fusion_k=2)
    assert spec.frac == ((0, 0), (1, 1))  # not a registry fractal
    d = json.loads(json.dumps(spec.to_json()))
    assert EngineSpec.from_json(d) == spec
    rebuilt = spec.build_frac()
    assert rebuilt.s == 2 and tuple(rebuilt.positions) == custom.positions


def test_from_args_registry_identity_by_name():
    spec = EngineSpec.from_args("block", fractals.SIERPINSKI, 5, 2, LIFE)
    assert spec.frac == "sierpinski" and spec.s == 2
    assert spec.build_frac() is fractals.SIERPINSKI


# ------------------------------------------------- canonical / normalize
def test_canonical_alias_rewrite_symmetric():
    a = EngineSpec("pallas", 2, "sierpinski", 4, 1).canonical()
    b = EngineSpec("pallas-strips", 2, "sierpinski", 4, 1).canonical()
    assert a == b and a.kind == "pallas-strips"
    # make_engine agrees (the old asymmetry: only the runner rewrote it)
    assert type(make_engine(a)) is type(make_engine(b))


def test_canonical_validation():
    with pytest.raises(ValueError, match="unknown engine kind"):
        EngineSpec("nope", 2, "sierpinski", 4).canonical()
    with pytest.raises(ValueError, match="k must be >= 1"):
        EngineSpec("block", 2, "sierpinski", 4, 1,
                   fusion_k=0).canonical()
    with pytest.raises(ValueError, match="exchange"):
        EngineSpec("dist-block", 2, "sierpinski", 4, 1,
                   exchange="carrier-pigeon").canonical()


def test_normalized_partition_matches_old_resolve_key():
    """For every kind: the equalities/inequalities the runner's old
    ``_resolve_key``/``_resolve_k`` tuple induced hold on normalized
    specs (one normalization path, same partition)."""
    for kind in KINDS:
        spec = _spec_for(kind)
        norm = spec.normalize()
        assert norm == norm.normalize()  # idempotent (old key was too)
        rho = norm.rho
        if is_block_kind(kind):
            # k=None resolves to the heuristic; an equal explicit k is
            # the SAME configuration (old _resolve_k contract)
            k_h = default_fusion_k(rho)
            assert norm.fusion_k == k_h
            expl = spec.__class__(**{**spec.to_json(),
                                     "fusion_k": k_h})
            assert EngineSpec.from_json(expl.to_json()).normalize() \
                == norm
            # ...and a different depth is a different configuration
            other = EngineSpec.from_json(
                {**spec.to_json(), "fusion_k": k_h + 1}).normalize()
            assert other != norm
        else:
            # non-block kinds: k normalizes away entirely (one slot)
            for k in (None, 1, 5):
                same = EngineSpec.from_json(
                    {**spec.to_json(), "fusion_k": k}).normalize()
                assert same == norm
        if not is_dist_kind(kind):
            # dist-only knobs are zeroed elsewhere (old key did this)
            noisy = EngineSpec.from_json(
                {**spec.to_json(), "exchange": "gather",
                 "axis": "model"}).normalize()
            assert noisy == norm
        else:
            assert EngineSpec.from_json(
                {**spec.to_json(), "exchange": "gather"}
            ).normalize() != norm


def test_normalize_zeroes_m_for_non_block_kinds():
    a = EngineSpec("cell", 2, "sierpinski", 4, m=0).normalize()
    b = EngineSpec("cell", 2, "sierpinski", 4, m=2).normalize()
    assert a == b and a.m == 0


def test_tuning_key_excludes_tunables():
    base = _spec_for("pallas-mxu")
    keys = {
        EngineSpec.from_json({**base.to_json(), "fusion_k": k,
                              "macro_p": p}).tuning_key()
        for k in (None, 1, 2) for p in (None, 2)}
    assert len(keys) == 1
    assert _spec_for("block").tuning_key() != base.tuning_key()


def test_spec_is_hashable_dict_key():
    d = {_spec_for(k).normalize(): k for k in KINDS}
    assert len(d) == len(KINDS)


# --------------------------------------------- make_engine spec-first
def test_make_engine_spec_path_no_warning():
    spec = EngineSpec("block", 2, "sierpinski", 4, 1, fusion_k=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = make_engine(spec)
    assert eng.effective_fusion_k == 2


def test_make_engine_legacy_shim_warns_and_matches():
    spec = EngineSpec("block", 2, "sierpinski", 4, 1, fusion_k=2)
    with pytest.warns(DeprecationWarning, match="EngineSpec"):
        legacy = make_engine("block", fractals.SIERPINSKI, 4, 1,
                             workload=LIFE, fusion_k=2)
    via_spec = make_engine(spec)
    assert type(legacy) is type(via_spec)
    s0 = via_spec.init_random(7)
    np.testing.assert_array_equal(np.asarray(legacy.step(s0)),
                                  np.asarray(via_spec.step(s0)))


# --------------------------------------------------- one cache identity
def test_runner_spec_and_legacy_share_one_slot():
    runner = BatchedRunner()
    spec = EngineSpec("block", 2, "sierpinski", 4, 1, workload="life",
                      fusion_k=2)
    e1 = runner.engine_for(spec)
    e2 = runner.engine_for("block", fractals.SIERPINSKI, 4, m=1,
                           workload=LIFE, k=2)
    assert e1 is e2 and runner.stats.builds == 1
    # the alias kind also lands in the same slot
    assert runner.engine_for("pallas", fractals.SIERPINSKI, 4, m=1,
                             k=1) is runner.engine_for(
        "pallas-strips", fractals.SIERPINSKI, 4, m=1, k=1)


def test_serving_bucket_is_normalized_spec():
    req = SimRequest(frac=fractals.SIERPINSKI, r=4, steps=3, m=1,
                     kind="pallas", k=None)
    bucket = req.bucket
    assert isinstance(bucket, EngineSpec)
    assert bucket.kind == "pallas-strips"          # alias collapsed
    assert bucket.fusion_k is not None             # knobs resolved
    assert bucket == bucket.normalize()            # already normalized
    # identical requests with spelled-out defaults share the bucket —
    # and the bucket IS the runner cache key
    other = SimRequest(frac=fractals.SIERPINSKI, r=4, steps=9, m=1,
                       kind="pallas-strips", k=bucket.fusion_k)
    assert other.bucket == bucket
    runner = BatchedRunner()
    runner.engine_for(bucket)
    assert runner.is_cached(other.bucket)
