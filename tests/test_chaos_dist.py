"""The DISTRIBUTED chaos matrix on a real 8-shard mesh: every
shard-level fault class (per-shard exception, stalled fused launch,
device loss + elastic 8->4 reshard, corrupted halo band, damaged
sharded checkpoint) must recover bit-exact vs an uninterrupted
single-device run. The matrix itself lives in
benchmarks/chaos_dist_bench.py — the same script the CI chaos-dist
gate runs — so the scenarios, parity assertions and recovery-time
arithmetic are written once.

Runs in a subprocess so --xla_force_host_platform_device_count never
leaks into this process (smoke tests must see 1 device); the in-process
single-device recovery tests are in test_elastic_dist.py."""
import os
import pathlib
import subprocess
import sys


def test_chaos_matrix_recovers_on_8_device_mesh(tmp_path):
    repo = pathlib.Path(__file__).resolve().parents[1]
    script = repo / "benchmarks" / "chaos_dist_bench.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out_json = tmp_path / "BENCH_chaos_dist.json"
    out = subprocess.run(
        [sys.executable, str(script), "--smoke",
         "--max-recovery-s", "60", "--out", str(out_json)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}")
    assert "CHAOS_DIST_OK" in out.stdout
    assert out_json.exists()  # the recovery-metrics artifact
