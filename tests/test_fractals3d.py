"""3D Squeeze extension (paper §5 future work): lambda3/nu3 inverse
property, compact-volume conservation, membership == 3D mask, MRF."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import fractals3d as f3

ALL3D = list(f3.REGISTRY3D.values())


def _all_compact(frac, r):
    nx, ny, nz = frac.compact_dims(r)
    cz, cy, cx = np.meshgrid(np.arange(nz), np.arange(ny), np.arange(nx),
                             indexing="ij")
    return (jnp.asarray(cx.reshape(-1)), jnp.asarray(cy.reshape(-1)),
            jnp.asarray(cz.reshape(-1)))


@pytest.mark.parametrize("frac", ALL3D, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [0, 1, 2, 3])
def test_compact_dims_hold_volume(frac, r):
    nx, ny, nz = frac.compact_dims(r)
    assert nx * ny * nz == frac.volume(r)


@pytest.mark.parametrize("frac", ALL3D, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2])
def test_lambda3_bijects_onto_fractal(frac, r):
    if frac.volume(r) > 200000:
        pytest.skip("too large for exhaustive 3D check")
    cx, cy, cz = _all_compact(frac, r)
    ex, ey, ez = f3.lambda3_map(frac, r, cx, cy, cz)
    n = frac.side(r)
    flat = (np.asarray(ez).astype(np.int64) * n + np.asarray(ey)) * n \
        + np.asarray(ex)
    assert len(np.unique(flat)) == frac.volume(r)
    mask = frac.mask(r)
    assert mask[np.asarray(ez), np.asarray(ey), np.asarray(ex)].all()


@pytest.mark.parametrize("frac", ALL3D, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2])
def test_nu3_inverts_lambda3(frac, r):
    if frac.volume(r) > 200000:
        pytest.skip("too large")
    cx, cy, cz = _all_compact(frac, r)
    ex, ey, ez = f3.lambda3_map(frac, r, cx, cy, cz)
    bx, by, bz = f3.nu3_map(frac, r, ex, ey, ez)
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(cx))
    np.testing.assert_array_equal(np.asarray(by), np.asarray(cy))
    np.testing.assert_array_equal(np.asarray(bz), np.asarray(cz))


@pytest.mark.parametrize("frac", ALL3D, ids=lambda f: f.name)
def test_membership_matches_mask(frac):
    r = 2
    n = frac.side(r)
    gz, gy, gx = np.meshgrid(*[np.arange(n)] * 3, indexing="ij")
    got = f3.is_fractal3(frac, r, jnp.asarray(gx.reshape(-1)),
                         jnp.asarray(gy.reshape(-1)),
                         jnp.asarray(gz.reshape(-1)))
    want = frac.mask(r).reshape(-1) > 0
    np.testing.assert_array_equal(np.asarray(got), want)


def test_menger_mrf():
    """Menger sponge: MRF = 27^r / 20^r = 1.35^r."""
    assert abs(f3.MENGER.mrf(5) - 1.35 ** 5) < 1e-6
    # sierpinski3d packs much harder: 8^r vs 4^r = 2^r
    assert f3.SIERPINSKI3D.mrf(10) == 2.0 ** 10


@given(st.integers(min_value=1, max_value=10), st.data())
@settings(max_examples=60, deadline=None)
def test_property_roundtrip_sierpinski3d(r, data):
    frac = f3.SIERPINSKI3D
    nx, ny, nz = frac.compact_dims(r)
    cx = data.draw(st.integers(0, nx - 1))
    cy = data.draw(st.integers(0, ny - 1))
    cz = data.draw(st.integers(0, nz - 1))
    ex, ey, ez = f3.lambda3_map(frac, r, jnp.asarray([cx]),
                                jnp.asarray([cy]), jnp.asarray([cz]))
    assert bool(f3.is_fractal3(frac, r, ex, ey, ez)[0])
    bx, by, bz = f3.nu3_map(frac, r, ex, ey, ez)
    assert (int(bx[0]), int(by[0]), int(bz[0])) == (cx, cy, cz)
