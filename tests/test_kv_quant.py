"""int8 KV cache: numerics close to the bf16 cache, exact-size halving,
ring-buffer compatibility, decode consistency within quantization error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as A
from repro.models import model as model_lib
from repro.models.config import LayerSpec, ModelConfig


def _cfg(window=None, kv_quant=True):
    return ModelConfig(
        name="t", d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, unit=(LayerSpec(kind="attn", window=window),),
        n_units=1, dtype="float32", kv_quant=kv_quant)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 2, 8, 16)) * 3.0
    q, s = A._kv_quantize(x)
    back = A._kv_dequantize(q, s, jnp.float32)
    assert q.dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02


@pytest.mark.parametrize("window", [None, 8], ids=["linear", "ring"])
def test_cached_attention_close_to_fp(window):
    cfgq = _cfg(window=window, kv_quant=True)
    cfgf = _cfg(window=window, kv_quant=False)
    spec = cfgq.unit[0]
    p = A.init_attn(jax.random.PRNGKey(1), cfgq)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 64))

    cq = A.init_attn_cache(cfgq, spec, 2, 16)
    cf = A.init_attn_cache(cfgf, spec, 2, 16)
    assert cq["k"].dtype == jnp.int8
    # int8 cache + fp32 scales ~ half the bf16 cache at hd=16; at the
    # production head_dim=128 the overhead is 1/128 (check the ratio form)
    bytes_q = cq["k"].size + 4 * cq["k_scale"].size
    bytes_f = cf["k"].size * 4  # fp32 smoke dtype
    assert bytes_q < bytes_f / 2

    oq, cq = A.apply_attn(p, x, cfgq, spec, 0, cache=cq)
    of, cf = A.apply_attn(p, x, cfgf, spec, 0, cache=cf)
    np.testing.assert_allclose(np.asarray(oq), np.asarray(of),
                               rtol=0.05, atol=0.05)
    # continue decoding one token
    x1 = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 64))
    oq1, _ = A.apply_attn(p, x1, cfgq, spec, 4, cache=cq)
    of1, _ = A.apply_attn(p, x1, cfgf, spec, 4, cache=cf)
    np.testing.assert_allclose(np.asarray(oq1), np.asarray(of1),
                               rtol=0.05, atol=0.05)


def test_full_model_decode_with_kv_quant():
    cfg = dataclasses.replace(configs.get_smoke_config("gemma2-2b"),
                              kv_quant=True)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab)
    full_logits, _, _ = model_lib.forward(params, {"tokens": tokens}, cfg)
    cache = model_lib.init_cache(cfg, 2, 12)
    last, cache, extras = model_lib.prefill(
        params, {"tokens": tokens[:, :8]}, cfg, cache)
    # quantization error bounded: same argmax as the exact forward
    for i in range(3):
        pos = 8 + i
        last, cache = model_lib.decode_step(
            params, tokens[:, pos:pos + 1], pos, cfg, cache, extras=extras)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full_logits[:, pos]),
            rtol=0.08, atol=0.15,
            err_msg=f"kv-quant decode step {i} diverged beyond int8 error")
