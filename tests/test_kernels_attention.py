"""Flash attention kernel vs the pure-jnp oracle: shape/dtype/feature sweep
(causal, sliding window, softcap, decode right-alignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(b, h, sq, sk, d, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, h, sq, d), dtype)
    k = jax.random.normal(k2, (b, h, sk, d), dtype)
    v = jax.random.normal(k3, (b, h, sk, d), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", [
    (1, 2, 128, 128, 128),   # single block
    (2, 3, 256, 256, 128),   # multi block
    (1, 2, 128, 384, 128),   # sq < sk (chunked prefill)
], ids=["1blk", "multi", "prefill-chunk"])
def test_flash_causal(shape, dtype):
    b, h, sq, sk, d = shape
    q, k, v = _qkv(b, h, sq, sk, d, dtype)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("window", [128, 256])
def test_flash_sliding_window(window):
    q, k, v = _qkv(1, 2, 384, 384, 128, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = _qkv(1, 2, 256, 256, 128, jnp.float32, seed=3)
    got = ops.flash_attention(q, k, v, causal=True, softcap=50.0,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_shape():
    """Sq=1 against a long cache: right-aligned query must see all keys."""
    q, k, v = _qkv(2, 2, 1, 512, 128, jnp.float32, seed=5)
    got = ops.flash_attention(q, k, v, causal=True, bq=1, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_size_invariance():
    q, k, v = _qkv(1, 1, 256, 256, 128, jnp.float32, seed=7)
    a = ops.flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    b = ops.flash_attention(q, k, v, bq=64, bk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
