"""Invariants of the locality-aware strip decomposition
(`core.compact.StripDecomposition`) — the static machinery behind the
neighbor-only p2p halo exchange.

The decomposition is pure host-side geometry, so everything here is
checked exhaustively in numpy: coverage (every block owned exactly
once), contiguity and balance of the row partition, the +-1-shard Moore
adjacency guarantee, full decode of the combined-coordinate table
against the layout's offset_table, interior/boundary classification,
routing buffer consistency, degenerate-mesh detection, and the wire
accounting the scaling gate reads.
"""
import numpy as np
import pytest

from repro.core import fractals
from repro.core.compact import (BlockLayout, StripDecomposition,
                                _balanced_contiguous_partition)

CONFIGS = [
    (fractals.SIERPINSKI, 5, 2, 2),
    (fractals.SIERPINSKI, 5, 2, 4),
    (fractals.SIERPINSKI, 7, 2, 8),
    (fractals.SIERPINSKI, 8, 1, 8),
    (fractals.CARPET, 3, 1, 4),
]


def _decomp(frac, r, m, ns):
    layout = BlockLayout(frac, r, m)
    layout.materialize()
    return layout, layout.strip_decomposition(ns)


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_perm_covers_every_block_once(frac, r, m, ns):
    layout, d = _decomp(frac, r, m, ns)
    assert d.valid
    real = d.perm[d.perm >= 0]
    assert sorted(real.tolist()) == list(range(layout.n_blocks))
    assert d.perm.shape == (d.nb_local * ns,)
    # shard_of/local_of invert perm
    for i, b in enumerate(d.perm):
        if b < 0:
            continue
        assert d.shard_of[b] == i // d.nb_local
        assert d.local_of[b] == i % d.nb_local


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_strips_are_contiguous_expanded_rows(frac, r, m, ns):
    """Each shard owns whole expanded block-grid rows, contiguous and
    ordered: rows never split, shard boundaries monotone in ey."""
    layout, d = _decomp(frac, r, m, ns)
    ey = layout.block_origin_expanded[:, 1] // layout.rho
    for y in np.unique(ey):
        shards = {int(d.shard_of[b]) for b in np.where(ey == y)[0]}
        assert len(shards) == 1, f"row {y} split across {shards}"
    row_shard = [int(d.shard_of[np.where(ey == y)[0][0]])
                 for y in np.unique(ey)]
    assert row_shard == sorted(row_shard), "strips out of row order"
    assert set(row_shard) == set(range(ns)), "some shard owns no row"


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_moore_neighbors_within_one_shard(frac, r, m, ns):
    """The load-bearing guarantee: every radius-1 neighbor of a block on
    shard s lives on shard s-1, s or s+1."""
    layout, d = _decomp(frac, r, m, ns)
    table = layout.neighbor_table
    for b in range(layout.n_blocks):
        for nb in table[b]:
            if nb == layout.ghost:
                continue
            assert abs(int(d.shard_of[nb]) - int(d.shard_of[b])) <= 1


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_combined_table_decodes_to_neighbor_table(frac, r, m, ns):
    """Full decode of the combined-coordinate table: every entry maps
    back to exactly the block offset_table(1) says — local slots to the
    shard's own strips, recv slabs through the neighbor's send buffer,
    the ghost row to layout.ghost."""
    layout, d = _decomp(frac, r, m, ns)
    nbl = d.nb_local
    want = layout.neighbor_table
    for s in range(ns):
        for li in range(nbl):
            b = d.perm[s * nbl + li]
            for dd in range(8):
                slot = int(d.table[s, li, dd])
                if b < 0:  # dead slot: all-ghost row
                    assert slot == nbl
                    continue
                wn = int(want[b, dd])
                if slot < nbl:                      # local strip
                    got = int(d.perm[s * nbl + slot])
                elif slot == nbl:                   # ghost zero row
                    got = layout.ghost
                elif slot < nbl + 1 + d.ms_next:    # from prev shard
                    j = slot - (nbl + 1)
                    lo = int(d.send_next_idx[s - 1, j])
                    got = (layout.ghost if lo == nbl
                           else int(d.perm[(s - 1) * nbl + lo]))
                else:                               # from next shard
                    j = slot - (nbl + 1 + d.ms_next)
                    lo = int(d.send_prev_idx[s + 1, j])
                    got = (layout.ghost if lo == nbl
                           else int(d.perm[(s + 1) * nbl + lo]))
                assert got == wn, (s, li, dd, slot, got, wn)


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_interior_boundary_partition(frac, r, m, ns):
    """interior_idx and boundary_idx partition [0, nbl): each real slot
    appears exactly once, interior slots' table rows are fully local
    (no combined slot past the ghost row), every boundary slot has at
    least one remote reference; sentinel padding only."""
    layout, d = _decomp(frac, r, m, ns)
    nbl = d.nb_local
    for s in range(ns):
        ii = [x for x in d.interior_idx[s] if x < nbl]
        bi = [x for x in d.boundary_idx[s] if x < nbl]
        assert sorted(ii + bi) == list(range(nbl))
        for li in ii:
            assert (d.table[s, li] <= nbl).all(), (s, li)
        for li in bi:
            assert (d.table[s, li] > nbl).any(), (s, li)


@pytest.mark.parametrize("frac,r,m,ns", CONFIGS,
                         ids=lambda c: getattr(c, "name", c))
def test_send_buffers_cover_remote_reads(frac, r, m, ns):
    """Whatever a shard's table reads from a recv slab, the neighbor's
    send buffer actually ships (no dangling routing slots), and send
    indices are valid local slots of the sender."""
    layout, d = _decomp(frac, r, m, ns)
    nbl = d.nb_local
    assert d.send_prev_idx.shape == (ns, d.ms_prev)
    assert d.send_next_idx.shape == (ns, d.ms_next)
    assert (d.send_prev_idx <= nbl).all()
    assert (d.send_next_idx <= nbl).all()
    # shard 0 has no prev neighbor, last shard no next: sentinel-only
    assert (d.send_prev_idx[0] == nbl).all()
    assert (d.send_next_idx[ns - 1] == nbl).all()


def test_degenerate_mesh_detected():
    """Fewer occupied expanded rows than shards -> invalid (the engine
    falls back to gather); never an exception."""
    layout = BlockLayout(fractals.SIERPINSKI, 3, 2)  # 2 block rows
    d = layout.strip_decomposition(8)
    assert not d.valid
    assert layout.strip_decomposition(2).valid


def test_single_shard_decomposition():
    """ns=1: everything local, no remote refs, zero wire bytes."""
    layout = BlockLayout(fractals.SIERPINSKI, 5, 2)
    d = layout.strip_decomposition(1)
    assert d.valid and d.nb_local == layout.n_blocks
    assert (d.table[0] <= d.nb_local).all()
    assert d.wire_bytes_per_exchange(2, 1) == 0


def test_memoized_per_layout():
    layout = BlockLayout(fractals.SIERPINSKI, 5, 2)
    assert layout.strip_decomposition(4) is layout.strip_decomposition(4)
    assert isinstance(layout.strip_decomposition(4), StripDecomposition)


def test_balanced_contiguous_partition():
    """Partition helper: contiguous groups, every group non-empty, max
    load minimized vs the trivial lower bound."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        n = int(rng.integers(4, 30))
        g = int(rng.integers(1, n + 1))
        counts = rng.integers(1, 50, n)
        bounds = _balanced_contiguous_partition(counts, g)
        assert len(bounds) == g
        prev = 0
        loads = []
        for lo, hi in bounds:
            assert lo == prev and hi > lo
            loads.append(int(counts[lo:hi].sum()))
            prev = hi
        assert prev == n
        assert max(loads) >= counts.sum() / g  # sanity on the cap
        assert max(loads) <= counts.sum()      # and it is a partition


def test_wire_accounting_scales_with_shards_not_blocks():
    """Per-device wire bytes depend on the boundary geometry (ms_*),
    not on nb: the r=11/m=1 curve the scaling gate pins is flat."""
    layout = BlockLayout(fractals.SIERPINSKI, 8, 1)
    pd = {ns: layout.strip_decomposition(ns)
          .wire_bytes_per_device_per_exchange(2, 1)
          for ns in (2, 4, 8)}
    total = {ns: layout.strip_decomposition(ns)
             .wire_bytes_per_exchange(2, 1) for ns in (2, 4, 8)}
    # totals grow with the pair count, per-device stays within the
    # widest-row bound rather than tracking nb/ns
    assert total[8] == (layout.strip_decomposition(8).ms_prev
                        + layout.strip_decomposition(8).ms_next) * 7 \
        * layout.strip_decomposition(8).slot_bytes(2, 1)
    nb_share = layout.n_blocks // 8 * 4 * 2 * layout.rho
    assert pd[8] < nb_share, "per-device wire bytes track nb — not flat"
