"""Workload subsystem tests: per-workload cross-engine equivalence (cell /
block / BB / lambda, step-for-step in expanded space), dense expanded-space
references for the PDE workloads, Pallas kernel parity, and the batched
runner (vmap-vs-loop equality + compiled-engine reuse)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fractals
from repro.core.compact import BlockLayout
from repro.core.stencil import make_engine
from repro.kernels import squeeze_stencil as sk
from repro.workloads import (GRAY_SCOTT, HEAT, HIGHLIFE, LIFE, BatchedRunner,
                             TotalisticCA, get_workload)

ALL_WORKLOADS = [LIFE, HIGHLIFE, HEAT, GRAY_SCOTT]
WL_IDS = [w.name for w in ALL_WORKLOADS]

CASES = [
    (fractals.SIERPINSKI, 5, 2),
    (fractals.CARPET, 3, 1),
    (fractals.VICSEK, 3, 1),
]
CASE_IDS = [f"{f.name}-r{r}-m{m}" for f, r, m in CASES]


def _tol(wl):
    return dict(rtol=0, atol=0) if wl.dtype == jnp.uint8 \
        else dict(rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- cross-engine parity
@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
def test_engines_agree_per_workload(frac, r, m, wl):
    bb = make_engine("bb", frac, r, workload=wl)
    lam = make_engine("lambda", frac, r, workload=wl)
    cell = make_engine("cell", frac, r, workload=wl)
    blk = make_engine("block", frac, r, m, workload=wl)

    e0 = bb.init_random(seed=7)
    s_bb, s_lam = e0, e0
    s_cell = cell.init_random(seed=7)
    s_blk = blk.init_random(seed=7)
    np.testing.assert_array_equal(np.asarray(cell.to_expanded(s_cell)),
                                  np.asarray(e0))
    np.testing.assert_array_equal(np.asarray(blk.to_expanded(s_blk)),
                                  np.asarray(e0))

    for step in range(5):
        s_bb = bb.step(s_bb)
        s_lam = lam.step(s_lam)
        s_cell = cell.step(s_cell)
        s_blk = blk.step(s_blk)
        np.testing.assert_allclose(
            np.asarray(s_lam), np.asarray(s_bb), **_tol(wl),
            err_msg=f"{wl.name}: lambda-engine diverged at step {step}")
        np.testing.assert_allclose(
            np.asarray(cell.to_expanded(s_cell)), np.asarray(s_bb),
            **_tol(wl),
            err_msg=f"{wl.name}: squeeze-cell diverged at step {step}")
        np.testing.assert_allclose(
            np.asarray(blk.to_expanded(s_blk)), np.asarray(s_bb),
            **_tol(wl),
            err_msg=f"{wl.name}: squeeze-block diverged at step {step}")


# ------------------------------------------- dense expanded-space references
def _dense_heat_step(state, mask, alpha):
    p = np.pad(state, 1)
    agg = (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])
    return (state + alpha * (agg - 4.0 * state)) * mask


def test_heat_matches_dense_reference():
    frac, r = fractals.SIERPINSKI, 5
    eng = make_engine("cell", frac, r, workload=HEAT)
    mask = np.asarray(frac.mask(r)).astype(np.float32)
    s = eng.init_random(seed=3)
    ref = np.asarray(eng.to_expanded(s))
    for step in range(8):
        s = eng.step(s)
        ref = _dense_heat_step(ref, mask, HEAT.alpha)
        np.testing.assert_allclose(
            np.asarray(eng.to_expanded(s)), ref, rtol=1e-5, atol=1e-5,
            err_msg=f"heat diverged from dense reference at step {step}")
    # diffusion with Dirichlet-0 holes loses mass monotonically
    assert ref.sum() < np.asarray(eng.to_expanded(
        make_engine("cell", frac, r, workload=HEAT).init_random(3))).sum()


def _dense_gray_scott_step(u, v, mask, wl):
    def lap(a):
        p = np.pad(a, 1)
        ortho = p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
        diag = p[:-2, :-2] + p[:-2, 2:] + p[2:, :-2] + p[2:, 2:]
        return 0.2 * ortho + 0.05 * diag - a
    uvv = u * v * v
    nu = u + wl.du * lap(u) - uvv + wl.feed * (1.0 - u)
    nv = v + wl.dv * lap(v) + uvv - (wl.feed + wl.kill) * v
    return nu * mask, nv * mask


def test_gray_scott_matches_dense_reference():
    frac, r, m = fractals.SIERPINSKI, 5, 2
    eng = make_engine("block", frac, r, m, workload=GRAY_SCOTT)
    mask = np.asarray(frac.mask(r)).astype(np.float32)
    s = eng.init_random(seed=11)
    e = np.asarray(eng.to_expanded(s))
    u, v = e[0], e[1]
    for step in range(6):
        s = eng.step(s)
        u, v = _dense_gray_scott_step(u, v, mask, GRAY_SCOTT)
        got = np.asarray(eng.to_expanded(s))
        np.testing.assert_allclose(
            got[0], u, rtol=1e-5, atol=1e-5,
            err_msg=f"gray-scott U diverged at step {step}")
        np.testing.assert_allclose(
            got[1], v, rtol=1e-5, atol=1e-5,
            err_msg=f"gray-scott V diverged at step {step}")


# ------------------------------------------------------- Pallas kernel parity
@pytest.mark.parametrize("variant", ["blocks", "strips", "fused"])
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
def test_pallas_kernels_run_all_workloads(wl, variant):
    frac, r, m = fractals.SIERPINSKI, 5, 2
    layout = BlockLayout(frac, r, m)
    eng = make_engine("block", frac, r, m, workload=wl)
    step = {"blocks": sk.stencil_step_blocks,
            "strips": sk.stencil_step_strips,
            "fused": sk.stencil_step_fused}[variant]
    s = eng.init_random(seed=5)
    for i in range(3):
        want = eng.step(s)
        got = step(layout, s, wl, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), **_tol(wl),
            err_msg=f"{wl.name}/{variant} diverged at step {i}")
        s = got


def test_pallas_engine_factory_kinds():
    frac, r, m = fractals.CARPET, 3, 1
    for wl in (LIFE, GRAY_SCOTT):
        blk = make_engine("block", frac, r, m, workload=wl)
        pal = make_engine("pallas-strips", frac, r, m, workload=wl)
        s = blk.init_random(seed=2)
        np.testing.assert_allclose(np.asarray(pal.step(s)),
                                   np.asarray(blk.step(s)), **_tol(wl))


# ----------------------------------------------- v5 MXU stencil-as-matmul
#: one case per lane-packing regime the paper's rho = 8-9 serving sweet
#: spot cares about: rho 3 (carpet m=1), 8 (sierpinski m=3), 9 (carpet m=2)
MXU_CASES = [
    (fractals.CARPET, 2, 1),
    (fractals.SIERPINSKI, 5, 3),
    (fractals.CARPET, 3, 2),
]
MXU_CASE_IDS = [f"{f.name}-rho{f.s ** m}" for f, r, m in MXU_CASES]


def test_weight_factors_reconstruct_exactly():
    """The rank-1 SVD terms must rebuild weights3x3 *exactly* (float64
    SVD precision) for every shipped workload — the MXU kernel's banded
    contractions are only as correct as this decomposition. Covers the
    multi-channel Gray-Scott 9-point Laplacian."""
    from repro.workloads import WORKLOADS
    for wl in WORKLOADS.values():
        terms = wl.weight_factors
        assert 1 <= len(terms) <= 3, f"{wl.name}: rank {len(terms)} > 3"
        recon = sum(np.outer(row, col) for row, col in terms)
        np.testing.assert_allclose(
            recon, wl.weights3x3, rtol=0, atol=1e-12,
            err_msg=f"{wl.name}: rank-1 terms do not reconstruct weights2d")
    assert GRAY_SCOTT.n_channels == 2  # the multi-channel case is covered


@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
@pytest.mark.parametrize("frac,r,m", MXU_CASES, ids=MXU_CASE_IDS)
def test_mxu_kernel_matches_block_engine(frac, r, m, wl, k):
    """v5 <-> block-engine step-for-step parity per workload x fusion
    depth x rho: bit-exact for the CA workloads (the f32 banded matmul
    reconstructs integer counts, rounded in-kernel), 1e-5 for the PDEs."""
    layout = BlockLayout(frac, r, m)
    eng = make_engine("block", frac, r, m, workload=wl)
    s = eng.init_random(seed=5)
    for rnd in range(2):
        want = s
        for _ in range(k):
            want = eng.step(want)
        got = sk.stencil_step_mxu_k(layout, s, wl, k=k, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), **_tol(wl),
            err_msg=f"{wl.name}/k={k} diverged (round {rnd})")
        s = got


def test_mxu_batch_grid_matches_single_dispatch():
    """The native (B, n_macro) batch grid must agree with B independent
    single-simulation dispatches — batching is pure orchestration."""
    frac, r, m = fractals.SIERPINSKI, 5, 3
    layout = BlockLayout(frac, r, m)
    for wl, k in ((LIFE, 2), (GRAY_SCOTT, 1)):
        eng = make_engine("block", frac, r, m, workload=wl)
        states = jnp.stack([eng.init_random(seed=i) for i in range(4)])
        native = sk.stencil_step_mxu_batched(layout, states, wl, k=k,
                                             interpret=True)
        for b in range(states.shape[0]):
            single = sk.stencil_step_mxu_k(layout, states[b], wl, k=k,
                                           interpret=True)
            np.testing.assert_allclose(
                np.asarray(native[b]), np.asarray(single), **_tol(wl),
                err_msg=f"{wl.name}/k={k}: batch grid != single, b={b}")


def test_mxu_runner_batch_grid_matches_vmap_path():
    """BatchedRunner's pallas-mxu batch-grid dispatch must match both a
    per-simulation loop and the vmap path it replaces (pallas-strips)."""
    frac, r, m = fractals.SIERPINSKI, 5, 3
    runner = BatchedRunner()
    for wl in (HEAT, LIFE):
        states = runner.init_batch("pallas-mxu", frac, r, seeds=range(8),
                                   m=m, workload=wl)
        eng = runner.engine_for("pallas-mxu", frac, r, m=m, workload=wl)
        assert eng.supports_native_batch
        stepped = runner.step("pallas-mxu", frac, r, states, m=m,
                              workload=wl)
        ran = runner.run("pallas-mxu", frac, r, states, steps=5, m=m,
                         workload=wl)
        vmap_ran = runner.run("pallas-strips", frac, r, states, steps=5,
                              m=m, workload=wl)
        np.testing.assert_allclose(np.asarray(ran), np.asarray(vmap_ran),
                                   **_tol(wl),
                                   err_msg=f"{wl.name}: mxu grid != vmap")
        for b in range(states.shape[0]):
            np.testing.assert_allclose(np.asarray(stepped[b]),
                                       np.asarray(eng.step(states[b])),
                                       **_tol(wl))
    # one build + a handful of traces per config, exactly like the vmap path
    assert runner.stats.builds == 4, runner.stats


def test_mxu_engine_factory_and_limits():
    frac, r, m = fractals.CARPET, 2, 1  # rho = 3
    eng = make_engine("pallas-mxu", frac, r, m, workload=LIFE)
    blk = make_engine("block", frac, r, m, workload=LIFE)
    s = eng.init_random(seed=3)
    np.testing.assert_array_equal(np.asarray(eng.step(s)),
                                  np.asarray(blk.step(s)))
    out = eng.run(s, 4)
    want = s
    for _ in range(4):
        want = blk.step(want)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    with pytest.raises(ValueError, match="k <= rho"):
        sk.stencil_step_mxu_k(BlockLayout(frac, r, m), s, LIFE, k=4,
                              interpret=True)
    with pytest.raises(ValueError, match="native batching"):
        make_engine("pallas-strips", frac, r, m).step_batched(s[None])


# ----------------------------------------------------------- batched runner
def test_batched_runner_matches_python_loop():
    frac, r = fractals.SIERPINSKI, 5
    runner = BatchedRunner()
    for kind, m, wl in [("cell", 0, HEAT), ("block", 2, GRAY_SCOTT),
                        ("cell", 0, LIFE)]:
        states = runner.init_batch(kind, frac, r, seeds=range(5), m=m,
                                   workload=wl)
        stepped = runner.step(kind, frac, r, states, m=m, workload=wl)
        ran = runner.run(kind, frac, r, states, steps=4, m=m, workload=wl)
        eng = runner.engine_for(kind, frac, r, m=m, workload=wl)
        for b in range(states.shape[0]):
            ref = states[b]
            np.testing.assert_allclose(np.asarray(stepped[b]),
                                       np.asarray(eng.step(ref)), **_tol(wl))
            for _ in range(4):
                ref = eng.step(ref)
            np.testing.assert_allclose(np.asarray(ran[b]), np.asarray(ref),
                                       **_tol(wl),
                                       err_msg=f"{kind}/{wl.name} batch {b}")


def test_batched_runner_reuses_compiled_engine():
    """>= 8 concurrent simulations of one (kind, frac, r, m, workload)
    config must share a single built+traced engine (the compile-count
    assertion from the acceptance criteria)."""
    frac, r = fractals.SIERPINSKI, 5
    runner = BatchedRunner()
    states = runner.init_batch("cell", frac, r, seeds=range(8),
                               workload=HEAT)
    assert states.shape[0] == 8
    for _ in range(3):
        states = runner.step("cell", frac, r, states, workload=HEAT)
    # stepping one-at-a-time through the same cache entry: still no rebuild
    for b in range(8):
        runner.step("cell", frac, r, states[b:b + 1], workload=HEAT)
    assert runner.stats.builds == 1, runner.stats
    # batched (B=8) and single (B=1) shapes each trace once, nothing more
    assert runner.stats.traces == 2, runner.stats
    # a different workload is a different cache entry
    runner.init_batch("cell", frac, r, seeds=range(2), workload=LIFE)
    assert runner.stats.builds == 2
    assert runner.cache_size() == 2


def test_batched_runner_lru_evicts():
    frac = fractals.SIERPINSKI
    runner = BatchedRunner(capacity=2)
    for r in (3, 4, 5):
        runner.engine_for("cell", frac, r, workload=LIFE)
    assert runner.cache_size() == 2
    assert runner.stats.evictions == 1
    # oldest (r=3) was evicted; re-requesting it rebuilds
    runner.engine_for("cell", frac, 3, workload=LIFE)
    assert runner.stats.builds == 4


def test_batched_runner_normalizes_pallas_alias():
    frac, r, m = fractals.CARPET, 3, 1
    runner = BatchedRunner()
    e1 = runner.engine_for("pallas", frac, r, m=m, workload=LIFE)
    e2 = runner.engine_for("pallas-strips", frac, r, m=m, workload=LIFE)
    assert e1 is e2
    assert runner.stats.builds == 1
    assert runner.cache_size() == 1


def test_batched_runner_to_expanded():
    frac, r, m = fractals.CARPET, 3, 1
    runner = BatchedRunner()
    states = runner.init_batch("block", frac, r, seeds=range(3), m=m,
                               workload=HEAT)
    exp = runner.to_expanded("block", frac, r, states, m=m, workload=HEAT)
    n = frac.side(r)
    assert exp.shape == (3, n, n)
    eng = runner.engine_for("block", frac, r, m=m, workload=HEAT)
    np.testing.assert_allclose(np.asarray(exp[1]),
                               np.asarray(eng.to_expanded(states[1])))


# --------------------------------------------------------------- misc rules
def test_workload_registry_roundtrip():
    assert get_workload("life") is LIFE
    assert get_workload("gray-scott") is GRAY_SCOTT
    with pytest.raises(KeyError):
        get_workload("nope")


def test_workload_ndim_guard():
    """A dimension-specific workload on the wrong-dimension engine must
    raise instead of silently computing a wrong Laplacian."""
    from repro.core import fractals3d as f3
    from repro.core.stencil3d import BB3DEngine
    from repro.workloads import HEAT3D
    with pytest.raises(ValueError, match="3D-only"):
        make_engine("bb", fractals.SIERPINSKI, 3, workload=HEAT3D)
    with pytest.raises(ValueError, match="2D-only"):
        BB3DEngine(f3.SIERPINSKI3D, 2, HEAT)
    with pytest.raises(ValueError, match="single-channel"):
        BB3DEngine(f3.SIERPINSKI3D, 2, GRAY_SCOTT)


def test_totalistic_life_matches_legacy_rule():
    from repro.workloads import life_rule
    rng = np.random.default_rng(0)
    alive = jnp.asarray(rng.integers(0, 2, (16, 16)), jnp.uint8)
    counts = jnp.asarray(rng.integers(0, 9, (16, 16)), jnp.int32)
    want = life_rule(alive, counts)
    got = TotalisticCA().apply(alive, counts, None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
