"""Subprocess body: EXECUTE the production-sharded train step on an
8-device mesh and compare loss + updated params against the unsharded
single-device step — the sharding rules must preserve semantics, not just
compile. Covers a dense arch and the MoE (shard-local dispatch) path."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.utils.jax_compat import make_mesh  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim import adamw  # noqa: E402


def check(arch, seq_shard=False, tol=2e-3):
    cfg = configs.get_smoke_config(arch)
    # d_ff=128 divides model=2; heads=4 divides; vocab 512 divides
    if seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard=True)
    if cfg.moe is not None:
        # Pin the capacity-dispatch grouping to the mesh's batch degree
        # (4): the group count is SEMANTIC — capacity is bounded per
        # group, so the g=1 unsharded default drops different tokens than
        # the 8-device shard-local dispatch and the updated params
        # diverge (worst relative delta ~2 observed — the old xfail).
        # With the grouping pinned on both sides, the sharded step is a
        # pure re-layout of the same math.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=4))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4,
                                weight_decay=0.0)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(opt_cfg, params)
    opt = jax.tree.map(lambda a: jnp.array(a, copy=True), opt)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab),
    }

    # unsharded reference
    step_ref = specs_lib.make_train_step(cfg, opt_cfg, mesh=None)
    p_ref, _, m_ref = step_ref(params, opt, batch)

    # sharded execution on a (4, 2) mesh with the production specs
    mesh = make_mesh((4, 2), ("data", "model"))
    p_sh = specs_lib.param_shardings(params, mesh)
    params_s = jax.device_put(params, p_sh)
    o_struct = jax.eval_shape(lambda: opt)
    o_sh = specs_lib.opt_state_shardings(o_struct, params, mesh)
    opt_s = jax.device_put(jax.tree.map(
        lambda a: jnp.array(a, copy=True), opt), o_sh)
    batch_s = jax.device_put(batch, specs_lib.batch_shardings(
        jax.eval_shape(lambda: batch), mesh))
    with mesh:
        step_sh = jax.jit(specs_lib.make_train_step(cfg, opt_cfg, mesh))
        p_out, _, m_out = step_sh(params_s, opt_s, batch_s)

    l_ref, l_out = float(m_ref["loss"]), float(m_out["loss"])
    assert abs(l_ref - l_out) / max(abs(l_ref), 1e-6) < tol, \
        f"{arch}: loss {l_ref} vs sharded {l_out}"
    worst = 0.0
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        d = float(jnp.max(jnp.abs(a - jax.device_get(b))))
        scale = float(jnp.max(jnp.abs(a))) + 1e-6
        worst = max(worst, d / scale)
    assert worst < 5e-2, f"{arch}: worst relative param delta {worst}"
    print(f"OK {arch} (seq_shard={seq_shard}): loss {l_ref:.5f} == "
          f"{l_out:.5f}, worst param delta {worst:.2e}")


def main():
    assert jax.device_count() == 8
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which not in ("all", "dense", "moe"):
        raise SystemExit(f"unknown selector {which!r}")
    if which in ("all", "dense"):
        check("smollm-135m")
        check("smollm-135m", seq_shard=True)
    if which in ("all", "moe"):
        check("mixtral-8x22b")  # MoE shard-local dispatch path
    print("SHARDED_EQ_OK")


if __name__ == "__main__":
    main()
