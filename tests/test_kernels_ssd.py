"""SSD chunk kernel vs the pure-jnp chunked-scan oracle: shape sweep over
(batch, seq, heads, head_dim, state, chunk), fp32 allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _inputs(b, s, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, n)) * 0.5
    return x, dt, a, bm, cm


@pytest.mark.parametrize("shape", [
    (1, 16, 2, 8, 8, 8),      # tiny
    (2, 64, 4, 16, 16, 16),   # multi-chunk, multi-batch
    (1, 40, 3, 8, 16, 16),    # ragged (padding path)
], ids=["tiny", "multi", "ragged"])
def test_ssd_kernel_matches_oracle(shape):
    b, s, h, p, n, chunk = shape
    x, dt, a, bm, cm = _inputs(b, s, h, p, n, seed=s)
    got = ops.ssd_chunk_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ssd_kernel_chunk_size_invariance():
    """Different chunkings of the same sequence agree (the scan identity)."""
    x, dt, a, bm, cm = _inputs(1, 64, 2, 8, 8, seed=3)
    y1 = ops.ssd_chunk_scan(x, dt, a, bm, cm, chunk=8, interpret=True)
    y2 = ops.ssd_chunk_scan(x, dt, a, bm, cm, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-5)


def test_ssd_kernel_matches_sequential_recurrence():
    """Ground truth: the per-token state recurrence h = h*exp(dtA) + dt B x,
    y = C.h (the decode path's math), fully sequential."""
    b, s, h, p, n = 1, 24, 2, 4, 8
    x, dt, a, bm, cm = _inputs(b, s, h, p, n, seed=7)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None])
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], bm[:, t])
        state = state * da[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, cm[:, t]))
    want = jnp.stack(ys, axis=1)
    got = ops.ssd_chunk_scan(x, dt, a, bm, cm, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
