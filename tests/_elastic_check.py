"""Subprocess body for the elastic-restore test: a checkpoint written by a
single-device run restores onto an 8-device mesh with production
shardings, trains on, and the losses continue sanely."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

from repro.utils.jax_compat import make_mesh  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.utils.sharding import param_specs  # noqa: E402


def main(ckpt_dir):
    assert jax.device_count() == 8
    cfg = configs.get_smoke_config("smollm-135m")
    mesh = make_mesh((4, 2), ("data", "model"))

    params_like = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_like = adamw.init(adamw.AdamWConfig(), params_like)
    mgr = CheckpointManager(ckpt_dir)

    specs = param_specs(params_like, mesh)
    flat_specs = {}
    import jax.tree_util as jtu
    for path, s in jtu.tree_flatten_with_path(specs)[0]:
        name = "__".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        flat_specs["params__" + name] = s

    def put(name, arr):
        # elastic restore: device_put with the NEW mesh's sharding
        spec = flat_specs.get(name)
        if spec is not None:
            return jax.device_put(
                arr, jax.sharding.NamedSharding(mesh, spec))
        return jax.device_put(arr)

    state = mgr.restore({"params": params_like, "opt": opt_like}, put=put)
    # restored leaves are sharded over the 8-device mesh
    some = jax.tree.leaves(state["params"])[0]
    assert len(some.sharding.device_set) >= 1
    # continue training one step under the mesh
    from repro.data.pipeline import SyntheticMarkov
    from repro.launch import specs as specs_lib
    data = SyntheticMarkov(vocab=cfg.vocab, seq_len=16, global_batch=4,
                           seed=3)
    step = jax.jit(specs_lib.make_train_step(
        cfg, adamw.AdamWConfig(), mesh))
    opt_state = jax.tree.map(lambda a: jax.numpy.array(a, copy=True),
                             state["opt"])
    with mesh:
        p, o, m = step(state["params"], opt_state, data.batch(0))
    assert np.isfinite(float(m["loss"]))
    print(f"ELASTIC_OK loss={float(m['loss']):.4f}")


if __name__ == "__main__":
    main(sys.argv[1])
