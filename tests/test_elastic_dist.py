"""ElasticDistributedRunner recovery state machine, in-process on the
default (single-device) CPU backend: detect -> retry -> restore ->
degraded-mode, driven by the shard-aware chaos hooks. Bit-exactness is
always against an uninterrupted SqueezeBlockEngine run of the same
seed — the compact trajectory is mesh-independent, so the single-device
reference is the ground truth for every mesh size. The full 8-device
matrix (including the elastic 8->4 reshard) runs in its own
interpreter via tests/test_chaos_dist.py."""
import numpy as np
import pytest

from repro.core.compact import BlockLayout
from repro.core.elastic import ElasticDistributedRunner
from repro.core.fractals import SIERPINSKI
from repro.core.stencil import SqueezeBlockEngine
from repro.runtime.fault import (DeviceLostError, Fault, FaultInjector,
                                 InjectedFault, PreemptionHandler)
from repro.workloads import LIFE

SEED = 7
STEPS = 12
K = 2


@pytest.fixture(scope="module")
def layout():
    return BlockLayout(SIERPINSKI, r=4, m=2)


@pytest.fixture(scope="module")
def ref(layout):
    eng = SqueezeBlockEngine(layout, LIFE, fusion_k=K)
    return np.asarray(eng.run(eng.init_random(SEED), STEPS))


def _runner(layout, tmp_path, faults=(), **kw):
    kw.setdefault("ckpt_dir", str(tmp_path / "ckpts"))
    kw.setdefault("ckpt_every", 4)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("backoff_cap_s", 0.05)
    inj = FaultInjector(faults) if faults else None
    return ElasticDistributedRunner(layout, workload=LIFE, fusion_k=K,
                                    injector=inj, **kw), inj


def _final(runner, out):
    return np.asarray(runner.engine.to_dense(out))


# ------------------------------------------------------------ happy path
def test_clean_run_matches_block_engine(layout, tmp_path, ref):
    runner, _ = _runner(layout, tmp_path)
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    st = runner.stats
    assert st.failures == 0 and st.retries == 0
    assert st.launches == STEPS // K
    assert st.checkpoints == STEPS // 4  # every boundary landed
    assert st.steps_done == STEPS


def test_from_dense_round_trip(layout, tmp_path):
    runner, _ = _runner(layout, tmp_path, ckpt_every=0)
    with runner:
        eng = runner.engine
        state = eng.init_random(3)
        dense = np.asarray(eng.to_dense(state))
        back = eng.from_dense(dense)
        np.testing.assert_array_equal(
            np.asarray(eng.to_dense(back)), dense)


# --------------------------------------------------------- fault classes
def test_shard_exception_retries_bit_exact(layout, tmp_path, ref):
    runner, inj = _runner(
        layout, tmp_path,
        faults=[Fault("shard_exception", at_segment=1, shard=0)])
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    assert inj.all_fired()
    st = runner.stats
    assert st.failures >= 1 and st.retries >= 1
    assert st.recoveries >= 1 and st.max_recovery_s > 0.0


def test_halo_corruption_detected_and_restored(layout, tmp_path, ref):
    runner, inj = _runner(
        layout, tmp_path,
        faults=[Fault("halo_corrupt", at_segment=1, shard=0)])
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    assert inj.all_fired()
    assert any(kind == "halo_corrupt" for _, kind, _ in inj.log)
    assert runner.stats.failures >= 1 and runner.stats.retries >= 1


def test_stalled_launch_abandoned_and_engine_rebuilt(layout, tmp_path,
                                                     ref):
    # launch 0 warms the (seg, shards, shape) key; the stall at launch
    # 1 then races the post-compile timeout, loses, and the runner
    # rebuilds the engine + restores
    runner, inj = _runner(
        layout, tmp_path,
        faults=[Fault("shard_stall", at_segment=1, stall_s=2.0)],
        launch_timeout_s=0.5, compile_grace_s=120.0)
    eng0 = runner.engine
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    assert inj.all_fired()
    st = runner.stats
    assert st.hangs >= 1 and runner.watchdog.hangs >= 1
    assert runner.engine is not eng0  # fresh executables
    assert runner.n_shards == eng0.n_shards  # same mesh, not a reshard


def test_damaged_checkpoint_falls_back_to_previous_step(layout,
                                                        tmp_path, ref):
    # ckpt at step 4 saves at launch counter 2, step 8 at counter 4:
    # damage the step-8 save the moment it lands, then crash a shard —
    # the restore must walk back to the intact step-4 checkpoint
    runner, inj = _runner(
        layout, tmp_path,
        faults=[Fault("corrupt", at_segment=4),
                Fault("shard_exception", at_segment=5)])
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    assert inj.all_fired()
    assert runner.stats.restores >= 1


def test_device_loss_at_floor_is_terminal(layout, tmp_path):
    # a single-device mesh cannot shrink: the loss re-raises instead of
    # resharding (the 8->4 elastic path runs in test_chaos_dist.py)
    runner, _ = _runner(
        layout, tmp_path,
        faults=[Fault("device_loss", at_segment=1, shard=0)],
        min_devices=1)
    with runner, pytest.raises(DeviceLostError):
        runner.run(STEPS, seed=SEED)
    assert runner.stats.reshards == 0
    assert not runner.stats.degraded


def test_retries_exhausted_reraises(layout, tmp_path):
    runner, _ = _runner(
        layout, tmp_path,
        faults=[Fault("shard_exception", at_segment=i)
                for i in range(3)],
        max_retries=2)
    with runner, pytest.raises(InjectedFault):
        runner.run(STEPS, seed=SEED)
    assert runner.stats.failures == 3
    assert runner.stats.retries == 2  # third failure gave up


def test_success_resets_the_retry_budget(layout, tmp_path, ref):
    # two separate failure streaks, each under max_retries, must both
    # recover: attempt counts per streak, not per run
    runner, inj = _runner(
        layout, tmp_path,
        faults=[Fault("shard_exception", at_segment=1),
                Fault("shard_exception", at_segment=4)],
        max_retries=1)
    with runner:
        out = runner.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(runner, out), ref)
    assert inj.all_fired()
    assert runner.stats.recoveries == 2
    assert len(runner.stats.recovery_seconds) == 2


# ------------------------------------------------------- resume / preempt
def test_fresh_runner_resumes_from_checkpoints(layout, tmp_path, ref):
    first, _ = _runner(layout, tmp_path)
    with first:
        first.run(STEPS, seed=SEED)
    # same directory, new runner: run() resumes from the newest intact
    # step (here the final one) instead of recomputing
    second, _ = _runner(layout, tmp_path)
    with second:
        out = second.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(second, out), ref)
    assert second.stats.launches == 0  # nothing left to simulate
    assert second.stats.restores == 1
    assert second.stats.retries == 0  # a resume is not a failure retry


def test_preemption_checkpoints_and_resumes(layout, tmp_path, ref):
    handler = PreemptionHandler(install=False)
    handler.request()  # preempted before the first launch
    first, _ = _runner(layout, tmp_path, preemption=handler)
    with first:
        first.run(STEPS, seed=SEED)
    assert first.stats.preempted
    assert first.stats.steps_done < STEPS
    assert first.stats.checkpoints >= 1  # the forced final save
    second, _ = _runner(layout, tmp_path)
    with second:
        out = second.run(STEPS, seed=SEED)
        np.testing.assert_array_equal(_final(second, out), ref)
    assert not second.stats.preempted


def test_min_devices_validated(layout, tmp_path):
    with pytest.raises(ValueError):
        ElasticDistributedRunner(layout, workload=LIFE, min_devices=99)
