"""Property fuzz of the compact halo machinery: random s x s occupancy
masks (arbitrary NBB families, not just the named fractals) checked
against the EXPANDED-space oracle.

For any occupancy mask, depth k <= rho and random block state, the
depth-k padded tiles assembled through ``offset_table(k)``
(``pad_with_halo_k``) must equal the (rho+2k) x (rho+2k) windows cut
from the zero-padded expanded embedding — the definitionally-correct
halo. The packed-strip round trip (``pack_edge_strips`` +
``halo_from_strips_k``, the bytes the gather exchange ships) must
then reproduce the corresponding bands of those verified tiles; and
the neighbor-only p2p route (``StripDecomposition``: per-shard packing,
routed send buffers, combined-coordinate table) must reproduce them
again shard by shard, for every valid shard count — proving the two
exchange modes bit-identical through the oracle.

The fixed-case tests always run; the hypothesis fuzz runs wherever
hypothesis is installed (it is pinned in requirements-dev.txt, so CI
always fuzzes) and is skipped cleanly elsewhere."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compact import BlockLayout
from repro.core.fractals import NBBFractal

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal envs: the fixed-case tests still run
    given = None


def _random_state(layout, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(
        0, 256, (layout.n_blocks, layout.rho, layout.rho)).astype(
            np.int32)


def _check_pad_matches_expanded_oracle(layout, k, state):
    """pad_with_halo_k == windows of the zero-padded expanded state.
    Returns the verified (nb, rho+2k, rho+2k) tiles."""
    rho = layout.rho
    got = np.asarray(layout.pad_with_halo_k(jnp.asarray(state), k))
    exp = np.asarray(layout.to_expanded(jnp.asarray(state)))
    padded = np.pad(exp, k)
    org = np.asarray(layout.block_origin_expanded)  # (nb, 2) = (x, y)
    w = rho + 2 * k
    for b in range(layout.n_blocks):
        ox, oy = int(org[b, 0]), int(org[b, 1])
        np.testing.assert_array_equal(
            got[b], padded[oy:oy + w, ox:ox + w],
            err_msg=f"block {b} of {layout.frac.positions} k={k}")
    return got


def _check_strip_round_trip(layout, k, state, tiles):
    """pack_edge_strips + halo_from_strips_k == the halo bands of the
    oracle-verified padded tiles (ghost-remapped table, zero ghost row
    appended — exactly the distributed engine's exchange)."""
    rho = layout.rho
    w = rho + 2 * k
    s = jnp.asarray(state)[None]            # (1, nb, rho, rho)
    strips = layout.pack_edge_strips(s, k)
    strips = jnp.concatenate(
        [strips, jnp.zeros((1, 1) + strips.shape[2:], strips.dtype)],
        axis=1)
    table = jnp.asarray(layout.offset_table(k))
    table = jnp.where(table == layout.ghost, layout.n_blocks, table)
    top, bot, west, east = layout.halo_from_strips_k(strips, table, k)
    np.testing.assert_array_equal(np.asarray(top)[0], tiles[:, :k, :])
    np.testing.assert_array_equal(np.asarray(bot)[0],
                                  tiles[:, w - k:, :])
    np.testing.assert_array_equal(np.asarray(west)[0],
                                  tiles[:, k:k + rho, :k])
    np.testing.assert_array_equal(np.asarray(east)[0],
                                  tiles[:, k:k + rho, w - k:])


def _check_p2p_exchange(layout, k, state, tiles, n_shards):
    """Shard-by-shard simulation of the neighbor-only exchange: each
    shard packs its local strips, ships ONLY the routed send buffers to
    its two strip neighbors (``pack_edge_strips_for``), assembles its
    combined buffer and reads halos through the decomposition's
    combined-coordinate table (``halo_from_neighbor_strips_k``). Every
    real block's bands must equal the expanded-oracle tiles — i.e. the
    p2p exchange is bit-identical to the (already oracle-verified)
    all-gather path, with no dependence on non-neighbor shards."""
    d = layout.strip_decomposition(n_shards)
    if not d.valid:
        return False
    rho, nbl, w = layout.rho, d.nb_local, layout.rho + 2 * k
    state_z = np.concatenate(
        [state, np.zeros((1, rho, rho), state.dtype)], axis=0)
    src = np.where(d.perm >= 0, d.perm, layout.n_blocks)
    native = state_z[src]                       # dead slots all-zero
    strips_z = []
    for sh in range(n_shards):
        local = jnp.asarray(native[sh * nbl:(sh + 1) * nbl])[None]
        st_local = layout.pack_edge_strips(local, k)
        strips_z.append(jnp.concatenate(
            [st_local,
             jnp.zeros((1, 1) + st_local.shape[2:], st_local.dtype)],
            axis=1))
    for sh in range(n_shards):
        # what the two ppermute shifts deliver: prev's send_next buffer
        # and next's send_prev buffer (edge shards receive zeros)
        if sh > 0:
            recv_prev = d.pack_edge_strips_for(strips_z[sh - 1],
                                               "next", sh - 1)
        else:
            recv_prev = jnp.zeros(
                (1, d.ms_next) + strips_z[sh].shape[2:],
                strips_z[sh].dtype)
        if sh < n_shards - 1:
            recv_next = d.pack_edge_strips_for(strips_z[sh + 1],
                                               "prev", sh + 1)
        else:
            recv_next = jnp.zeros(
                (1, d.ms_prev) + strips_z[sh].shape[2:],
                strips_z[sh].dtype)
        combined = jnp.concatenate(
            [strips_z[sh], recv_prev, recv_next], axis=1)
        top, bot, west, east = d.halo_from_neighbor_strips_k(
            combined, jnp.asarray(d.table[sh]), k)
        for li in range(nbl):
            b = int(d.perm[sh * nbl + li])
            if b < 0:
                continue
            msg = f"shard {sh} local {li} block {b} k={k} ns={n_shards}"
            np.testing.assert_array_equal(
                np.asarray(top)[0, li], tiles[b, :k, :], err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(bot)[0, li], tiles[b, w - k:, :], err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(west)[0, li], tiles[b, k:k + rho, :k],
                err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(east)[0, li], tiles[b, k:k + rho, w - k:],
                err_msg=msg)
    return True


def _check(s, positions, r, k, seed):
    layout = BlockLayout(NBBFractal("fuzz", s, tuple(positions)),
                         r=r, m=1)
    layout.materialize()
    state = _random_state(layout, seed)
    tiles = _check_pad_matches_expanded_oracle(layout, k, state)
    _check_strip_round_trip(layout, k, state, tiles)
    for ns in (2, 3):
        _check_p2p_exchange(layout, k, state, tiles, ns)


# ------------------------------------------------- fixed representatives
CASES = [
    # sierpinski family (L-shape), depth 1
    (2, ((0, 0), (0, 1), (1, 1)), 2, 1, 0),
    # same mask, max depth k = rho, deeper level
    (2, ((0, 0), (0, 1), (1, 1)), 3, 2, 1),
    # disconnected diagonal: every neighbor is a ghost
    (2, ((0, 1), (1, 0)), 3, 1, 2),
    # vicsek X mask at s=3, mid depth
    (3, ((0, 0), (0, 2), (1, 1), (2, 0), (2, 2)), 2, 2, 3),
    # degenerate no-hole mask (dense grid embedded in the machinery)
    (3, tuple((x, y) for y in range(3) for x in range(3)), 2, 3, 4),
]


@pytest.mark.parametrize("s,positions,r,k,seed", CASES)
def test_halo_matches_expanded_oracle_fixed_masks(s, positions, r, k,
                                                  seed):
    _check(s, positions, r, k, seed)


@pytest.mark.parametrize("n_shards", [2, 3, 4])
@pytest.mark.parametrize("k", [1, 2])
def test_p2p_exchange_matches_oracle_multi_shard(n_shards, k):
    """Non-vacuous p2p coverage: a deep L-shape mask has enough occupied
    rows that the strip decomposition is VALID at every tested shard
    count — the simulation must actually run (returns True), not fall
    through the degenerate-mesh guard."""
    layout = BlockLayout(
        NBBFractal("fuzz", 2, ((0, 0), (0, 1), (1, 1))), r=4, m=1)
    layout.materialize()
    state = _random_state(layout, seed=9)
    tiles = _check_pad_matches_expanded_oracle(layout, k, state)
    assert _check_p2p_exchange(layout, k, state, tiles, n_shards)


# --------------------------------------------------------- hypothesis fuzz
if given is not None:
    @st.composite
    def _mask_cases(draw):
        s = draw(st.sampled_from([2, 3]))
        cells = [(x, y) for y in range(s) for x in range(s)]
        positions = draw(st.lists(st.sampled_from(cells), min_size=2,
                                  max_size=s * s, unique=True))
        r = draw(st.integers(min_value=2, max_value=3))
        k = draw(st.integers(min_value=1, max_value=s))  # rho=s at m=1
        seed = draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
        return s, positions, r, k, seed

    @settings(deadline=None, max_examples=25)
    @given(case=_mask_cases())
    def test_fuzzed_masks_match_expanded_oracle(case):
        _check(*case)
else:
    def test_fuzzed_masks_match_expanded_oracle():
        pytest.importorskip("hypothesis")  # records the skip reason
