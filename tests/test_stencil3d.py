"""3D compact stencil vs the expanded bounding-volume oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractals3d as f3
from repro.core.stencil3d import BB3DEngine, Squeeze3DEngine

CASES = [(f3.SIERPINSKI3D, 3), (f3.SIERPINSKI3D, 4), (f3.MENGER, 2)]


@pytest.mark.parametrize("frac,r", CASES,
                         ids=[f"{f.name}-r{r}" for f, r in CASES])
def test_3d_engines_agree(frac, r):
    bb = BB3DEngine(frac, r)
    sq = Squeeze3DEngine(frac, r)
    s_bb = bb.init_random(seed=5)
    s_sq = sq.init_random(seed=5)
    np.testing.assert_array_equal(np.asarray(sq.to_expanded(s_sq)),
                                  np.asarray(s_bb))
    for step in range(4):
        s_bb = bb.step(s_bb)
        s_sq = sq.step(s_sq)
        np.testing.assert_array_equal(
            np.asarray(sq.to_expanded(s_sq)), np.asarray(s_bb),
            err_msg=f"3D compact engine diverged at step {step}")


def test_3d_memory_reduction():
    frac, r = f3.SIERPINSKI3D, 6
    bb = BB3DEngine(frac, r).memory_bytes()
    sq = Squeeze3DEngine(frac, r).memory_bytes()
    assert bb == frac.side(r) ** 3
    assert sq == frac.volume(r)
    assert bb / sq == 2.0 ** r  # 8^r / 4^r


def test_3d_activity_nontrivial():
    frac, r = f3.MENGER, 2
    sq = Squeeze3DEngine(frac, r)
    s = sq.init_random(seed=1)
    s3 = sq.run(s, 3)
    assert s3.shape == s.shape
    assert bool(jnp.all((s3 == 0) | (s3 == 1)))
