"""Temporal fusion (k-step deep-halo stepping) across the stack.

Covers: depth-k halo geometry vs expanded-space windows (tables, masks,
pad), fused k-step parity vs k single steps for every workload x block
engine x k in {1, 2, 3} (bit-exact for CA, allclose for the PDE
workloads), the remainder path (steps % k != 0), the k > rho multi-ring
XLA path across block-level holes, buffer donation (zero-copy stepping),
the fusion-depth heuristic/override, zero-weight gather skipping, and the
batched runner's fused run + k cache-key component.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fractals
from repro.core.compact import BlockLayout
from repro.core.stencil import default_fusion_k, make_engine
from repro.kernels import squeeze_stencil as sk
from repro.workloads import (GRAY_SCOTT, HEAT, HIGHLIFE, LIFE, BatchedRunner)
from repro.workloads.base import MOORE_DIRS, halo_needs

ALL_WORKLOADS = [LIFE, HIGHLIFE, HEAT, GRAY_SCOTT]
WL_IDS = [w.name for w in ALL_WORKLOADS]

CASES = [
    (fractals.SIERPINSKI, 5, 2),   # rho = 4
    (fractals.CARPET, 3, 1),       # rho = 3, holes at block level
]
CASE_IDS = [f"{f.name}-r{r}-m{m}" for f, r, m in CASES]


def _tol(wl):
    return dict(rtol=0, atol=0) if wl.dtype == jnp.uint8 \
        else dict(rtol=1e-5, atol=1e-5)


def _single_steps(eng, state, n):
    for _ in range(n):
        state = eng.step(state)
    return state


# ------------------------------------------------------ depth-k geometry
@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
def test_pad_with_halo_k_depth1_matches_pad_with_halo(frac, r, m):
    layout = BlockLayout(frac, r, m)
    rng = np.random.default_rng(0)
    s = jnp.asarray(
        rng.integers(0, 7, (layout.n_blocks, layout.rho, layout.rho))
        .astype(np.float32) * np.asarray(layout.micro_mask))
    np.testing.assert_array_equal(np.asarray(layout.pad_with_halo_k(s, 1)),
                                  np.asarray(layout.pad_with_halo(s)))


@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("k", [1, 2, 5])
def test_halo_geometry_matches_expanded_windows(frac, r, m, k):
    """halo_mask(k) and pad_with_halo_k(s, k) must equal the depth-k
    window around each block cut from zero-padded expanded space — at
    every depth, including k > rho (multi-ring offset tables) and across
    out-of-fractal (ghost) regions."""
    layout = BlockLayout(frac, r, m)
    rho = layout.rho
    rng = np.random.default_rng(1)
    s = jnp.asarray(
        rng.integers(0, 9, (layout.n_blocks, rho, rho)).astype(np.float32)
        * np.asarray(layout.micro_mask))
    mask_pad = np.pad(np.asarray(frac.mask(r)), k)
    state_pad = np.pad(np.asarray(layout.to_expanded(s)), k)
    hmask = layout.halo_mask(k)
    padded = np.asarray(layout.pad_with_halo_k(s, k))
    for b, (ox, oy) in enumerate(layout.block_origin_expanded):
        np.testing.assert_array_equal(
            hmask[b], mask_pad[oy:oy + rho + 2 * k, ox:ox + rho + 2 * k],
            err_msg=f"halo_mask block {b}")
        np.testing.assert_array_equal(
            padded[b], state_pad[oy:oy + rho + 2 * k, ox:ox + rho + 2 * k],
            err_msg=f"pad_with_halo_k block {b}")


def test_offset_table_depth1_is_neighbor_table():
    layout = BlockLayout(fractals.SIERPINSKI, 5, 2)
    assert layout.halo_offsets(layout.rho) == MOORE_DIRS
    np.testing.assert_array_equal(layout.offset_table(2),
                                  layout.neighbor_table)


# ------------------------------------------------- fused k-step parity
@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
@pytest.mark.parametrize("k", [1, 2, 3])
def test_block_step_k_matches_single_steps(frac, r, m, wl, k):
    eng = make_engine("block", frac, r, m, workload=wl)
    s = eng.init_random(seed=5)
    np.testing.assert_allclose(
        np.asarray(eng.step_k(s, k)), np.asarray(_single_steps(eng, s, k)),
        **_tol(wl), err_msg=f"block/{wl.name}/k={k}")


@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
@pytest.mark.parametrize("k", [1, 2, 3])
def test_pallas_fused_k_kernel_matches_single_steps(frac, r, m, wl, k):
    layout = BlockLayout(frac, r, m)
    eng = make_engine("block", frac, r, m, workload=wl)
    s = eng.init_random(seed=5)
    got = sk.stencil_step_fused_k(layout, s, wl, k=k, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_single_steps(eng, s, k)),
        **_tol(wl), err_msg=f"pallas-v4/{wl.name}/k={k}")


@pytest.mark.parametrize("kind", ["block", "pallas-strips"])
@pytest.mark.parametrize("wl", [LIFE, GRAY_SCOTT],
                         ids=["life", "gray-scott"])
@pytest.mark.parametrize("k,steps", [(2, 5), (3, 4)])
def test_fused_run_remainder_path(kind, wl, k, steps):
    """run() tiles steps into floor(steps/k) fused launches + steps % k
    single steps; parity must hold when the remainder is nonempty."""
    frac, r, m = fractals.SIERPINSKI, 5, 2
    eng = make_engine(kind, frac, r, m, workload=wl, fusion_k=k)
    assert eng.effective_fusion_k == k
    s = eng.init_random(seed=9)
    np.testing.assert_allclose(
        np.asarray(eng.run(s, steps)),
        np.asarray(_single_steps(eng, s, steps)),
        **_tol(wl), err_msg=f"{kind}/{wl.name}/k={k}/steps={steps}")


def test_step_k_beyond_rho_multi_ring():
    """k > rho on a fractal with block-level holes: the depth-k offset
    tables must resolve blocks *beyond* a ghost (hole) block exactly, not
    compose through it."""
    frac, r, m = fractals.CARPET, 3, 1       # rho = 3
    eng = make_engine("block", frac, r, m, workload=LIFE)
    s = eng.init_random(seed=2)
    for k in (4, 7):                         # kb = 2 and 3 block rings
        np.testing.assert_array_equal(
            np.asarray(eng.step_k(s, k)),
            np.asarray(_single_steps(eng, s, k)), err_msg=f"k={k}")


def test_pallas_fused_k_rejects_k_beyond_rho():
    layout = BlockLayout(fractals.CARPET, 3, 1)  # rho = 3
    eng = make_engine("block", fractals.CARPET, 3, 1, workload=LIFE)
    s = eng.init_random(seed=1)
    with pytest.raises(ValueError, match="k <= rho"):
        sk.stencil_step_fused_k(layout, s, LIFE, k=4, interpret=True)
    with pytest.raises(ValueError, match="fusion_k"):
        make_engine("pallas-strips", fractals.CARPET, 3, 1,
                    workload=LIFE, fusion_k=4)


# ------------------------------------------------- heuristic / override
def test_default_fusion_k_heuristic():
    assert default_fusion_k(1) == 1          # no room for a halo ring
    assert default_fusion_k(3) == 2
    assert default_fusion_k(4) == 2
    assert default_fusion_k(8) == 3
    assert default_fusion_k(27) == 3
    for rho in (1, 2, 3, 4, 8, 9, 27):
        assert 1 <= default_fusion_k(rho) <= rho


def test_engine_fusion_k_override(monkeypatch):
    monkeypatch.setenv("SQUEEZE_TUNING", "off")  # pin the heuristic k
    frac, r, m = fractals.SIERPINSKI, 5, 2   # rho = 4 -> heuristic k = 2
    assert make_engine("block", frac, r, m).effective_fusion_k == 2
    assert make_engine("block", frac, r, m,
                       fusion_k=3).effective_fusion_k == 3
    assert make_engine("pallas-strips", frac, r, m,
                       fusion_k=1).effective_fusion_k == 1
    with pytest.raises(ValueError, match="fusion_k"):
        make_engine("block", frac, r, m, fusion_k=0)


# ------------------------------------------------------ zero-weight skip
def test_halo_needs_per_workload():
    # LIFE reads everything; HEAT (orthogonal-only) never reads corners
    assert halo_needs(LIFE.weights2d) == (True,) * 8
    assert halo_needs(HEAT.weights2d) == (True, True, True, True,
                                          False, False, False, False)
    assert halo_needs(GRAY_SCOTT.weights2d) == (True,) * 8
    # a corner weight alone keeps its two adjacent edge strips alive
    w = {d: 0 for d in MOORE_DIRS}
    w[(-1, -1)] = 1
    needs = halo_needs(tuple(w[d] for d in MOORE_DIRS))
    assert needs == (True, False, True, False, True, False, False, False)


def test_strips_gather_skips_zero_weight_corners():
    """With HEAT's needs, the v2 halo tensor's corner entries are constant
    zeros (not gathered) while edge strips still carry neighbor data —
    and kernel parity holds regardless (covered by test_workloads)."""
    layout = BlockLayout(fractals.SIERPINSKI, 5, 2)
    rho = layout.rho
    s = jnp.ones((1, layout.n_blocks, rho, rho), jnp.float32)
    full = np.asarray(sk._gather_halo_strips(layout, s))
    skip = np.asarray(sk._gather_halo_strips(layout, s,
                                             halo_needs(HEAT.weights2d)))
    # rows 0/1 of the halo tensor are top/bottom incl. corner cells
    assert skip[:, :, 0, 0].max() == 0 and skip[:, :, 0, -1].max() == 0
    assert skip[:, :, 1, 0].max() == 0 and skip[:, :, 1, -1].max() == 0
    # interior of the strips is untouched by the skip
    np.testing.assert_array_equal(skip[:, :, 0, 1:-1], full[:, :, 0, 1:-1])
    np.testing.assert_array_equal(skip[:, :, 2, :rho], full[:, :, 2, :rho])
    # some real corner data existed, so the zeroing is the skip's doing
    assert full[:, :, 0, 0].max() > 0


# ------------------------------------------------------------- donation
def _donation_supported() -> bool:
    f = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    x = jnp.zeros(16)
    f(x)
    return x.is_deleted()


def test_donated_run_consumes_input():
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    eng = make_engine("block", fractals.SIERPINSKI, 5, 2, workload=HEAT)
    s = eng.init_random(seed=3)
    # NB: np.asarray(s) would be a zero-copy view pinning the buffer and
    # silently blocking donation — copy explicitly
    keep = np.array(s, copy=True)
    ref = _single_steps(eng, s, 4)
    out = eng.run(s, 4, donate=True)
    assert s.is_deleted()                    # zero-copy: input was consumed
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # non-donated run leaves the input alive
    s2 = jnp.asarray(keep)
    eng.run(s2, 4)
    assert not s2.is_deleted()


def test_donated_stepping_no_alloc_growth():
    """Steady-state donated stepping must not accumulate live buffers:
    every fused launch consumes its input and produces one output."""
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    eng = make_engine("block", fractals.SIERPINSKI, 5, 2, workload=HEAT)
    s = eng.run(eng.init_random(seed=4), 2, donate=True)  # warm the jit
    jax.block_until_ready(s)
    base = len(jax.live_arrays())
    for _ in range(6):
        s = eng.run(s, 2, donate=True)
    jax.block_until_ready(s)
    assert len(jax.live_arrays()) <= base


# ------------------------------------------------------- batched runner
def test_runner_fused_run_matches_loop():
    frac, r = fractals.SIERPINSKI, 5
    runner = BatchedRunner()
    for kind, m, wl, k in [("block", 2, GRAY_SCOTT, 2),
                           ("block", 2, LIFE, 3),
                           ("pallas-strips", 2, HEAT, 2)]:
        states = runner.init_batch(kind, frac, r, seeds=range(3), m=m,
                                   workload=wl)
        ran = runner.run(kind, frac, r, states, steps=5, m=m, workload=wl,
                        k=k)
        eng = runner.engine_for(kind, frac, r, m=m, workload=wl, k=k)
        for b in range(states.shape[0]):
            np.testing.assert_allclose(
                np.asarray(ran[b]),
                np.asarray(_single_steps(eng, states[b], 5)), **_tol(wl),
                err_msg=f"{kind}/{wl.name}/k={k} batch {b}")


def test_runner_cache_key_includes_k(monkeypatch):
    monkeypatch.setenv("SQUEEZE_TUNING", "off")  # pin the heuristic k
    frac, r, m = fractals.SIERPINSKI, 5, 2
    runner = BatchedRunner()
    e_default = runner.engine_for("block", frac, r, m=m, workload=LIFE)
    # the heuristic depth (rho=4 -> 2) and an equal explicit k share a slot
    assert runner.engine_for("block", frac, r, m=m, workload=LIFE,
                             k=2) is e_default
    assert runner.stats.builds == 1
    # a different fusion depth is a different compiled configuration
    e3 = runner.engine_for("block", frac, r, m=m, workload=LIFE, k=3)
    assert e3 is not e_default and e3.fusion_k == 3
    assert runner.stats.builds == 2
    # non-block kinds normalize k away entirely (one slot, no fusion)
    runner.engine_for("cell", frac, r, workload=LIFE)
    runner.engine_for("cell", frac, r, workload=LIFE, k=5)
    assert runner.stats.builds == 3
    with pytest.raises(ValueError, match="k must be >= 1"):
        runner.engine_for("block", frac, r, m=m, workload=LIFE, k=0)


def test_runner_donated_run():
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    frac, r, m = fractals.SIERPINSKI, 5, 2
    runner = BatchedRunner()
    states = runner.init_batch("block", frac, r, seeds=range(4), m=m,
                               workload=HEAT)
    ref = runner.run("block", frac, r, states, steps=4, m=m, workload=HEAT)
    out = runner.run("block", frac, r, states, steps=4, m=m, workload=HEAT,
                     donate=True)
    assert states.is_deleted()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
