"""Data pipeline: statelessness (restart invariance), shard disjointness,
signal learnability, prefetcher correctness."""
import numpy as np

from repro.data.pipeline import MemmapCorpus, Prefetcher, SyntheticMarkov


def test_batches_are_stateless_and_deterministic():
    d1 = SyntheticMarkov(vocab=97, seq_len=32, global_batch=4, seed=5)
    d2 = SyntheticMarkov(vocab=97, seq_len=32, global_batch=4, seed=5)
    for step in (0, 3, 1000):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_labels_are_next_token_shift():
    d = SyntheticMarkov(vocab=50, seq_len=16, global_batch=2, seed=0)
    b = d.batch(0)
    # the label at t equals the token at t+1
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_are_disjoint_streams():
    shards = [SyntheticMarkov(vocab=97, seq_len=8, global_batch=8, seed=1,
                              shard=i, n_shards=4) for i in range(4)]
    batches = [s.batch(0)["tokens"] for s in shards]
    assert all(b.shape == (2, 8) for b in batches)
    flat = [b.tobytes() for b in batches]
    assert len(set(flat)) == 4  # no two shards identical


def test_markov_signal_present():
    """perm[t] follows t with p_signal — measurable structure."""
    d = SyntheticMarkov(vocab=64, seq_len=512, global_batch=4, seed=2,
                        p_signal=0.9)
    b = d.batch(0)
    perm = d._perm()
    hits = (perm[b["tokens"]] == b["labels"]).mean()
    assert 0.85 < hits < 0.95


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "toks.bin")
    arr = (np.arange(10000) % 251).astype(np.uint16)
    arr.tofile(path)
    d = MemmapCorpus(path=path, vocab=256, seq_len=64, global_batch=4,
                     seed=3)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    b2 = d.batch(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_prefetcher_yields_in_order():
    d = SyntheticMarkov(vocab=31, seq_len=8, global_batch=2, seed=4)
    pf = Prefetcher(d, start_step=10)
    try:
        for want in (10, 11, 12):
            step, batch = pf.next()
            assert step == want
            np.testing.assert_array_equal(batch["tokens"],
                                          d.batch(want)["tokens"])
    finally:
        pf.close()
